"""Quickstart: simulate a real-world IoT stream in 20 lines.

Runs the paper's full pipeline — POSD preprocessing, NSA time-compression
(Algorithm 1), volatility report (Tables 1-3 metrics), and the PSDA
producer (Algorithm 2) feeding a toy consumer.

    PYTHONPATH=src python examples/quickstart.py
"""

import threading

from repro.core import (
    Producer,
    StreamQueue,
    VirtualClock,
    make_stream,
    nsa,
    preprocess,
    volatility,
)

# 1) a day of SogouQ-like search-engine queries (synthetic surrogate)
raw = make_stream("sogouq", scale=0.1, seed=0)
stream = preprocess(raw)                     # POSD: parse times, sort, zone
print(f"original: {len(stream):,} records over {stream.time_range/3600:.1f}h "
      f"volatility={volatility(stream)}")

# 2) compress the day into 10 simulated minutes (144x task acceleration)
sim = nsa(stream, max_range=600)             # NSA: normalize + sample
print(f"simulated: {len(sim):,} records into 600s "
      f"volatility={volatility(sim, 600)}")

# 3) replay it through the producer into a consumer (the 'SPS task')
queue = StreamQueue(maxsize=64)
producer = Producer(sim, queue, clock=VirtualClock())
threading.Thread(target=producer.run, daemon=True).start()

seen = 0
for bucket in queue:                         # ordered per-second buckets
    seen += len(bucket)
print(f"consumer received {seen:,} records in "
      f"{producer.emitted_buckets} buckets — "
      f"status={'success' if seen == len(sim) else 'fault'}")
