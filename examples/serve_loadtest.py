"""Serving load test driven by a time-compressed real-world stream.

The paper's headline scenario: a load test that would take a day replays in
minutes while preserving the arrival process's volatility and trend. Here a
small LM serves batched requests whose arrivals follow the compressed
SogouQ query stream (continuous batching, prefill + decode, latency
percentiles reported).

    PYTHONPATH=src python examples/serve_loadtest.py
"""

import sys

from repro.launch import serve

sys.argv = [
    "serve",
    "--dataset", "sogouq",
    "--max-range", "60",
    "--scale", "0.01",
    "--slots", "8",
    "--max-len", "48",
    "--prompt-len", "8",
    "--new-tokens", "6",
    "--max-requests-per-bucket", "3",
    "--out", "results/serve_loadtest_metrics.json",
]
serve.main()
