"""End-to-end driver: train a ~100M-parameter LM on a simulated IoT stream.

The paper's use-case "parameter optimization / load testing of a stream
processing task" with the SPS being a JAX training job: the PSDA producer
replays one compressed day of UserBehavior; batches inherit the stream's
arrival volatility. Fault tolerance is on: a failure is injected mid-run
and the loop recovers from the latest checkpoint.

    PYTHONPATH=src python examples/train_stream.py [--steps 300]

(~100M params; a few hundred steps take minutes on CPU.)
"""

import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] if len(sys.argv) > 1 else [])
parser = argparse.ArgumentParser()
parser.add_argument("--steps", type=int, default=300)
parser.add_argument("--batch", type=int, default=4)
parser.add_argument("--seq", type=int, default=256)
args = parser.parse_args()

from repro.launch import train  # noqa: E402

sys.argv = [
    "train",
    "--dataset", "userbehavior",
    "--max-range", "600",
    "--scale", "0.05",
    "--steps", str(args.steps),
    "--batch", str(args.batch),
    "--seq", str(args.seq),
    "--ckpt-every", "100",
    "--inject-failure", str(args.steps * 2 // 3),
    "--out", "results/train_stream_metrics.json",
]
train.main()
