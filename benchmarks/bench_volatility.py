"""Paper Tables 1-3: volatility of simulated stream data at the six time
ranges on the three datasets, next to the original stream's statistics.

Three beyond-paper rows track the fused metrics engine:
- ``volatility/fused_engine/*``     — one engine call (histogram + moments)
  vs the seed's separate bincount + moment passes, per dataset;
- ``volatility/batched_sweep_3x6``  — the full Tables 1-3 scenario sweep
  (3 datasets × 6 time ranges) reported through ONE batched metrics call
  (the ``Controller.run_many`` path) vs 18 sequential per-scenario
  dispatches, each re-reading its original stream (the seed
  ``Controller.run`` metrics tax);
- ``volatility/trend_cumsum_86400_w600`` — the O(n) cumsum sliding-mean
  ``trend()`` vs the seed's O(n·w) ``np.convolve`` at window=600 over a
  day-long (86 400-bucket) count series.

Set ``BENCH_QUICK=1`` for CI-smoke scales.
"""

from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from repro.streamsim import (make_stream, metrics_batched, nsa,
                             per_second_counts, preprocess, volatility)
from repro.streamsim.metrics import sliding_mean, trend_correlation_from_counts

TIME_RANGES = (600, 1200, 1800, 2400, 3000, 3600)
# full-scale tables match the paper's magnitudes; SCALE trades runtime
SCALE = {"sogouq": 1.0, "traffic": 1.0, "userbehavior": 0.25}
QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
if QUICK:
    SCALE = {k: 0.01 for k in SCALE}


def _best(fn, reps=3):
    """(result, min-of-reps seconds) — min is robust to scheduler noise."""
    out, best = fn(), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(csv: List[str]) -> None:
    streams, sims = {}, {}
    for name in ("sogouq", "traffic", "userbehavior"):
        t0 = time.perf_counter()
        s = preprocess(make_stream(name, scale=SCALE[name], seed=0))
        streams[name] = s
        v0 = volatility(s)
        csv.append(f"volatility/{name}/original,{(time.perf_counter()-t0)*1e6:.0f},"
                   f"avg={v0.average:.2f};var={v0.variance:.2f};"
                   f"std={v0.std_variance:.2f}")
        for mr in TIME_RANGES:
            t0 = time.perf_counter()
            sim = nsa(s, mr)
            dt = time.perf_counter() - t0
            sims[(name, mr)] = sim
            v = volatility(sim, mr)
            csv.append(
                f"volatility/{name}/max{mr},{dt*1e6:.0f},"
                f"avg={v.average:.2f};var={v.variance:.2f};"
                f"std={v.std_variance:.2f}")

        # fused engine: ONE call yields counts AND moments; the seed path
        # ran a bincount for the counts plus separate moment reductions
        m, dt_fused = _best(lambda: metrics_batched([s], [None])[0])

        def _seed_two_pass():
            q = per_second_counts(s)
            return (float(q.sum()), float((q.astype(np.float64) ** 2).sum()))

        _, dt_seed = _best(_seed_two_pass)
        assert abs(m.volatility.average - v0.average) <= \
            1e-3 * max(v0.average, 1e-9)
        csv.append(f"volatility/fused_engine/{name},{dt_fused*1e6:.0f},"
                   f"seed_two_pass_us={dt_seed*1e6:.0f};"
                   f"avg={m.volatility.average:.2f}")

    # ---- batched 3×6 scenario sweep vs 18 sequential dispatches ----------
    names = list(streams)
    scenarios = [(n, mr) for n in names for mr in TIME_RANGES]

    t0 = time.perf_counter()
    ms = metrics_batched(
        [streams[n] for n in names] + [sims[sc] for sc in scenarios],
        [None] * len(names) + [mr for _, mr in scenarios])
    om = dict(zip(names, ms[:len(names)]))
    batched = {
        sc: (om[sc[0]].volatility, m.volatility,
             trend_correlation_from_counts(om[sc[0]].counts, m.counts))
        for sc, m in zip(scenarios, ms[len(names):])}
    dt_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    sequential = {}
    for name, mr in scenarios:  # the seed per-run metrics tax, 18×
        s, sim = streams[name], sims[(name, mr)]
        sequential[(name, mr)] = (
            volatility(s), volatility(sim, mr),
            trend_correlation_from_counts(per_second_counts(s),
                                          per_second_counts(sim, mr)))
    dt_seq = time.perf_counter() - t0

    for sc in scenarios:
        assert abs(batched[sc][1].average - sequential[sc][1].average) <= \
            1e-3 * max(sequential[sc][1].average, 1e-9)
    csv.append(
        f"volatility/batched_sweep_3x6,{dt_batched*1e6:.0f},"
        f"scenarios={len(scenarios)};sequential_us={dt_seq*1e6:.0f};"
        f"speedup={dt_seq/max(dt_batched, 1e-9):.1f}x")

    # ---- cumsum trend vs the seed's convolve at window=600 over a day ----
    rng = np.random.default_rng(0)
    day = rng.poisson(25.0, 86_400).astype(np.float64)
    w = 600
    t_cum, dt_cum = _best(lambda: sliding_mean(day, w), reps=7)
    t_conv, dt_conv = _best(
        lambda: np.convolve(day, np.ones(w) / w, mode="same"), reps=7)
    np.testing.assert_allclose(t_cum, t_conv, rtol=1e-9, atol=1e-9)
    csv.append(
        f"volatility/trend_cumsum_86400_w600,{dt_cum*1e6:.0f},"
        f"convolve_us={dt_conv*1e6:.0f};"
        f"speedup={dt_conv/max(dt_cum, 1e-9):.1f}x")
