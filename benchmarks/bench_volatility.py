"""Paper Tables 1-3: volatility of simulated stream data at the six time
ranges on the three datasets, next to the original stream's statistics.

Also reports the device-kernel path (repro.kernels.ops.volatility_stats)
against the numpy statistics as a cross-check.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.kernels import ops
from repro.streamsim import make_stream, nsa, per_second_counts, preprocess, volatility

TIME_RANGES = (600, 1200, 1800, 2400, 3000, 3600)
# full-scale tables match the paper's magnitudes; SCALE trades runtime
SCALE = {"sogouq": 1.0, "traffic": 1.0, "userbehavior": 0.25}


def run(csv: List[str]) -> None:
    for name in ("sogouq", "traffic", "userbehavior"):
        t0 = time.perf_counter()
        s = preprocess(make_stream(name, scale=SCALE[name], seed=0))
        v0 = volatility(s)
        csv.append(f"volatility/{name}/original,{(time.perf_counter()-t0)*1e6:.0f},"
                   f"avg={v0.average:.2f};var={v0.variance:.2f};"
                   f"std={v0.std_variance:.2f}")
        for mr in TIME_RANGES:
            t0 = time.perf_counter()
            sim = nsa(s, mr)
            dt = time.perf_counter() - t0
            v = volatility(sim, mr)
            # kernel cross-check on the per-second counts
            q = per_second_counts(sim, mr)
            ka, kv_, kstd = ops.volatility_stats(q.astype(np.float32))
            assert abs(float(ka) - v.average) < 1e-3 * max(v.average, 1)
            csv.append(
                f"volatility/{name}/max{mr},{dt*1e6:.0f},"
                f"avg={v.average:.2f};var={v.variance:.2f};"
                f"std={v.std_variance:.2f};kernel_avg={float(ka):.2f}")
