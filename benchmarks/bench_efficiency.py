"""Paper Fig. 7 / Table 4 + §6: simulation cost per time range and the
headline task acceleration.

Two measurements per (dataset, max_range):
- simulation cost: NSA wall time (paper Table 4's 'time spent by the
  simulation process'), for BOTH the paper-faithful per-record loops and
  this framework's vectorized NSA (the beyond-paper speedup);
- task acceleration: original_range / max_range (>= 24x at 3600s).
"""

from __future__ import annotations

import os
import time
from typing import List

from repro.streamsim import make_stream, nsa, nsa_paper, preprocess
from repro.streamsim.nsa import compression_factor

TIME_RANGES = (3600, 3000, 2400, 1800, 1200, 600)  # paper Table 4 order
SCALE = {"sogouq": 1.0, "traffic": 1.0, "userbehavior": 0.25}
PAPER_LOOP_SCALE = 0.02  # per-record Python loops need a smaller stream
if bool(int(os.environ.get("BENCH_QUICK", "0"))):
    SCALE = {k: 0.01 for k in SCALE}
    PAPER_LOOP_SCALE = 0.002


def run(csv: List[str]) -> None:
    for name in ("sogouq", "traffic", "userbehavior"):
        s = preprocess(make_stream(name, scale=SCALE[name], seed=0))
        for mr in TIME_RANGES:
            t0 = time.perf_counter()
            sim = nsa(s, mr)
            dt = time.perf_counter() - t0
            csv.append(
                f"efficiency/{name}/max{mr},{dt*1e6:.0f},"
                f"rows={len(sim)};task_speedup={compression_factor(s, mr):.1f}x")
        # paper-faithful loop vs vectorized, equal inputs (reduced scale)
        sp = preprocess(make_stream(name, scale=PAPER_LOOP_SCALE, seed=0))
        t0 = time.perf_counter()
        nsa_paper(sp, 600)
        t_loop = time.perf_counter() - t0
        t0 = time.perf_counter()
        nsa(sp, 600)
        t_vec = time.perf_counter() - t0
        csv.append(
            f"efficiency/{name}/nsa_paper_loop,{t_loop*1e6:.0f},"
            f"vectorized_us={t_vec*1e6:.0f};"
            f"nsa_speedup={t_loop/max(t_vec,1e-9):.1f}x")
