"""PR 7 trajectory rows: chunked double-buffered pipeline + chunk overhead.

Two rows quantify what the unbounded-stream pipeline buys (carry reuse
and overlap, on multi-day sweeps) and costs (nothing measurable, in the
degenerate single-chunk case):

- ``chunked_pipeline_7day_8sc`` — a 7-day, 2-dataset × 4-time-range
  sweep (8 scenarios) streamed in ``chunk_s``-second chunks. NEW:
  ``Controller.run_many(chunk_s=..., duration_s=7*86400)`` — the
  double-buffered :class:`~repro.streamsim.engine.ChunkedSweepRunner`
  pipeline: chunk ``k+1``'s NSA → metrics dispatch is in flight while
  chunk ``k``'s host leg (gather → ``append_chunk`` → replay feed) runs,
  and the running statistics live in a device-resident
  :class:`~repro.kernels.ops.ChunkCarry` updated once per chunk. OLD
  (the path it replaces): the carry-less sequential chunk loop — block
  on every chunk's totals before dispatching the next, and, having no
  carry, rebuild the running statistics FROM SCRATCH over all chunks
  seen so far (the same ``stream_metrics_chunk`` kernel, replayed from a
  fresh carry each round — O(K²) metric dispatches vs the pipeline's
  O(K)), then replay the assembled streams. The win is algorithmic, so
  the row is gated at >=1.2x by ``check_regression.py``. The row also
  carries ``host_peak_rss_kb`` (``ru_maxrss``) — the bounded-residency
  evidence to read alongside the ``feed_hwm_chunks <= 2`` stat asserted
  in tests/test_chunked.py.

- ``chunk_vs_monolith_1day`` — a single-day grid run with
  ``chunk_s=86400`` (every scenario is ONE day-sized chunk) vs the
  monolithic ``run_many`` path. Both paths recompute from a purged
  store each rep, so this is the full pipeline cost side by side; the
  gate (<=1.05x) guards the chunk machinery's overhead in the
  degenerate case where it buys nothing. The row runs at a LARGER
  scale than the 7-day row: the chunk path's fixed cost (feed handoffs
  + one extra thread hop per chunk, ~ms) would dominate a toy-sized
  measurement and gate scheduler noise instead of structure.

Both rows run at reduced scale off-TPU and carry the usual ``@`` suffix
so trend tooling never mixes incommensurable sizes.
"""

from __future__ import annotations

import os
import resource
import shutil
import tempfile
from typing import List

import numpy as np

from repro.kernels import ops
from repro.streamsim import plan_sweep
from repro.streamsim.controller import Controller
from repro.streamsim.engine import (REPORT_TREND_WINDOW_S, replay_many)
from repro.streamsim.nsa import ChunkedNSA, materialize_sweep_chunk
from repro.streamsim.plan import DAY_S
from repro.streamsim.preprocess import Stream

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


def _tmin(fn, reps=3):
    """(result, min-of-reps seconds) — min is robust to scheduler noise."""
    import time
    out, best = fn(), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        best = min(best, time.perf_counter() - t0)
        assert r == out, "non-deterministic benchmark result"
    return out, best


def _consumer(queue):
    return {"records_seen": sum(len(b) for b in queue)}


def _purge(store, plan) -> None:
    """Drop the plan's simulated streams so every rep recomputes them
    (the store cache would otherwise turn later reps into replay-only)."""
    for spec in plan.scenarios:
        store.delete(spec.store_key)


def _concat(chunks: List[Stream]) -> Stream:
    return Stream(
        name=chunks[0].name,
        t=np.concatenate([c.t for c in chunks]),
        payload={k: np.concatenate([c.payload[k] for c in chunks])
                 for k in chunks[0].payload},
        scale_stamp=np.concatenate([c.scale_stamp for c in chunks]))


def run(csv: List[str]) -> None:
    if ops.on_tpu():
        scale, scale1, tag, tag1 = 0.05, 0.05, "", ""
    else:
        scale = 0.002 if QUICK else 0.004
        scale1 = 0.1                  # single-day row: see module docstring
        tag = f"@scale{scale}"
        tag1 = f"@scale{scale1}"
    reps = 2 if QUICK else 4
    seed = 11

    tmp = tempfile.mkdtemp(prefix="bench_pr7_")
    try:
        ctrl = Controller(os.path.join(tmp, "store"))

        # --- 7-day chunked pipeline vs carry-less sequential loop --------
        datasets7 = ["sogouq", "traffic"]
        ranges7 = (15, 30, 45, 60)
        dur, chunk = 7 * DAY_S, 30
        originals7, _ = ctrl._prepare_all(datasets7, scale, seed, dur)
        plan7 = plan_sweep(ctrl.store, datasets7, ranges7,
                           {d: len(originals7[d]) for d in datasets7},
                           scale=scale, seed=seed, n_devices=1,
                           host_index=0, n_hosts=1, chunk_s=chunk,
                           duration_s=dur)

        def _pipelined():
            _purge(ctrl.store, plan7)
            reports = ctrl.run_many(datasets7, ranges7, _consumer,
                                    scale=scale, seed=seed, chunk_s=chunk,
                                    duration_s=dur)
            return sum(r.consumer_metrics["records_seen"] for r in reports)

        def _sequential_chunks():
            # the carry-less loop this PR replaces: same chunk kernels,
            # but (a) block on each chunk's totals before the next
            # dispatch (no overlap) and (b) rebuild the running stats
            # from a FRESH carry over every chunk so far (no cross-chunk
            # state) — then replay the assembled streams
            _purge(ctrl.store, plan7)
            originals, _ = ctrl._prepare_all(datasets7, scale, seed, dur)
            specs = plan7.scenarios
            pairs = [(s.dataset, s.span_s) for s in specs]
            cn = ChunkedNSA(originals, pairs)
            parts = {s.scenario: [] for s in specs}
            history = []          # (lo, hi, ss_kept, totals) per chunk
            for k in range(plan7.n_chunks):
                lo = k * chunk
                hi = min(lo + chunk, cn.width)
                if lo >= hi:
                    break
                h = cn.chunk(lo, hi)
                totals = np.asarray(h.totals, np.int64)   # block: no overlap
                chunks = materialize_sweep_chunk(originals, pairs, h,
                                                 totals)
                for r, s in enumerate(specs):
                    if k < s.n_chunks:
                        parts[s.scenario].append(chunks[r])
                        ctrl.store.append_chunk(s.store_key, k, chunks[r])
                history.append((lo, hi, h.ss_kept, h.totals))
                car = ops.chunk_carry_init(len(specs), cn.width,
                                           window=REPORT_TREND_WINDOW_S)
                for (lo_i, hi_i, ss_i, tot_i) in history:
                    car = ops.stream_metrics_chunk(car, ss_i, tot_i,
                                                   lo_i, hi_i)
                np.asarray(car.hist)          # running stats READ per chunk
            for s in specs:
                ctrl.store.finalize_chunks(
                    s.store_key, name=originals[s.dataset].name,
                    n_chunks=s.n_chunks,
                    extra_meta={"max_range": s.max_range})
            sims = {s.scenario: _concat(parts[s.scenario]) for s in specs}
            metrics, _ = replay_many(sims, _consumer, 64)
            return sum(m["records_seen"] for m in metrics.values())

        got_new, dt_new = _tmin(_pipelined, reps=reps)
        got_old, dt_old = _tmin(_sequential_chunks, reps=reps)
        assert got_new == got_old, "pipelined and sequential chunk loops " \
            f"must deliver identical record totals ({got_new} vs {got_old})"
        rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        csv.append(
            f"PR7/chunked_pipeline_7day_8sc{tag},{dt_new*1e6:.0f},"
            f"scenarios={len(plan7.scenarios)};days=7;chunk_s={chunk};"
            f"rounds={plan7.n_chunks};"
            f"sequential_chunk_path_us={dt_old*1e6:.0f};"
            f"host_peak_rss_kb={rss_kb};"
            f"speedup={dt_old/max(dt_new, 1e-9):.1f}x")

        # --- single-chunk chunked run vs monolithic run ------------------
        datasets1 = ["sogouq", "traffic", "userbehavior"]
        ranges1 = (30, 60)
        originals1, _ = ctrl._prepare_all(datasets1, scale1, seed)
        plan1 = plan_sweep(ctrl.store, datasets1, ranges1,
                           {d: len(originals1[d]) for d in datasets1},
                           scale=scale1, seed=seed, n_devices=1,
                           host_index=0, n_hosts=1)

        def _single_chunk():
            _purge(ctrl.store, plan1)
            reports = ctrl.run_many(datasets1, ranges1, _consumer,
                                    scale=scale1, seed=seed, chunk_s=DAY_S)
            return sum(r.consumer_metrics["records_seen"] for r in reports)

        def _monolithic():
            _purge(ctrl.store, plan1)
            reports = ctrl.run_many(datasets1, ranges1, _consumer,
                                    scale=scale1, seed=seed)
            return sum(r.consumer_metrics["records_seen"] for r in reports)

        got_c, dt_c = _tmin(_single_chunk, reps=reps)
        got_m, dt_m = _tmin(_monolithic, reps=reps)
        assert got_c == got_m, "chunked and monolithic sweeps must " \
            f"deliver identical record totals ({got_c} vs {got_m})"
        csv.append(
            f"PR7/chunk_vs_monolith_1day{tag1},{dt_c*1e6:.0f},"
            f"scenarios={len(plan1.scenarios)};chunk_s={DAY_S};"
            f"monolithic_path_us={dt_m*1e6:.0f};"
            f"overhead={dt_c/max(dt_m, 1e-9):.2f}x")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
