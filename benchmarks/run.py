"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_volatility  -> Tables 1-3 (volatility at the six time ranges)
  bench_network     -> Fig. 6   (bytes into the SPS, trend correlation)
  bench_efficiency  -> Fig. 7 / Table 4 + the >=24x headline (§6)
  bench_kernels     -> Pallas kernel micro-benchmarks

Alongside the CSV, every module's rows are written machine-readable to
``BENCH_<module>.json`` (set ``BENCH_OUT_DIR`` to redirect; default CWD) so
the per-PR perf trajectory can be tracked by tooling instead of CSV scraping.

``BENCH_QUICK=1`` switches every module to CI-smoke scales (small synthetic
streams, reduced kernel shapes); reduced rows carry an ``@shape`` suffix in
their name so trend tooling never mixes them with full-scale rows. CI runs
the quick mode on every PR and uploads the JSON as workflow artifacts.
"""

import json
import os
import sys


def _parse_row(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main() -> None:
    from benchmarks import (bench_efficiency, bench_kernels, bench_network,
                            bench_PR4, bench_PR5, bench_PR6, bench_PR7,
                            bench_PR8, bench_PR9, bench_PR10,
                            bench_volatility)
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    csv = ["name,us_per_call,derived"]
    for mod in (bench_volatility, bench_network, bench_efficiency,
                bench_kernels, bench_PR4, bench_PR5, bench_PR6, bench_PR7,
                bench_PR8, bench_PR9, bench_PR10):
        print(f"# running {mod.__name__} ...", file=sys.stderr, flush=True)
        start = len(csv)
        mod.run(csv)
        suffix = mod.__name__.split(".")[-1].replace("bench_", "")
        path = os.path.join(out_dir, f"BENCH_{suffix}.json")
        with open(path, "w") as f:
            json.dump([_parse_row(r) for r in csv[start:]], f, indent=2)
        print(f"# wrote {path}", file=sys.stderr, flush=True)
    print("\n".join(csv))


if __name__ == '__main__':
    main()
