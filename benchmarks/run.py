"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_volatility  -> Tables 1-3 (volatility at the six time ranges)
  bench_network     -> Fig. 6   (bytes into the SPS, trend correlation)
  bench_efficiency  -> Fig. 7 / Table 4 + the >=24x headline (§6)
  bench_kernels     -> Pallas kernel micro-benchmarks
"""

import sys


def main() -> None:
    from benchmarks import bench_efficiency, bench_kernels, bench_network, \
        bench_volatility
    csv = ["name,us_per_call,derived"]
    for mod in (bench_volatility, bench_network, bench_efficiency,
                bench_kernels):
        print(f"# running {mod.__name__} ...", file=sys.stderr, flush=True)
        mod.run(csv)
    print("\n".join(csv))


if __name__ == '__main__':
    main()
