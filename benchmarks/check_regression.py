"""Bench-regression smoke gate for the batched-sweep rows.

Reads ``BENCH_*.json`` files produced by ``benchmarks/run.py`` and fails
(exit 1) if any gated row is slower than the path it replaced (recorded
as a ``*_us`` derived field on the row):

- ``PR4/sweep_single_dispatch_3x6`` vs ``per_range_path_us`` — the
  range-padded single launch must beat the per-range dispatch loop
  (guards range-padding overhead on small sweeps).
- ``PR5/sweep_sharded_4dev_8x6`` vs ``pr4_single_dispatch_us`` — the
  planner's size-grouped shards must beat the monolithic PR 4 launch
  (guards the padded-area win and the per-shard dispatch overhead).
- ``PR5/device_resident_report_64`` vs ``host_gather_path_us`` — the
  device-resident report chain must beat the host-gather + per-scenario
  loop it replaced.
- ``PR6/sweep_resume_3x4_k8`` vs ``restart_from_zero_us`` — resuming a
  killed checkpointed sweep (8 of 12 scenarios already marked done) must
  beat restarting it from zero (guards the marker-read overhead and any
  accidental re-replay of completed scenarios).

Structural regressions (an accidental per-scenario dispatch loop, a
padding blowup, a host round-trip creeping back in) show up as
multiples, far outside benchmark noise; the currently measured quick-mode
margins are >2x on every gated row.

Usage: ``python benchmarks/check_regression.py BENCH_PR4.json
[BENCH_PR5.json ...]`` — each file is checked against the gated rows it
is expected to carry (matched by the row prefix in the file name).
"""

from __future__ import annotations

import json
import os
import re
import sys

#: gated row -> the derived field naming the replaced path's time
GATES = {
    "PR4/sweep_single_dispatch_3x6": "per_range_path_us",
    "PR5/sweep_sharded_4dev_8x6": "pr4_single_dispatch_us",
    "PR5/device_resident_report_64": "host_gather_path_us",
    "PR6/sweep_resume_3x4_k8": "restart_from_zero_us",
}


def _expected_rows(path: str):
    """The gated rows a file must carry, by its BENCH_<prefix>.json name."""
    stem = os.path.basename(path)
    m = re.match(r"BENCH_(\w+)\.json$", stem)
    prefix = (m.group(1) if m else "") + "/"
    return [name for name in GATES if name.startswith(prefix)]


def _check_row(rows, name: str, baseline_field: str) -> int:
    row = next((r for r in rows if r["name"].split("@")[0] == name), None)
    if row is None:
        print(f"FAIL: no {name} row found", file=sys.stderr)
        return 1
    m = re.search(rf"{baseline_field}=(\d+(?:\.\d+)?)", row["derived"])
    if m is None:
        print(f"FAIL: {row['name']} carries no {baseline_field} baseline",
              file=sys.stderr)
        return 1
    new, baseline = float(row["us_per_call"]), float(m.group(1))
    verdict = "OK" if new <= baseline else "FAIL"
    print(f"{verdict}: {row['name']} = {new:.0f}us vs replaced-path "
          f"baseline {baseline:.0f}us ({baseline / max(new, 1e-9):.1f}x)")
    if new > baseline:
        print(f"{name} is SLOWER than the path it replaces — structural "
              "regression", file=sys.stderr)
        return 1
    return 0


def check(paths) -> int:
    status = 0
    for path in paths:
        with open(path) as f:
            rows = json.load(f)
        expected = _expected_rows(path)
        if not expected:
            print(f"note: no gated rows expected in {path}")
            continue
        for name in expected:
            status |= _check_row(rows, name, GATES[name])
    return status


if __name__ == "__main__":
    sys.exit(check(sys.argv[1:] or ["BENCH_PR4.json", "BENCH_PR5.json"]))
