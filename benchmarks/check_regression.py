"""Bench-regression smoke gate for the single-dispatch sweep.

Reads a ``BENCH_PR4.json`` produced by ``benchmarks/run.py`` and fails
(exit 1) if the ``PR4/sweep_single_dispatch_3x6`` row is slower than the
per-range path it replaced (its ``per_range_path_us`` derived field) —
the guard against the range-padding overhead regressing small sweeps,
which is exactly the regime quick-mode CI measures. Structural
regressions (an accidental per-range dispatch loop, a padding blowup)
show up as multiples, far outside benchmark noise; the currently measured
quick-mode margin is >3x.

Usage: ``python benchmarks/check_regression.py path/to/BENCH_PR4.json``
"""

from __future__ import annotations

import json
import re
import sys

GATED_ROW = "PR4/sweep_single_dispatch_3x6"


def check(path: str) -> int:
    with open(path) as f:
        rows = json.load(f)
    row = next((r for r in rows
                if r["name"].split("@")[0] == GATED_ROW), None)
    if row is None:
        print(f"FAIL: no {GATED_ROW} row in {path}", file=sys.stderr)
        return 1
    m = re.search(r"per_range_path_us=(\d+(?:\.\d+)?)", row["derived"])
    if m is None:
        print(f"FAIL: {row['name']} carries no per_range_path_us baseline",
              file=sys.stderr)
        return 1
    new, baseline = float(row["us_per_call"]), float(m.group(1))
    verdict = "OK" if new <= baseline else "FAIL"
    print(f"{verdict}: {row['name']} = {new:.0f}us vs per-range baseline "
          f"{baseline:.0f}us ({baseline / max(new, 1e-9):.1f}x)")
    if new > baseline:
        print("single-dispatch sweep is SLOWER than the per-range path it "
              "replaces — range-padding overhead regression", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_PR4.json"))
