"""Bench-regression smoke gate for the batched-sweep rows.

Reads ``BENCH_*.json`` files produced by ``benchmarks/run.py`` and fails
(exit 1) if any gated row misses its ratio against the path it replaced
(recorded as a ``*_us`` derived field on the row). Each gate is
``(baseline_field, max_ratio)``: the row passes while
``new <= baseline * max_ratio``, so ``1.0`` means "no slower than the
replaced path" and ``1 / 1.2`` means "at least 1.2x faster".

- ``PR4/sweep_single_dispatch_3x6`` vs ``per_range_path_us`` — the
  range-padded single launch must beat the per-range dispatch loop
  (guards range-padding overhead on small sweeps).
- ``PR5/sweep_sharded_4dev_8x6`` vs ``pr4_single_dispatch_us`` — the
  planner's size-grouped shards must beat the monolithic PR 4 launch
  (guards the padded-area win and the per-shard dispatch overhead).
- ``PR5/device_resident_report_64`` vs ``host_gather_path_us`` — the
  device-resident report chain must beat the host-gather + per-scenario
  loop it replaced.
- ``PR6/sweep_resume_3x4_k8`` vs ``restart_from_zero_us`` — resuming a
  killed checkpointed sweep (8 of 12 scenarios already marked done) must
  beat restarting it from zero (guards the marker-read overhead and any
  accidental re-replay of completed scenarios).
- ``PR7/chunked_pipeline_7day_8sc`` vs ``sequential_chunk_path_us`` at
  ratio ``1/1.2`` — the double-buffered cross-chunk-carry pipeline must
  be at least 1.2x faster than the naive sequential chunk loop it
  replaces for unbounded streams (block per chunk, recompute running
  stats from scratch each chunk).
- ``PR7/chunk_vs_monolith_1day`` vs ``monolithic_path_us`` at ratio
  ``1.05`` — running one day as a single day-sized chunk must cost at
  most 5% over the monolithic sweep path (guards chunking overhead in
  the degenerate single-chunk case).
- ``PR8/task_etl`` / ``PR8/task_windowed_stats`` /
  ``PR8/task_event_detect`` vs ``original_replay_us`` at ratio ``1/4``
  — each stream task's simulated replay must be at least 4x faster than
  replaying the original stream (the paper's >= 24x claim holds at
  full-day spans; the CI smoke runs a reduced span, so the gate floor
  is conservative). The fidelity half of the claim is enforced INSIDE
  ``bench_PR8.run`` (hard failure below ``FIDELITY_FLOOR``).
- ``PR8/task_serving`` vs ``original_replay_us`` at ratio ``1/2`` — the
  warm-engine serving load test must be at least 2x faster under the
  simulated arrival mix.
- ``PR9/service_failover_recovery`` vs ``restart_from_zero_us`` — a
  surviving sweep-service worker recovering a killed peer's sweep (8 of
  12 results already published, one expired lease reaped + requeued)
  must beat restarting the whole sweep from zero (guards the reap/claim
  marker overhead and any accidental re-execution of published
  scenarios).
- ``PR9/service_overhead`` vs ``direct_run_many_us`` at ratio ``1.15``
  — the full service path (election, queue/lease/result markers,
  heartbeat, count-row merge) must stay within 15% of the direct
  ``run_many`` it wraps when nothing fails.
- ``PR10/tuned_vs_fixed_metrics_86400`` / ``PR10/tuned_vs_fixed_sweep_8x6``
  vs ``fixed_tile_path_us`` at ratio ``1.0`` — a dispatch running under
  the cached tile autotuner must never lose to the fixed default tiles
  it replaces (the tuner's floor IS the default config: it sits in the
  candidate lattice, so a slower row means the oracle-gated sweep picked
  a loser or cache lookup grew a hot-path cost). The one-off
  cache-population sweep is excluded from the timed leg and reported as
  the untimed ``tune_sweep_us`` field.

Structural regressions (an accidental per-scenario dispatch loop, a
padding blowup, a host round-trip creeping back in) show up as
multiples, far outside benchmark noise.

Usage: ``python benchmarks/check_regression.py BENCH_PR4.json
[BENCH_PR5.json ...]`` — each file is checked against the gated rows it
is expected to carry (matched by the row prefix in the file name). A
named file that does not exist is a hard FAIL with a one-line message
(no traceback): a missing baseline means the gate silently stopped
gating, which is itself the regression.
"""

from __future__ import annotations

import json
import os
import re
import sys

#: gated row -> (derived field naming the replaced path's time, max ratio
#: of the new time over that baseline for the gate to pass)
GATES = {
    "PR4/sweep_single_dispatch_3x6": ("per_range_path_us", 1.0),
    "PR5/sweep_sharded_4dev_8x6": ("pr4_single_dispatch_us", 1.0),
    "PR5/device_resident_report_64": ("host_gather_path_us", 1.0),
    "PR6/sweep_resume_3x4_k8": ("restart_from_zero_us", 1.0),
    "PR7/chunked_pipeline_7day_8sc": ("sequential_chunk_path_us", 1 / 1.2),
    "PR7/chunk_vs_monolith_1day": ("monolithic_path_us", 1.05),
    "PR8/task_etl": ("original_replay_us", 1 / 4),
    "PR8/task_windowed_stats": ("original_replay_us", 1 / 4),
    "PR8/task_event_detect": ("original_replay_us", 1 / 4),
    "PR8/task_serving": ("original_replay_us", 1 / 2),
    "PR9/service_failover_recovery": ("restart_from_zero_us", 1.0),
    "PR9/service_overhead": ("direct_run_many_us", 1.15),
    "PR10/tuned_vs_fixed_metrics_86400": ("fixed_tile_path_us", 1.0),
    "PR10/tuned_vs_fixed_sweep_8x6": ("fixed_tile_path_us", 1.0),
}


def _expected_rows(path: str):
    """The gated rows a file must carry, by its BENCH_<prefix>.json name."""
    stem = os.path.basename(path)
    m = re.match(r"BENCH_(\w+)\.json$", stem)
    prefix = (m.group(1) if m else "") + "/"
    return [name for name in GATES if name.startswith(prefix)]


def _check_row(rows, name: str, baseline_field: str,
               max_ratio: float, path: str) -> int:
    row = next((r for r in rows if r["name"].split("@")[0] == name), None)
    if row is None:
        print(f"FAIL: no {name} row found [read {path}]", file=sys.stderr)
        return 1
    m = re.search(rf"{baseline_field}=(\d+(?:\.\d+)?)", row["derived"])
    if m is None:
        print(f"FAIL: {row['name']} carries no {baseline_field} baseline "
              f"[read {path}]", file=sys.stderr)
        return 1
    new, baseline = float(row["us_per_call"]), float(m.group(1))
    ok = new <= baseline * max_ratio
    need = (f"needed <= {max_ratio:.2f}x of baseline" if max_ratio != 1.0
            else "needed no slower")
    print(f"{'OK' if ok else 'FAIL'}: {row['name']} = {new:.0f}us vs "
          f"replaced-path baseline {baseline:.0f}us "
          f"({baseline / max(new, 1e-9):.1f}x; {need}) [read {path}]")
    if not ok:
        print(f"{name} misses its gate against the path it replaces — "
              "structural regression", file=sys.stderr)
        return 1
    return 0


def check(paths) -> int:
    status = 0
    for path in paths:
        if not os.path.isfile(path):
            print(f"FAIL: benchmark file {path} is missing — the gated "
                  "rows it carries were never produced (run "
                  "`BENCH_QUICK=1 python benchmarks/run.py` first)",
                  file=sys.stderr)
            status |= 1
            continue
        with open(path) as f:
            rows = json.load(f)
        expected = _expected_rows(path)
        if not expected:
            print(f"note: no gated rows expected in {path}")
            continue
        for name in expected:
            field, max_ratio = GATES[name]
            status |= _check_row(rows, name, field, max_ratio, path)
    return status


if __name__ == "__main__":
    sys.exit(check(sys.argv[1:] or ["BENCH_PR4.json", "BENCH_PR5.json",
                                    "BENCH_PR6.json", "BENCH_PR7.json",
                                    "BENCH_PR8.json", "BENCH_PR9.json",
                                    "BENCH_PR10.json"]))
