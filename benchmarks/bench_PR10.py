"""PR 10 trajectory rows: shape-keyed tile autotuner vs the fixed tiles.

Two rows quantify what the measured-sweep tuner costs (a one-off
candidate sweep, cached under the store) and buys (a tile choice at
least as fast as the shipped constants — on CPU-interpret and current
TPU shapes usually *the same* constants, which is exactly the guarantee
being gated):

- ``tuned_vs_fixed_metrics_86400`` — the one-day fused-metrics dispatch
  (per-second histogram + volatility moments over ``max_range`` buckets).
  NEW: the dispatch runs under an ambient ``KernelTuner("cached",
  store=...)`` whose winner was measured on-device and persisted; the
  timed leg hits the in-memory/JSON cache (zero sweep work). OLD: the
  same dispatch with the fixed default tiles. Gated ≤ 1.0× by
  ``check_regression.py``: the tuner may only ever *match or beat* the
  fixed tiles — a tuned dispatch slower than the constants it replaces
  means the oracle-gated sweep picked a loser or the cache lookup grew a
  hot-path cost. The one-off cache-population sweep is explicitly NOT in
  the timed leg; it is reported as the untimed ``tune_sweep_us`` field.

- ``tuned_vs_fixed_sweep_8x6`` — the PR 5 planner shape (8 heterogeneous
  streams × 6 time ranges, 48 scenarios) through the full
  ``execute_sweep`` engine path, ``autotune="cached"`` vs the default
  fixed-tile run. Same gate, same exclusion: the first tuned run
  populates the shared tuner's cache (reported untimed as
  ``tune_sweep_us``), the timed reps measure the steady state every
  later sweep sees.

Off-TPU both sides run the Pallas kernels in interpret mode at reduced
shapes (``@`` name suffixes keep trend tooling honest), so the ratio
measures the tuner's dispatch-time machinery — cache lookups and config
plumbing — rather than silicon tile preferences; on TPU the same rows
measure real tile wins.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List

import numpy as np

from repro.kernels import ops, tuning
from repro.streamsim import make_stream, plan_sweep, preprocess
from repro.streamsim import engine as sweep_engine
from repro.streamsim.store import StreamStore

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


def _tmin_pair(fn_a, fn_b, reps=3):
    """((result_a, min_a), (result_b, min_b)) with a/b timed alternately
    rep by rep — drifting machine load hits both legs equally instead of
    landing entirely on whichever leg happened to run in the slow window.
    For ratio-gated rows this is what keeps the comparison fair."""
    out_a, out_b = fn_a(), fn_b()
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        assert r == out_a, "non-deterministic benchmark result"
        t0 = time.perf_counter()
        r = fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
        assert r == out_b, "non-deterministic benchmark result"
    return (out_a, best_a), (out_b, best_b)


def _hetero_streams(n, base_scale, seed=10):
    """n streams with a record-count spread — the planner's target shape
    (mirrors bench_PR5, so the two benches track the same regime)."""
    names = ("sogouq", "traffic", "userbehavior")
    out = {}
    for i in range(n):
        sc = base_scale * (1 + (i % 4)) * (2 if i >= n // 2 else 1)
        s = preprocess(make_stream(names[i % 3], scale=sc, seed=seed + i))
        s.name = f"s{i}"
        out[f"s{i}"] = s
    return out


def run(csv: List[str]) -> None:
    on_tpu = ops.on_tpu()
    reps = 2 if QUICK else 4
    tmp = tempfile.mkdtemp(prefix="bench_pr10_")
    try:
        store = StreamStore(os.path.join(tmp, "store"))

        # --- one-day metrics dispatch: tuned vs fixed tiles --------------
        if on_tpu:
            mr, scale, tag = 86400, 0.05, ""
        else:
            # interpret mode: shrink the bucket axis so a candidate sweep
            # costs seconds, not minutes — the machinery under test (cache
            # lookup on the hot path) is shape-independent
            mr, scale = 900, 0.004
            tag = f"@mr{mr}-scale{scale}"
        streams = [preprocess(make_stream(n, scale=scale, seed=10 + i))
                   for i, n in enumerate(("sogouq", "traffic",
                                          "userbehavior", "traffic"))]
        stamps = []
        for s in streams:
            from repro.streamsim.nsa import nsa
            stamps.append(nsa(s, mr, backend="pallas").scale_stamp)

        tuner = tuning.KernelTuner("cached", store=store, reps=3)

        def _fixed():
            hist, mom, _ = ops.stream_metrics_batched(stamps, mr)
            return int(np.asarray(hist).sum())

        def _tuned():
            with tuning.use(tuner):
                hist, mom, _ = ops.stream_metrics_batched(stamps, mr)
            return int(np.asarray(hist).sum())

        # cache population (the one-off measured sweep + JSON persist) is
        # deliberately OUTSIDE the timed legs: it is a per-(device, shape)
        # cost amortized over every later dispatch
        t0 = time.perf_counter()
        _tuned()
        tune_sweep_s = time.perf_counter() - t0
        # the 1.0x gate leaves no noise margin and each leg costs only a
        # few ms, so this row takes extra alternated reps — min-of-reps
        # must converge on both sides before the ratio means anything
        (got_tuned, dt_tuned), (got_fixed, dt_fixed) = _tmin_pair(
            _tuned, _fixed, reps=max(reps, 8))
        assert got_tuned == got_fixed, "tuned and fixed-tile metrics " \
            f"must bucket identically ({got_tuned} vs {got_fixed})"
        csv.append(
            f"PR10/tuned_vs_fixed_metrics_86400{tag},{dt_tuned*1e6:.0f},"
            f"streams={len(stamps)};max_range={mr};"
            f"fixed_tile_path_us={dt_fixed*1e6:.0f};"
            f"tune_sweep_us={tune_sweep_s*1e6:.0f};"
            f"ratio={dt_tuned/max(dt_fixed, 1e-9):.2f}x")

        # --- full 8x6 engine sweep: autotune="cached" vs default ---------
        if on_tpu:
            ranges, base, stag = (600, 1200, 1800, 2400, 3000, 3600), \
                0.05, ""
        else:
            ranges = (60, 120, 180, 240, 300, 360)
            base = 0.0001 if QUICK else 0.0002
            stag = f"@scale{base}"
        sweep_streams = _hetero_streams(8, base)
        row_counts = {k: len(v) for k, v in sweep_streams.items()}

        def _plan():
            return plan_sweep(store, list(sweep_streams), ranges,
                              row_counts, n_devices=4, host_index=0,
                              n_hosts=1)

        def _sweep_fixed():
            result = sweep_engine.execute_sweep(_plan(), sweep_streams,
                                                store, backend="pallas")
            sims = result.materialize(store=False)
            return sum(len(s) for s in sims.values())

        def _sweep_tuned():
            result = sweep_engine.execute_sweep(_plan(), sweep_streams,
                                                store, backend="pallas",
                                                autotune="cached")
            sims = result.materialize(store=False)
            return sum(len(s) for s in sims.values())

        t0 = time.perf_counter()
        _sweep_tuned()        # populates the shared cached tuner (untimed)
        sweep_tune_s = time.perf_counter() - t0
        (got_tuned, dt_tuned), (got_fixed, dt_fixed) = _tmin_pair(
            _sweep_tuned, _sweep_fixed, reps=reps)
        assert got_tuned == got_fixed, "tuned and fixed-tile sweeps must " \
            f"produce identical simulated row totals " \
            f"({got_tuned} vs {got_fixed})"
        csv.append(
            f"PR10/tuned_vs_fixed_sweep_8x6{stag},{dt_tuned*1e6:.0f},"
            f"scenarios={8 * len(ranges)};"
            f"fixed_tile_path_us={dt_fixed*1e6:.0f};"
            f"tune_sweep_us={sweep_tune_s*1e6:.0f};"
            f"ratio={dt_tuned/max(dt_fixed, 1e-9):.2f}x")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
