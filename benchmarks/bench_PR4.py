"""PR 4 trajectory rows: single-dispatch full-sweep NSA + batched replay.

Three rows quantify what collapsing the Tables 1-3 sweep buys:

- ``sweep_single_dispatch_3x6`` — the full (3 datasets × 6 time ranges)
  scenario grid simulated end-to-end (normalize → sample → mask → compact
  → gather, producing all 18 simulated streams): ONE range-padded
  ``nsa_sweep`` launch (1 sample dispatch + 1 batched compaction) vs the
  per-range path it replaces (6 ``nsa_batched`` dispatches + 18 per-stream
  compactions — the pre-PR-4 ``Controller.run_many`` composition). This is
  the NSA-stage analogue of PR 2's ``volatility/batched_sweep_3x6`` row,
  which collapsed the same grid one pipeline stage later (metrics). The CI
  regression smoke fails if the single-dispatch path is ever slower than
  the per-range path (guarding the range-padding overhead on small
  sweeps).
- ``nsa_range_padded_64x256k`` — kernel-level range padding: 64 rows
  cycling through mixed ``max_range`` values in ONE dispatch vs one
  per-range ``stream_sample_batched`` dispatch per distinct range.
- ``producer_multiqueue_replay`` — the PSDA replay in the
  ``Controller.run_many`` shape: ONE merged virtual-time loop feeding 18
  bounded queues drained by concurrent consumers, vs 18 sequential
  producer-thread/consumer replays (the pre-PR-4 ``_produce_consume``
  loop). Thread-scheduling sensitive — a trajectory row, not a CI gate.

All rows are min-of-reps; reduced scales carry an ``@`` suffix so trend
tooling never mixes incommensurable sizes. Full scale is the TPU target —
off-TPU the Pallas legs run in interpret mode, whose per-grid-step
emulation cost grows with the batched row count, so the CPU rows measure
small sweeps: exactly the regime the CI padding-overhead guard cares
about.
"""

from __future__ import annotations

import os
import threading
import time
from typing import List

from repro.kernels import ops
from repro.streamsim import (Producer, StreamQueue, VirtualClock,
                             make_stream, nsa_batched, preprocess)
from repro.streamsim.nsa import nsa_sweep
from repro.streamsim.producer import MultiQueueProducer
from repro.streamsim.queue import QueueGroup

TIME_RANGES = (600, 1200, 1800, 2400, 3000, 3600)
QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
QUEUE_SIZE = 65_536


def _tmin(fn, reps=3):
    """(result, min-of-reps seconds) — min is robust to scheduler noise."""
    out, best = fn(), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        best = min(best, time.perf_counter() - t0)
        assert r == out, "non-deterministic benchmark result"
    return out, best


def _consume(queue) -> int:
    return sum(len(b) for b in queue)


def _replay_multi(sims) -> int:
    """ONE merged virtual-time loop + one concurrent consumer per queue."""
    group = QueueGroup(sims, maxsize=QUEUE_SIZE)
    mp = MultiQueueProducer(sims, group.queues, clock=VirtualClock())
    seen = {}
    consumers = [threading.Thread(
        target=lambda k=k: seen.__setitem__(k, _consume(group[k])),
        daemon=True) for k in sims]
    producer = threading.Thread(target=mp.run, daemon=True)
    for th in consumers + [producer]:
        th.start()
    for th in consumers + [producer]:
        th.join()
    return sum(seen.values())


def _replay_sequential(sims) -> int:
    """The pre-PR-4 shape: per scenario, a producer thread feeding a
    bounded queue drained by the caller (Controller._produce_consume)."""
    total = 0
    for sim in sims.values():
        queue = StreamQueue(maxsize=QUEUE_SIZE)
        producer = Producer(sim, queue, clock=VirtualClock())
        th = threading.Thread(target=producer.run, daemon=True)
        th.start()
        total += _consume(queue)
        th.join()
    return total


def run(csv: List[str]) -> None:
    # full scale only makes sense on TPU (off-TPU the kernels run in
    # interpret mode); the @scale suffix records what actually ran
    if ops.on_tpu():
        scale, tag = {"sogouq": 1.0, "traffic": 1.0, "userbehavior": 0.25}, ""
    else:
        s = 0.0002 if QUICK else 0.0005
        scale = {k: s for k in ("sogouq", "traffic", "userbehavior")}
        tag = f"@scale{s}"
    streams = {name: preprocess(make_stream(name, scale=sc, seed=0))
               for name, sc in scale.items()}
    reps = 3 if QUICK else 5

    # --- the full-grid NSA sweep: 1 launch vs 6 + 18 ----------------------
    def _single_dispatch():
        sims = nsa_sweep(streams, TIME_RANGES, backend="pallas")
        return sum(len(s) for s in sims.values())

    def _per_range():
        total = 0
        for mr in TIME_RANGES:
            batch = nsa_batched(streams, mr, backend="pallas")
            total += sum(len(s) for s in batch.values())
        return total

    got_new, dt_new = _tmin(_single_dispatch, reps=reps)
    got_old, dt_old = _tmin(_per_range, reps=reps)
    assert got_new == got_old, "sweep and per-range paths must produce " \
        f"identical simulated row totals ({got_new} vs {got_old})"
    csv.append(
        f"PR4/sweep_single_dispatch_3x6{tag},{dt_new*1e6:.0f},"
        f"scenarios=18;nsa_dispatches=1;"
        f"per_range_path_us={dt_old*1e6:.0f};"
        f"speedup={dt_old/max(dt_new, 1e-9):.1f}x")

    # --- kernel-level range padding: 64 mixed-range rows, one dispatch ----
    import numpy as np
    rng = np.random.default_rng(0)
    S = 8 if QUICK else 64
    ns = 262_144 if ops.on_tpu() else (1_024 if QUICK else 4_096)
    ktag = "" if (S, ns) == (64, 262_144) else f"@{S}x{ns}"
    ts = [np.sort(rng.uniform(0, 86_400.0, ns)) for _ in range(S)]
    ranges = [TIME_RANGES[i % len(TIME_RANGES)] for i in range(S)]
    mults = [86_400.0 / mr for mr in ranges]

    def _padded():
        _, keep, _ = ops.stream_sample_batched(ts, ranges, mults)
        return int(np.asarray(keep).sum())

    def _grouped():
        kept = 0
        for mr in sorted(set(ranges)):
            rows = [i for i, r in enumerate(ranges) if r == mr]
            _, keep, _ = ops.stream_sample_batched(
                [ts[i] for i in rows], mr, [mults[i] for i in rows])
            kept += int(np.asarray(keep).sum())
        return kept

    got_p, dt_p = _tmin(_padded, reps=reps)
    got_g, dt_g = _tmin(_grouped, reps=reps)
    assert got_p == got_g
    csv.append(
        f"PR4/nsa_range_padded_64x256k{ktag},{dt_p*1e6:.0f},"
        f"shape={S}x{ns};ranges={len(set(ranges))};dispatches=1;"
        f"grouped_{len(set(ranges))}_dispatches_us={dt_g*1e6:.0f}")

    # --- replay: one merged loop + concurrent drains vs 18 sequential -----
    # host-side (no Pallas leg), so it affords a larger stream than the
    # interpret-mode NSA rows: per-bucket transport work has to dominate
    # thread bookkeeping for the loop structure to be measurable
    if ops.on_tpu():
        rscale, rtag = scale, tag
    else:
        rs = 0.002 if QUICK else 0.005
        rscale = {k: rs for k in scale}
        rtag = f"@scale{rs}"
    rstreams = {name: preprocess(make_stream(name, scale=sc, seed=0))
                for name, sc in rscale.items()}
    sims = nsa_sweep(rstreams, TIME_RANGES, backend="numpy")
    got_m, dt_m = _tmin(lambda: _replay_multi(sims), reps=reps)
    got_s, dt_s = _tmin(lambda: _replay_sequential(sims), reps=reps)
    assert got_m == got_s
    csv.append(
        f"PR4/producer_multiqueue_replay{rtag},{dt_m*1e6:.0f},"
        f"scenarios={len(sims)};loops=1;"
        f"sequential_{len(sims)}_loops_us={dt_s*1e6:.0f}")
