"""Kernel micro-benchmarks: Pallas ops (interpret mode on CPU — correctness
path; TPU is the performance target) vs their jnp oracles, plus the fused
end-to-end NSA device path vs host numpy.
"""

from __future__ import annotations

import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


def _t(fn, *args, reps=5):
    fn(*args)  # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)
    return (time.perf_counter() - t0) / reps


def _tmin(fn, reps=3):
    """Min-of-reps wall time (robust to scheduler noise)."""
    fn()  # compile / warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
        best = min(best, time.perf_counter() - t0)
    return best


def run(csv: List[str]) -> None:
    rng = np.random.default_rng(0)

    # stream_sample: 1M records into 600 buckets (quick mode runs a reduced
    # record count; the name suffix records the executed shape so trend
    # tooling never compares incommensurable sizes)
    n, mr = (65_536, 600) if QUICK else (1_000_000, 600)
    tag = "" if n == 1_000_000 else f"@{n}"
    t = np.sort(rng.uniform(0, 86_400, n))
    mult = 86_400 / mr
    dt_k = _t(lambda: ops.stream_sample(t, mr, mult))
    dt_o = _t(lambda: ops.stream_sample_ref(t, mr, mult))
    csv.append(f"kernels/stream_sample_1M{tag},{dt_k*1e6:.0f},"
               f"oracle_us={dt_o*1e6:.0f}")

    # mask compaction: 1M-record keep mask -> kept indices, one device pass
    mask = rng.random(n) < (1.0 / mult)
    dt_k = _t(lambda: ops.compact_mask(mask), reps=3)
    dt_o = _t(lambda: np.flatnonzero(mask), reps=3)
    csv.append(f"kernels/compact_1M{tag},{dt_k*1e6:.0f},"
               f"host_np_us={dt_o*1e6:.0f}")

    # batched NSA: 64 concurrent device streams, one 2-D-grid dispatch vs
    # 64 sequential single-stream dispatches. Full 64x256k on TPU; the
    # interpret-mode CPU path runs a reduced per-stream length (the grid is
    # interpreted step-by-step) — the derived column records the real shape.
    S = 8 if QUICK else 64
    ns = 262_144 if ops.on_tpu() else (1_024 if QUICK else 4_096)
    ts = [np.sort(rng.uniform(0, 86_400, ns)) for _ in range(S)]
    dt_b = _t(lambda: ops.stream_sample_batched(ts, mr, mult), reps=1)

    def _looped():
        outs = [ops.stream_sample(t_s, mr, mult) for t_s in ts]
        return outs[-1]

    dt_l = _t(_looped, reps=1)
    # canonical row name is the TPU shape; off-TPU runs append the actual
    # executed shape so trend tooling never compares incommensurable sizes
    row = "kernels/batched_nsa_64x256k" if (S, ns) == (64, 262_144) \
        else f"kernels/batched_nsa_64x256k@{S}x{ns}"
    csv.append(f"{row},{dt_b*1e6:.0f},"
               f"shape={S}x{ns};dispatches=1;"
               f"looped_{S}_dispatches_us={dt_l*1e6:.0f}")

    # fused metrics engine: histogram + moments in one record pass
    ss = np.sort(rng.integers(0, mr, n)).astype(np.int32)
    dt_k = _t(lambda: ops.stream_metrics(ss, mr))
    dt_o = _t(lambda: ref.stream_metrics_ref(jnp.asarray(ss)[None, :], mr))
    csv.append(f"kernels/metrics_fused_1M{tag},{dt_k*1e6:.0f},"
               f"oracle_us={dt_o*1e6:.0f}")

    # ...and a full-day bucket axis (86 400 simulated seconds block-tiled
    # through VMEM — the seed one-hot kernel could not express this shape)
    nd = n // 4
    ssd = np.sort(rng.integers(0, 86_400, nd)).astype(np.int32)
    dt_k = _t(lambda: ops.stream_metrics(ssd, 86_400), reps=2)
    csv.append(f"kernels/metrics_fused_day_axis@{nd},{dt_k*1e6:.0f},"
               f"buckets=86400")

    # batched metrics: S streams' histograms + moments, one 2-D dispatch
    sss = [np.sort(rng.integers(0, mr, ns)).astype(np.int32)
           for _ in range(S)]
    dt_b = _t(lambda: ops.stream_metrics_batched(sss, mr), reps=1)
    dt_l = _t(lambda: [ops.stream_metrics(x, mr) for x in sss], reps=1)
    csv.append(f"kernels/metrics_fused_batched@{S}x{ns},{dt_b*1e6:.0f},"
               f"dispatches=1;looped_{S}_dispatches_us={dt_l*1e6:.0f}")

    # device trend path (prefix-sum scan kernel + window gathers) vs the
    # PR 2 host cumsum sliding mean, day-long count series at window=600
    from repro.streamsim.metrics import (sliding_mean,
                                         trend_correlation_from_counts)
    nt = 8_640 if QUICK else 86_400
    ttag = "" if nt == 86_400 else f"@{nt}"
    day = rng.poisson(25.0, nt).astype(np.int64)
    dt_k = _tmin(lambda: ops.trend_scan(day, 600))
    dt_h = _tmin(lambda: sliding_mean(day.astype(np.float64), 600))
    csv.append(f"kernels/trend_scan_86400_w600{ttag},{dt_k*1e6:.0f},"
               f"host_cumsum_us={dt_h*1e6:.0f}")

    # S×S correlation engine: full Pearson matrix from one scan + one Gram
    # dispatch vs the per-pair host loop (S·(S-1)/2 pairwise calls)
    Sc, nc = (8, 600) if QUICK else (64, 3_600)
    ctag = "" if (Sc, nc) == (64, 3_600) else f"@{Sc}x{nc}"
    qs = [rng.poisson(25.0, nc).astype(np.int64) for _ in range(Sc)]
    dt_k = _tmin(lambda: ops.trend_correlation_batched(qs, 60), reps=2)

    def _pairwise_host():
        return [trend_correlation_from_counts(qs[a], qs[b], 60)
                for a in range(Sc) for b in range(a + 1, Sc)]

    dt_h = _tmin(_pairwise_host, reps=2)
    csv.append(f"kernels/corr_matrix_64x64{ctag},{dt_k*1e6:.0f},"
               f"shape={Sc}x{nc};dispatches=2;"
               f"pairwise_host_{Sc*(Sc-1)//2}_pairs_us={dt_h*1e6:.0f}")

    # volatility moments over a day of per-second counts
    q = rng.poisson(25.0, 86_400).astype(np.float32)
    dt_k = _t(lambda: ops.volatility_stats(q))
    csv.append(f"kernels/volatility_86400,{dt_k*1e6:.0f},")

    # flash decode: 8 x 32 heads x 128 over 4k cache
    b, h, kh, d, s = 8, 32, 8, 128, 4096
    key = jax.random.PRNGKey(0)
    q_ = jax.random.normal(key, (b, h, d), jnp.float32)
    k_ = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, d))
    v_ = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, d))
    lens = jnp.full((b,), s, jnp.int32)
    dt_k = _t(lambda: ops.flash_decode(q_, k_, v_, lens, block_s=512), reps=2)
    dt_o = _t(lambda: ref.flash_decode_ref(q_, k_, v_, lens), reps=2)
    csv.append(f"kernels/flash_decode_8x32x4k,{dt_k*1e6:.0f},"
               f"oracle_us={dt_o*1e6:.0f}")
