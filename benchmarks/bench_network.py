"""Paper Fig. 6: bytes transmitted into the stream-processing system per
time range — the simulated stream must show the original's trend/volatility
on the wire. We run the PSDA producer into the StreamQueue (the Kafka
analogue) and report transported bytes + trend correlation vs the original.
"""

from __future__ import annotations

import os
import threading
import time
from typing import List

import numpy as np

from repro.streamsim import (
    Producer,
    StreamQueue,
    VirtualClock,
    make_stream,
    nsa,
    preprocess,
)
from repro.streamsim.metrics import trend_correlation

TIME_RANGES = (600, 1200, 1800, 2400, 3000, 3600)
_SCALE = 0.005 if bool(int(os.environ.get("BENCH_QUICK", "0"))) else 0.1


def run(csv: List[str]) -> None:
    s = preprocess(make_stream("userbehavior", scale=_SCALE, seed=0))
    for mr in TIME_RANGES:
        sim = nsa(s, mr)
        q = StreamQueue(maxsize=4096)
        prod = Producer(sim, q, clock=VirtualClock())
        per_second_bytes = np.zeros(mr)

        def consume():
            for b in q:
                per_second_bytes[b.scale_stamp] += b.nbytes()

        t0 = time.perf_counter()
        th = threading.Thread(target=consume)
        th.start()
        status = prod.run()
        th.join()
        dt = time.perf_counter() - t0
        assert status == 0
        corr = trend_correlation(s, sim, window_s=60)
        csv.append(
            f"network/userbehavior/max{mr},{dt*1e6:.0f},"
            f"bytes={int(per_second_bytes.sum())};"
            f"mean_Bps={per_second_bytes.mean():.0f};"
            f"peak_Bps={per_second_bytes.max():.0f};trend_corr={corr:.3f}")
