"""PR 9 trajectory rows: sweep-service failover recovery + service overhead.

Two rows quantify what the lease-based sweep service costs (marker I/O,
when nothing dies) and buys (skipped work, when a worker does die):

- ``service_failover_recovery`` — a 3-dataset × 4-time-range sweep (12
  scenarios) whose service namespace already carries the state a
  kill-one-of-two-workers crash leaves behind: 8 scenarios' results
  published, ONE scenario held by an expired lease (the dead worker's),
  3 still queued. NEW: a surviving participant reaps the dead lease,
  requeues it, executes only the 4 outstanding scenarios, and merges.
  OLD (the path it replaces): the same sweep restarted from zero — all
  12 scenarios re-replayed. Recovery does a strict subset of the
  restart's replay/report work plus O(grid) marker I/O, so the row is
  gated ≤ 1.0× by ``check_regression.py``. Rebuilding the crash scene
  between reps is test scaffolding, not recovery work, and stays
  outside the timed region.

- ``service_overhead`` — the full 12-scenario sweep through
  ``run_many(service=True)`` with ``lease_batch`` covering the whole
  grid (one election, one claim pass, one engine run — the direct-like
  shape) vs the direct ``run_many`` path. The delta is pure service
  machinery: the publisher election, queue/lease/result/fidelity marker
  round-trips, the heartbeat thread, and the count-row merge. Gated
  ≤ 1.15× — the service must stay a thin coat of paint on the engine,
  not a second engine.

Both rows run at reduced scale off-TPU and carry the usual ``@`` suffix
so trend tooling never mixes incommensurable sizes.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List

from repro.kernels import ops
from repro.streamsim.controller import Controller
from repro.streamsim.resilience import Lease
from repro.streamsim.service import SweepService, scenario_marker

DATASETS = ("sogouq", "traffic", "userbehavior")
QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


def _tmin(fn, reps=3):
    """(result, min-of-reps seconds) — min is robust to scheduler noise."""
    out, best = fn(), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        best = min(best, time.perf_counter() - t0)
        assert r == out, "non-deterministic benchmark result"
    return out, best


def _tmin_pair(fn_a, fn_b, reps=3):
    """((result_a, min_a), (result_b, min_b)) with a/b timed alternately
    rep by rep — drifting machine load hits both legs equally instead of
    landing entirely on whichever leg happened to run in the slow window.
    For ratio-gated rows this is what keeps the comparison fair."""
    out_a, out_b = fn_a(), fn_b()
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        assert r == out_a, "non-deterministic benchmark result"
        t0 = time.perf_counter()
        r = fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
        assert r == out_b, "non-deterministic benchmark result"
    return (out_a, best_a), (out_b, best_b)


def _tmin_staged(setup, fn, reps=3):
    """_tmin with an untimed setup() before every timed fn(state) — keeps
    scaffolding that rebuilds the measured path's precondition (e.g. the
    crash-scene markers) out of the measurement."""
    out, best = fn(setup()), float("inf")
    for _ in range(reps):
        state = setup()
        t0 = time.perf_counter()
        r = fn(state)
        best = min(best, time.perf_counter() - t0)
        assert r == out, "non-deterministic benchmark result"
    return out, best


def _consumer(queue):
    return {"records_seen": sum(len(b) for b in queue)}


def run(csv: List[str]) -> None:
    if ops.on_tpu():
        scale, tag = 0.05, ""
    else:
        scale = 0.002 if QUICK else 0.004
        tag = f"@scale{scale}"
    ranges = (15, 30, 45, 60)
    datasets = list(DATASETS)
    reps = 2 if QUICK else 4
    seed = 9
    k = 8                    # results published before the worker died
    grid = [(d, mr) for d in datasets for mr in ranges]

    tmp = tempfile.mkdtemp(prefix="bench_pr9_")
    try:
        ctrl = Controller(os.path.join(tmp, "store"))
        store = ctrl.store
        originals = {d: ctrl.prepare(d, scale=scale, seed=seed)
                     for d in datasets}

        # seed run: warms the NSA cache (every timed path below sees
        # identical cache hits) and yields the result/fidelity marker
        # payloads a killed run would have published before dying
        seed_svc = SweepService(store, datasets, ranges, scale=scale,
                                seed=seed, lease_ttl_s=120.0,
                                poll_s=0.01, lease_batch=len(grid),
                                worker_id="seed-run")
        seed_svc.work(originals, _consumer)
        results = {n: store.get_marker(seed_svc.ns_results, n)
                   for n in store.list_markers(seed_svc.ns_results)}
        fid_rows = {n: store.get_marker(seed_svc.ns_fidelity, n)
                    for n in store.list_markers(seed_svc.ns_fidelity)}
        store.clear_markers(seed_svc.group)
        names = [scenario_marker(d, mr) for d, mr in grid]

        def _svc(worker):
            return SweepService(store, datasets, ranges, scale=scale,
                                seed=seed, lease_ttl_s=120.0, poll_s=0.01,
                                lease_batch=len(grid), worker_id=worker)

        # --- recover-from-kill vs restart-from-zero ----------------------
        def _crash_scene():
            # recreate the killed 2-worker sweep's marker state (the
            # finalize step clears the namespace, so each rep starts
            # from the identical crash scene); untimed — on a real
            # failover the scene already exists on disk
            svc = _svc("survivor")
            svc.publish_queue()
            for n in names[:k]:
                store.remove_marker(svc.ns_queue, n)
                store.put_marker(svc.ns_results, n, results[n])
            for n, payload in fid_rows.items():
                if n.startswith("orig__") or \
                        n.split("sim__", 1)[-1] in names[:k]:
                    store.put_marker(svc.ns_fidelity, n, payload)
            dead_name, (dd, dmr) = names[k], grid[k]
            store.claim_marker(svc.ns_queue, dead_name,
                               svc.ns_leases, dead_name)
            store.put_marker(svc.ns_leases, dead_name, Lease(
                worker="killed-worker", dataset=dd, max_range=dmr,
                ttl_s=1.0, deadline=time.time() - 1.0,
                attempts=1).to_json())
            return svc

        def _recover(svc):
            svc.work(originals, _consumer)
            reports, fidelity, _ = svc.finalize()
            assert len(fidelity) == len(ranges)
            return sum(r.consumer_metrics["records_seen"]
                       for r in reports)

        def _restart_from_zero():
            svc = _svc("restarter")
            svc.work(originals, _consumer)
            reports, fidelity, _ = svc.finalize()
            assert len(fidelity) == len(ranges)
            return sum(r.consumer_metrics["records_seen"]
                       for r in reports)

        got_new, dt_new = _tmin_staged(_crash_scene, _recover, reps=reps)
        got_old, dt_old = _tmin(_restart_from_zero, reps=reps)
        assert got_new == got_old, "recovered and restarted sweeps must " \
            f"deliver identical record totals ({got_new} vs {got_old})"
        csv.append(
            f"PR9/service_failover_recovery{tag},{dt_new*1e6:.0f},"
            f"scenarios={len(grid)};recovered_from={k};"
            f"restart_from_zero_us={dt_old*1e6:.0f};"
            f"speedup={dt_old/max(dt_new, 1e-9):.1f}x")

        # --- service machinery vs direct run_many ------------------------
        # the service's fixed cost is O(grid) marker round-trips, so this
        # row runs at a scale where the sweep itself is the dominant term
        # (the regime services exist for); a fresh store keeps the larger
        # originals out of the failover row's cache
        o_scale = scale if ops.on_tpu() else 0.5
        o_tag = "" if ops.on_tpu() else f"@scale{o_scale}"
        ctrl2 = Controller(os.path.join(tmp, "store_overhead"))
        for d in datasets:
            ctrl2.prepare(d, scale=o_scale, seed=seed)

        def _service_mode():
            out = ctrl2.run_many(datasets, ranges, _consumer,
                                 scale=o_scale, seed=seed, service=True,
                                 lease_ttl_s=120.0, service_poll_s=0.01,
                                 lease_batch=len(grid))
            return sum(r.consumer_metrics["records_seen"] for r in out)

        def _direct():
            out = ctrl2.run_many(datasets, ranges, _consumer,
                                 scale=o_scale, seed=seed)
            return sum(r.consumer_metrics["records_seen"] for r in out)

        # gate margin is ~8%, so: a few extra reps even in quick mode AND
        # the two legs timed alternately — one cold rep or one slow window
        # must not decide the row
        oreps = max(reps, 4)
        (got_svc, dt_svc), (got_dir, dt_dir) = _tmin_pair(
            _service_mode, _direct, reps=oreps)
        assert got_svc == got_dir, "service and direct sweeps must " \
            f"deliver identical record totals ({got_svc} vs {got_dir})"
        csv.append(
            f"PR9/service_overhead{o_tag},{dt_svc*1e6:.0f},"
            f"scenarios={len(grid)};direct_run_many_us={dt_dir*1e6:.0f};"
            f"overhead={dt_svc/max(dt_dir, 1e-9):.2f}x")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
