"""PR 5 trajectory rows: sweep planner shards + device-resident reporting.

Two rows quantify what the plan/engine split buys over the PR 4
single-device composition:

- ``sweep_sharded_4dev_8x6`` — an 8-stream × 6-time-range grid (48
  scenarios) with heterogeneous stream sizes, the planner's target shape.
  NEW: ``plan_sweep`` partitions the grid into 4 size-grouped,
  cost-balanced shards and the engine runs each shard's
  normalize→sample→compact→metrics chain as one dispatch per stage,
  followed by the single ``materialize()`` host pass. OLD (the PR 4
  path): ONE monolithic ``nsa_sweep`` launch padded to the global maximum
  row length + the host-input batched metrics dispatch over the gathered
  scale stamps. The planner wins on *padded area*: a monolithic launch
  pads every row to the longest stream's tile count, while size-grouped
  shards pad only to their own maximum — less kernel work on real
  hardware, fewer interpret-mode grid steps on CPU. Gated by
  ``check_regression.py`` (the sharded path must never lose to the
  monolith it replaces).

- ``device_resident_report_64`` — 64 scenarios' report statistics
  (per-second histograms + volatility moments + per-scenario
  original↔simulated trend correlation). NEW: the fused metrics engine
  consumes the NSA chain's device-resident kept stamps directly
  (``stream_metrics_batched_device``) and ALL pairwise trend correlations
  come from one fused XLA chain (``trend_corr_pairwise``). OLD (PR 4):
  gather kept stamps to host, re-stack them into the host-input metrics
  dispatch, download the histograms, then run the per-scenario host
  sliding-mean/resample/Pearson loop. Also gated.

All rows are min-of-reps; reduced scales carry an ``@`` suffix so trend
tooling never mixes incommensurable sizes. Full scale is the TPU target —
off-TPU the Pallas legs run in interpret mode on both sides of each
comparison, so the structural difference (padded area, host round-trips,
per-scenario loops) is what the ratio measures.
"""

from __future__ import annotations

import os
import time
from typing import List

import numpy as np

from repro.kernels import ops
from repro.streamsim import make_stream, plan_sweep, preprocess
from repro.streamsim import engine as sweep_engine
from repro.streamsim.metrics import (per_second_counts,
                                     trend_correlation_from_counts)
from repro.streamsim.nsa import nsa_sweep

TIME_RANGES = (600, 1200, 1800, 2400, 3000, 3600)
QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


class _NoStore:
    """Planner/engine store stub: nothing cached, nothing persisted."""

    def exists(self, key) -> bool:
        return False

    def __bool__(self) -> bool:
        return False


def _tmin(fn, reps=3):
    """(result, min-of-reps seconds) — min is robust to scheduler noise."""
    out, best = fn(), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        best = min(best, time.perf_counter() - t0)
        assert r == out, "non-deterministic benchmark result"
    return out, best


def _hetero_streams(n, base_scale, seed=3):
    """n streams with ~8x record-count spread — the planner's target
    shape (a monolithic launch pads everything to the biggest)."""
    names = ("sogouq", "traffic", "userbehavior")
    out = {}
    for i in range(n):
        sc = base_scale * (1 + (i % 4)) * (2 if i >= n // 2 else 1)
        s = preprocess(make_stream(names[i % 3], scale=sc, seed=seed + i))
        s.name = f"s{i}"
        out[f"s{i}"] = s
    return out


def run(csv: List[str]) -> None:
    if ops.on_tpu():
        base, tag = 0.05, ""
    else:
        base = 0.0001 if QUICK else 0.0002
        tag = f"@scale{base}"
    streams = _hetero_streams(8, base)
    reps = 2 if QUICK else 5
    row_counts = {k: len(v) for k, v in streams.items()}
    store = _NoStore()
    w_max = max(TIME_RANGES)

    # --- sharded plan/engine vs the PR 4 monolithic single dispatch -------
    def _sharded():
        plan = plan_sweep(store, list(streams), TIME_RANGES, row_counts,
                          n_devices=4, host_index=0, n_hosts=1)
        result = sweep_engine.execute_sweep(plan, streams, store,
                                            backend="pallas")
        sims = result.materialize(store=False)
        return sum(len(s) for s in sims.values())

    def _pr4_monolith():
        sims = nsa_sweep(streams, TIME_RANGES, backend="pallas")
        stamps = [sims[(n, mr)].scale_stamp
                  for n in streams for mr in TIME_RANGES]
        hist, _, _ = ops.stream_metrics_batched(stamps, w_max)
        hist.block_until_ready()
        return sum(len(s) for s in sims.values())

    got_new, dt_new = _tmin(_sharded, reps=reps)
    got_old, dt_old = _tmin(_pr4_monolith, reps=reps)
    assert got_new == got_old, "sharded and monolithic sweeps must " \
        f"produce identical simulated row totals ({got_new} vs {got_old})"
    plan = plan_sweep(store, list(streams), TIME_RANGES, row_counts,
                      n_devices=4, host_index=0, n_hosts=1)
    csv.append(
        f"PR5/sweep_sharded_4dev_8x6{tag},{dt_new*1e6:.0f},"
        f"scenarios=48;shards={len(plan.shards)};"
        f"padded_area={plan.padded_area()};"
        f"monolithic_area={plan.monolithic_area()};"
        f"pr4_single_dispatch_us={dt_old*1e6:.0f};"
        f"speedup={dt_old/max(dt_new, 1e-9):.1f}x")

    # --- device-resident report stats vs the PR 4 host-gather path -------
    # 64 scenarios as ONE engine shard: kept stamps stay on device
    import jax.numpy as jnp

    from repro.streamsim.nsa import nsa_sweep_device

    r_ranges = tuple(int(t) for t in np.linspace(75, 600, 8))
    r_streams = _hetero_streams(8, base * 2, seed=11)
    r_names = list(r_streams)
    r_pairs = [(n, mr) for n in r_streams for mr in r_ranges]
    ss_kept, _, totals, _ = nsa_sweep_device(r_streams, r_pairs)
    # compaction packs kept stamps to the front: the metrics dispatch (one
    # per path variant, identical shape — run in setup) reads only the
    # kept-width column slice, exactly as the engine does
    n_kept = int(-(-max(int(totals.max(initial=1)), 1)
                   // ops.TILE) * ops.TILE)
    ss_kept = ss_kept[:, :min(n_kept, ss_kept.shape[1])]
    r_w = max(r_ranges)
    hist, mom = ops.stream_metrics_batched_device(ss_kept, totals, r_w)
    hist.block_until_ready()
    lb = np.array([mr for _, mr in r_pairs], np.int64)
    om_counts = {n: per_second_counts(s) for n, s in r_streams.items()}
    la_u = np.array([len(om_counts[n]) for n in r_names], np.int64)
    a_index = np.array([r_names.index(n) for n, _ in r_pairs])
    qa_mat = np.zeros((len(r_names), int(la_u.max())), np.int32)
    for i, n in enumerate(r_names):
        qa_mat[i, :len(om_counts[n])] = om_counts[n]
    qa_dev = jnp.asarray(qa_mat)

    def _device_resident():
        # counts stay device-resident: one fused chain computes every
        # pair's trend correlation (each original's trend ONCE), and only
        # O(S) scalars ([Σq, Σq²] moments, P correlations) reach host
        corrs = ops.trend_corr_pairwise(qa_dev, la_u, hist, lb, 60,
                                        a_index=a_index)
        m = np.asarray(mom)
        return round(float(np.nansum(corrs) + m[:, 0].sum()), 3)

    def _pr4_host_gather():
        # the PR 4 report stage: histogram matrix gathered to host, then
        # the per-scenario sliding-mean/resample/Pearson loop (the
        # original's full-length trend recomputed for every scenario)
        counts = np.asarray(hist)
        corrs = [trend_correlation_from_counts(
            om_counts[n], counts[i, :mr])
            for i, (n, mr) in enumerate(r_pairs)]
        m = np.asarray(mom)
        return round(float(np.nansum(corrs) + m[:, 0].sum()), 3)

    got_d, dt_d = _tmin(_device_resident, reps=reps)
    got_h, dt_h = _tmin(_pr4_host_gather, reps=reps)
    assert abs(got_d - got_h) <= max(2e-3 * abs(got_h), 0.5), \
        f"report statistics diverged across paths ({got_d} vs {got_h})"
    csv.append(
        f"PR5/device_resident_report_64{tag},{dt_d*1e6:.0f},"
        f"scenarios={len(r_pairs)};"
        f"host_gather_path_us={dt_h*1e6:.0f};"
        f"speedup={dt_h/max(dt_d, 1e-9):.1f}x")
