"""PR 8 trajectory rows: the stream-task tier's paper-claim comparison.

The paper's validation (§6) runs a stream task against the original
day-long stream AND the NSA-compressed simulated stream, claiming the
simulated run is >= 24x faster while the task sees the same volatility
and trends. :class:`~repro.streamsim.taskbench.TaskBenchRunner` is that
experiment as code; these rows are its CI record — one row per task in
the RIoTBench-style suite, each gated by ``check_regression.py`` against
``original_replay_us`` (the original-replay leg it replaces):

- ``PR8/task_etl``, ``PR8/task_windowed_stats``,
  ``PR8/task_event_detect`` — the bucket tasks over the sliced sogouq
  morning (QUICK / off-TPU) or the full synthetic day (TPU), gated at
  >= 4x (observed 30-60x; the paper's 24x needs the full-day span, so
  the CI gate is deliberately conservative at reduced spans) and
  hard-checked here against ``FIDELITY_FLOOR``: a row whose task-output
  trend correlation between the two replays falls below the documented
  floor FAILS the benchmark run itself — the fidelity half of the claim
  is a gate, not a footnote.
- ``PR8/task_serving`` — the serving engine load-tested by the diurnal
  userbehavior arrival mix (the million-user trace at reduced scale),
  ``reuse_engine=True`` so decode traces stay warm across legs, with an
  explicit warmup call so neither timed leg pays compilation. Gated at
  >= 2x (observed ~15x). Its fidelity is recorded but NOT floor-checked:
  the admission cap (``max_requests_per_bucket``) intentionally
  saturates the output series under load, which is the load-test point.

Every row records ``paper_ratio=24`` (the headline figure), the measured
``speedup``, ``fidelity``, both volatility digests, and the
p50/p99/p999 latency summarized from the device-resident histogram path
(ONE fused ``stream_metrics_batched`` dispatch per task sweep).
"""

from __future__ import annotations

import os
from typing import List

from repro.kernels import ops
from repro.streamsim import (
    ETLTask,
    EventDetectTask,
    FIDELITY_FLOOR,
    PAPER_SPEEDUP,
    ServingTask,
    TaskBenchRunner,
    WindowedStatsTask,
)
from repro.streamsim.queue import Bucket, StreamQueue

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


def _row(csv: List[str], rep, tag: str) -> None:
    lat = rep.latency
    csv.append(
        f"PR8/task_{rep.task.replace('-', '_')}{tag},"
        f"{rep.t_simulated_s * 1e6:.0f},"
        f"original_replay_us={rep.t_original_s * 1e6:.0f};"
        f"speedup={rep.speedup:.1f}x;paper_ratio={rep.paper_ratio:.0f};"
        f"fidelity={rep.trend_fidelity:.3f};"
        f"cv_orig={rep.cv_original:.3f};cv_sim={rep.cv_simulated:.3f};"
        f"dataset={rep.dataset};max_range={rep.max_range};"
        f"records_sim={rep.records_simulated};"
        f"p50_us={lat['p50_us']:.1f};p99_us={lat['p99_us']:.1f};"
        f"p999_us={lat['p999_us']:.1f};jitter_us={lat['jitter_us']:.1f}")


def _serving_task():
    """Tiny consumer-LM serving task (CPU-sized; shapes are static so one
    warmup call compiles prefill + decode for every later leg)."""
    import jax
    import numpy as np
    from repro.configs.paper_stream import consumer_lm
    from repro.models import transformer as T

    cfg = consumer_lm().replace(n_layers=2, d_model=64, n_heads=4,
                                n_kv_heads=2, head_dim=16, d_ff=128,
                                vocab_size=512, loss_chunk=16)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    task = ServingTask(cfg, params, slots=4, max_len=48, prompt_len=4,
                       max_new_tokens=3, max_requests_per_bucket=1,
                       reuse_engine=True)
    warm = StreamQueue(maxsize=4)
    for s in range(2):
        warm.put(Bucket(scale_stamp=s, t=np.zeros(1),
                        payload={"x": np.zeros(1)}, emit_time=0.0))
    warm.close()
    task(warm)
    return task


def run(csv: List[str]) -> None:
    # --- bucket tasks: the paper comparison on the sogouq diurnal ramp ---
    if ops.on_tpu() and not QUICK:
        span_s, tag = None, ""            # full synthetic day
    else:
        span_s, tag = 7200, "@2h"         # morning ramp: fast AND diurnal
    runner = TaskBenchRunner(["sogouq"], [100], scale=0.3, seed=0,
                             span_s=span_s)
    reports = runner.run([
        ETLTask(),
        WindowedStatsTask(window_s=30),
        EventDetectTask(mode="threshold", threshold=4.0),
    ])
    for rep in reports:
        if rep.trend_fidelity < FIDELITY_FLOOR:
            raise RuntimeError(
                f"task {rep.task!r} trend fidelity {rep.trend_fidelity:.3f}"
                f" fell below the documented floor {FIDELITY_FLOOR} "
                f"(dataset={rep.dataset}, max_range={rep.max_range}) — "
                "the equivalence half of the paper claim regressed")
        assert rep.paper_ratio == PAPER_SPEEDUP
        _row(csv, rep, tag)

    # --- serving task: diurnal million-user arrival mix, warm engine -----
    sruns = TaskBenchRunner(["userbehavior"], [60], scale=0.02, seed=0,
                            span_s=900).run([_serving_task()])
    _row(csv, sruns[0], "@ub900s")
