"""PR 6 trajectory rows: checkpointed resume + chaos-layer noop cost.

Two rows quantify what the robustness layer costs (nothing, when off)
and buys (skipped work, when a killed sweep resumes):

- ``sweep_resume_3x4_k8`` — a 3-dataset × 4-time-range sweep (12
  scenarios) whose checkpoint namespace already carries report markers
  for 8 completed scenarios, exactly the state a sweep killed after 8
  scenarios leaves behind. NEW: ``Controller.run_many(checkpoint=True)``
  loads the 8 finished reports straight from their markers and
  re-plans/replays only the remaining 4 scenarios. OLD (the path it
  replaces): the same killed sweep restarted from zero — every scenario
  re-replayed, every report re-assembled. The win is deterministic
  (resume does a strict subset of the rerun's replay/report work, plus
  O(k) marker reads), so the row is gated by ``check_regression.py``.

- ``chaos_noop_replay_12`` — the same 12 scenarios through
  ``replay_many`` with a seeded all-noop :class:`FaultPlan` attached vs
  no plan at all. The fault hooks short-circuit on a noop spec (the
  delivered stream is bit-identical — tested in tests/test_faults.py),
  so this row documents the measured overhead of carrying the chaos
  layer disabled. Informative, not gated: the two paths are near-equal
  by design and a strict ≤ gate would flake on scheduler noise.

Both rows run at reduced scale off-TPU and carry the usual ``@`` suffix
so trend tooling never mixes incommensurable sizes.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List

from repro.kernels import ops
from repro.streamsim import FaultPlan, plan_sweep
from repro.streamsim.controller import Controller
from repro.streamsim.engine import replay_many
from repro.streamsim.resilience import SweepCheckpoint

DATASETS = ("sogouq", "traffic", "userbehavior")
QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


def _tmin(fn, reps=3):
    """(result, min-of-reps seconds) — min is robust to scheduler noise."""
    out, best = fn(), float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn()
        best = min(best, time.perf_counter() - t0)
        assert r == out, "non-deterministic benchmark result"
    return out, best


def _consumer(queue):
    return {"records_seen": sum(len(b) for b in queue)}


def run(csv: List[str]) -> None:
    if ops.on_tpu():
        scale, tag = 0.05, ""
    else:
        scale = 0.002 if QUICK else 0.004
        tag = f"@scale{scale}"
    ranges = (15, 30, 45, 60)
    datasets = list(DATASETS)
    reps = 2 if QUICK else 4
    seed = 9
    k = 8                              # scenarios "completed" before the kill
    grid = [(d, mr) for d in datasets for mr in ranges]

    tmp = tempfile.mkdtemp(prefix="bench_pr6_")
    try:
        ctrl = Controller(os.path.join(tmp, "store"))
        # setup sweep: warms the store's NSA cache (both timed paths see
        # identical cache hits) and yields the reports a killed run would
        # have checkpointed before dying
        setup_reports = ctrl.run_many(datasets, ranges, _consumer,
                                      scale=scale, seed=seed)
        row_counts = {d: len(ctrl.prepare(d, scale=scale, seed=seed))
                      for d in datasets}
        plan = plan_sweep(ctrl.store, datasets, ranges, row_counts,
                          scale=scale, seed=seed, n_devices=1,
                          host_index=0, n_hosts=1)

        # --- resume-from-k vs restart-from-zero --------------------------
        def _resumed():
            # recreate the killed sweep's marker state (run_many clears
            # the namespace on completion, so each rep starts identical)
            ckpt = SweepCheckpoint(ctrl.store, plan.sweep_id)
            for r in setup_reports[:k]:
                ckpt.mark_report(r)
            out = ctrl.run_many(datasets, ranges, _consumer, scale=scale,
                                seed=seed, checkpoint=True)
            return sum(r.consumer_metrics["records_seen"] for r in out)

        def _restart_from_zero():
            out = ctrl.run_many(datasets, ranges, _consumer, scale=scale,
                                seed=seed)
            return sum(r.consumer_metrics["records_seen"] for r in out)

        got_new, dt_new = _tmin(_resumed, reps=reps)
        got_old, dt_old = _tmin(_restart_from_zero, reps=reps)
        assert got_new == got_old, "resumed and restarted sweeps must " \
            f"deliver identical record totals ({got_new} vs {got_old})"
        csv.append(
            f"PR6/sweep_resume_3x4_k8{tag},{dt_new*1e6:.0f},"
            f"scenarios={len(grid)};resumed_from={k};"
            f"restart_from_zero_us={dt_old*1e6:.0f};"
            f"speedup={dt_old/max(dt_new, 1e-9):.1f}x")

        # --- noop chaos layer vs no chaos layer --------------------------
        sims = {(d, mr): ctrl.simulate(d, mr, scale=scale, seed=seed)
                for d, mr in grid}

        def _noop_plan():
            metrics, _ = replay_many(sims, _consumer, 64,
                                     fault_plan=FaultPlan(seed=13))
            return sum(m["records_seen"] for m in metrics.values())

        def _no_plan():
            metrics, _ = replay_many(sims, _consumer, 64)
            return sum(m["records_seen"] for m in metrics.values())

        got_noop, dt_noop = _tmin(_noop_plan, reps=reps)
        got_plain, dt_plain = _tmin(_no_plan, reps=reps)
        assert got_noop == got_plain, "a noop fault plan must deliver " \
            f"bit-identical streams ({got_noop} vs {got_plain})"
        csv.append(
            f"PR6/chaos_noop_replay_12{tag},{dt_noop*1e6:.0f},"
            f"scenarios={len(grid)};no_plan_path_us={dt_plain*1e6:.0f};"
            f"overhead={dt_noop/max(dt_plain, 1e-9):.2f}x")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
