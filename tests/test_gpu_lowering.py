"""GPU (Triton) lowering validation for the scan/accumulate kernels.

The TPU kernels' sequential-grid accumulators cannot compile on GPU, so
:mod:`repro.kernels.gpu_lowering` restructures them row-parallel. These
tests validate the lowering *logic* in Pallas interpret mode on every
backend (the CPU tier), check equivalence against BOTH the TPU kernels
(interpret) and the pure-jnp references, and — when a real CUDA/ROCm
device is present — compile the same kernels for the silicon path
(skip-marked elsewhere).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import gpu_lowering as gpu  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

HAS_GPU = ops.on_gpu()
needs_gpu = pytest.mark.skipif(not HAS_GPU, reason="no CUDA/ROCm device")


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(29)


# -------------------------------------------------------------- interpret
def test_compact_matches_tpu_kernel_and_ref(rng):
    from repro.kernels.compact import compact_positions_batched_pallas
    mask = (rng.random((5, 2048)) < 0.35).astype(np.int32)
    m = jnp.asarray(mask)
    pos_g, tot_g = gpu.compact_positions_batched_gpu(m, interpret=True)
    pos_t, tot_t = compact_positions_batched_pallas(m, interpret=True)
    np.testing.assert_array_equal(np.asarray(pos_g), np.asarray(pos_t))
    np.testing.assert_array_equal(np.asarray(tot_g), np.asarray(tot_t))
    incl = np.cumsum(mask, axis=1)
    np.testing.assert_array_equal(np.asarray(pos_g), incl - mask)
    np.testing.assert_array_equal(np.asarray(tot_g).ravel(), incl[:, -1])


def test_compact_single_stream_contract(rng):
    mask = (rng.random(1024) < 0.5).astype(np.int32)
    pos, tot = gpu.compact_positions_gpu(jnp.asarray(mask), interpret=True)
    incl = np.cumsum(mask)
    np.testing.assert_array_equal(np.asarray(pos), incl - mask)
    assert int(tot[0]) == int(incl[-1])


def test_metrics_bit_exact_hist_and_kahan_moments(rng):
    from repro.kernels.metrics_fused import stream_metrics_pallas
    ss = np.sort(rng.integers(0, 1500, (4, 2048)), axis=1).astype(np.int32)
    buckets = 1536
    h_g, m_g = gpu.stream_metrics_gpu(jnp.asarray(ss), buckets,
                                      interpret=True)
    h_t, m_t = stream_metrics_pallas(jnp.asarray(ss), buckets,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(h_g), np.asarray(h_t))
    # SAME Kahan block order as the TPU kernel -> bit-equal f32 moments
    np.testing.assert_array_equal(np.asarray(m_g), np.asarray(m_t))
    h_r, m_r = ref.stream_metrics_ref(jnp.asarray(ss), buckets)
    np.testing.assert_array_equal(np.asarray(h_g), np.asarray(h_r))
    np.testing.assert_allclose(np.asarray(m_g), np.asarray(m_r),
                               rtol=1e-5, atol=1e-2)


def test_metrics_padding_ids_count_nowhere(rng):
    ss = np.full((2, 1024), 10_000, np.int32)       # all padding stamps
    ss[0, :5] = [0, 1, 1, 2, 511]
    h, m = gpu.stream_metrics_gpu(jnp.asarray(ss), 512, interpret=True)
    h = np.asarray(h)
    assert h[0].sum() == 5 and h[1].sum() == 0
    assert h[0][1] == 2


def test_metrics_carry_composes_across_chunks(rng):
    from repro.kernels.metrics_fused import stream_metrics_carry_pallas
    buckets = 1024
    a = np.sort(rng.integers(0, buckets, (3, 1024)), axis=1) \
        .astype(np.int32)
    b = np.sort(rng.integers(0, buckets, (3, 1024)), axis=1) \
        .astype(np.int32)
    zero = jnp.zeros((3, 4), jnp.float32)
    h1, c1 = gpu.stream_metrics_carry_gpu(jnp.asarray(a), zero, buckets)
    h2, c2 = gpu.stream_metrics_carry_gpu(jnp.asarray(b), c1, buckets)
    h1t, c1t = stream_metrics_carry_pallas(jnp.asarray(a), zero, buckets,
                                           interpret=True)
    h2t, c2t = stream_metrics_carry_pallas(jnp.asarray(b), c1t, buckets,
                                           interpret=True)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h1t))
    np.testing.assert_array_equal(np.asarray(h2), np.asarray(h2t))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c1t))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(c2t))


def test_trend_scan_bit_exact(rng):
    from repro.kernels.trend_scan import trend_scan_pallas
    q = rng.integers(0, 9, (6, 2048)).astype(np.int32)
    s_g = gpu.trend_scan_gpu(jnp.asarray(q), interpret=True)
    s_t = trend_scan_pallas(jnp.asarray(q), interpret=True)
    np.testing.assert_array_equal(np.asarray(s_g), np.asarray(s_t))
    np.testing.assert_array_equal(np.asarray(s_g), np.cumsum(q, axis=1))


def test_trend_scan_carry_contract(rng):
    from repro.kernels.trend_scan import trend_scan_carry_pallas
    q = rng.integers(0, 9, (4, 1024)).astype(np.int32)
    init = rng.integers(0, 1000, 4).astype(np.int32)
    p_g, t_g = gpu.trend_scan_carry_gpu(jnp.asarray(q), jnp.asarray(init))
    p_t, t_t = trend_scan_carry_pallas(jnp.asarray(q), jnp.asarray(init),
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(p_g), np.asarray(p_t))
    np.testing.assert_array_equal(np.asarray(t_g), np.asarray(t_t))
    np.testing.assert_array_equal(
        np.asarray(p_g), init[:, None] + np.cumsum(q, axis=1))


def test_pair_stats_within_tolerance(rng):
    from repro.kernels.trend_scan import pair_stats_pallas
    x = rng.standard_normal((5, 2048)).astype(np.float32)
    s_g, g_g = gpu.pair_stats_gpu(jnp.asarray(x), interpret=True)
    s_t, g_t = pair_stats_pallas(jnp.asarray(x), interpret=True)
    np.testing.assert_allclose(np.asarray(s_g), np.asarray(s_t),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(g_g), np.asarray(g_t),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(g_g), x @ x.T,
                               rtol=1e-3, atol=1e-3)


def test_ops_backend_auto_prefers_any_accelerator():
    # "auto" resolves to the pallas path whenever a real accelerator is
    # present (TPU *or* GPU) — the GPU lowering makes that safe
    from repro.streamsim.nsa import _resolve_backend
    expect = "pallas" if ops.on_accelerator() else "numpy"
    assert _resolve_backend("auto") == expect
    assert ops.on_accelerator() == (ops.on_tpu() or ops.on_gpu())


# ---------------------------------------------------------------- compiled
@needs_gpu
def test_compiled_compact_on_gpu(rng):
    mask = (rng.random((4, 4096)) < 0.3).astype(np.int32)
    pos, tot = gpu.compact_positions_batched_gpu(jnp.asarray(mask),
                                                 interpret=False)
    incl = np.cumsum(mask, axis=1)
    np.testing.assert_array_equal(np.asarray(pos), incl - mask)
    np.testing.assert_array_equal(np.asarray(tot).ravel(), incl[:, -1])


@needs_gpu
def test_compiled_metrics_on_gpu(rng):
    ss = np.sort(rng.integers(0, 900, (4, 4096)), axis=1).astype(np.int32)
    h, m = gpu.stream_metrics_gpu(jnp.asarray(ss), 1024, interpret=False)
    h_r, m_r = ref.stream_metrics_ref(jnp.asarray(ss), 1024)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_r))
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_r),
                               rtol=1e-4, atol=1e-2)


@needs_gpu
def test_compiled_trend_and_pair_on_gpu(rng):
    q = rng.integers(0, 9, (4, 4096)).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(gpu.trend_scan_gpu(jnp.asarray(q), interpret=False)),
        np.cumsum(q, axis=1))
    x = rng.standard_normal((4, 2048)).astype(np.float32)
    _, g = gpu.pair_stats_gpu(jnp.asarray(x), interpret=False)
    np.testing.assert_allclose(np.asarray(g), x @ x.T,
                               rtol=1e-3, atol=1e-3)


@needs_gpu
def test_compiled_stream_sample_on_gpu(rng):
    # the TPU stream_sample kernel is grid-parallel-safe and must compile
    # unchanged on GPU (ops dispatches it with interpret=False there)
    from repro.kernels.stream_sample import stream_sample_pallas
    t = np.sort(rng.uniform(0, 900.0, 2048))
    t32, starts, counts, ktab, scal = ops._nsa_tables(t, 600, 1.0)
    args = (jnp.asarray(t32)[None], jnp.asarray(starts)[None],
            jnp.asarray(counts)[None], jnp.asarray(ktab)[None],
            jnp.asarray(scal)[None])
    ss_c, keep_c = stream_sample_pallas(*args, 600, interpret=False)
    ss_i, keep_i = stream_sample_pallas(*args, 600, interpret=True)
    np.testing.assert_array_equal(np.asarray(ss_c), np.asarray(ss_i))
    np.testing.assert_array_equal(np.asarray(keep_c), np.asarray(keep_i))
