"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness assertions) plus model-level equivalence tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models import transformer as T
from repro.models.layers import unembed


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                                cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = get_smoke(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        hidden, aux = T.forward(cfg, params, batch["inputs"])
        assert hidden.shape == (2, 32, cfg.d_model)
        assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())

    def test_train_step(self, arch):
        cfg = get_smoke(arch)
        from repro.training.optimizer import AdamW, adamw_init
        from repro.training.steps import make_train_step
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = adamw_init(params)
        step = jax.jit(make_train_step(cfg, AdamW(lr=1e-3, warmup_steps=1)))
        p2, o2, metrics = step(params, opt_state, _batch(cfg))
        assert np.isfinite(float(metrics["loss"]))
        assert int(o2["step"]) == 1
        # params must actually change
        delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
            jax.tree.leaves(params), jax.tree.leaves(p2)))
        assert delta > 0

    def test_decode_matches_forward(self, arch):
        """Greedy prefill+decode must agree with teacher-forced forward."""
        cfg = get_smoke(arch)
        if cfg.input_mode == "embeddings":
            pytest.skip("decode consistency is a token-arch property")
        params = T.init_params(cfg, jax.random.PRNGKey(1))
        b, s = 2, 24
        toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                  cfg.vocab_size)
        hidden, _ = T.forward(cfg, params, toks)
        full_logits = unembed(hidden, T._head_table(cfg, params),
                              cfg.logit_softcap)
        lens = jnp.array([s, s])
        pre_logits, cache = T.prefill(cfg, params, toks, lens, max_len=s + 4)
        np.testing.assert_allclose(
            np.asarray(pre_logits, np.float32),
            np.asarray(full_logits[:, -1], np.float32), rtol=2e-3, atol=2e-3)
        # one decode step vs forward on the extended sequence
        nxt = jnp.argmax(pre_logits, -1).astype(jnp.int32)
        dec_logits, cache = T.decode_step(cfg, params, cache, nxt)
        toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
        hidden2, _ = T.forward(cfg, params, toks2)
        full2 = unembed(hidden2[:, -1], T._head_table(cfg, params),
                        cfg.logit_softcap)
        np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                                   np.asarray(full2, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestEquivalences:
    def test_chunked_attention_equals_naive(self):
        cfg = get_smoke("llama3-8b").replace(attn_impl="naive")
        cfg_c = cfg.replace(attn_impl="chunked", attn_chunk_q=8,
                            attn_chunk_kv=16)
        params = T.init_params(cfg, jax.random.PRNGKey(3))
        toks = jax.random.randint(jax.random.PRNGKey(4), (2, 64), 0,
                                  cfg.vocab_size)
        h1, _ = T.forward(cfg, params, toks)
        h2, _ = T.forward(cfg_c, params, toks)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=1e-4, atol=1e-4)

    def test_windowed_chunked_equals_naive(self):
        cfg = get_smoke("recurrentgemma-2b")
        cfg_n = cfg.replace(attn_impl="naive")
        cfg_c = cfg.replace(attn_impl="chunked", attn_chunk_q=8,
                            attn_chunk_kv=8)
        params = T.init_params(cfg_n, jax.random.PRNGKey(5))
        toks = jax.random.randint(jax.random.PRNGKey(6), (2, 48), 0,
                                  cfg.vocab_size)
        h1, _ = T.forward(cfg_n, params, toks)
        h2, _ = T.forward(cfg_c, params, toks)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=1e-4, atol=1e-4)

    def test_remat_does_not_change_loss(self):
        cfg = get_smoke("llama3-8b").replace(remat="none")
        cfg_r = cfg.replace(remat="full")
        params = T.init_params(cfg, jax.random.PRNGKey(7))
        batch = _batch(cfg, seed=8)
        l1, _ = T.loss_fn(cfg, params, batch)
        l2, _ = T.loss_fn(cfg_r, params, batch)
        assert np.isclose(float(l1), float(l2), rtol=1e-5)
        g1 = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
        g2 = jax.grad(lambda p: T.loss_fn(cfg_r, p, batch)[0])(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-5)

    def test_loss_chunking_invariant(self):
        cfg = get_smoke("qwen3-32b").replace(loss_chunk=8)
        cfg2 = cfg.replace(loss_chunk=32)
        params = T.init_params(cfg, jax.random.PRNGKey(9))
        batch = _batch(cfg, seed=10)
        l1, _ = T.loss_fn(cfg, params, batch)
        l2, _ = T.loss_fn(cfg2, params, batch)
        assert np.isclose(float(l1), float(l2), rtol=1e-6)

    def test_moe_capacity_drops_gracefully(self):
        cfg = get_smoke("deepseek-v3-671b").replace(capacity_factor=0.25)
        params = T.init_params(cfg, jax.random.PRNGKey(11))
        batch = _batch(cfg, seed=12)
        loss, metrics = T.loss_fn(cfg, params, batch)
        assert np.isfinite(float(loss)), "token dropping must stay finite"

    def test_rwkv_long_decode_state_is_constant_size(self):
        cfg = get_smoke("rwkv6-1_6b")
        cache8 = jax.eval_shape(lambda: T.init_cache(cfg, 2, 8))
        cache512 = jax.eval_shape(lambda: T.init_cache(cfg, 2, 512))
        b8 = sum(np.prod(l.shape) for l in jax.tree.leaves(cache8))
        b512 = sum(np.prod(l.shape) for l in jax.tree.leaves(cache512))
        assert b8 == b512, "attention-free state is O(1) in context"

    def test_local_window_cache_bounded(self):
        cfg = get_smoke("recurrentgemma-2b")  # window 16
        cache = jax.eval_shape(lambda: T.init_cache(cfg, 2, 512))
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            name = str(path[-1])
            if "'k'" in name or "'v'" in name:
                assert leaf.shape[2] == cfg.window, \
                    "local attention cache is a window ring buffer"
