"""Chunked double-buffered pipeline tests (the unbounded-stream PR's
acceptance gates).

Contracts under test:

- **chunk-boundary equivalence**: for any chunk size — single-bucket,
  ragged last chunk, hour, day-sized single chunk — the chunked
  ``run_many`` reproduces the monolithic reports: simulated rows and
  stored streams bit-equal, statistics within the documented
  tolerances, on BOTH backends;
- **carry reset**: back-to-back chunked runs over the same plan report
  identically — no :class:`~repro.kernels.ops.ChunkCarry` state leaks
  across runs (and the second run exercises chunk-granular resume:
  existing chunk files are skipped, not rewritten);
- **device residency + double buffering**: the metrics carry consumes
  jax arrays straight from the chunk dispatch (no host transfer
  between chunks), and chunk ``k+1``'s NSA dispatch is issued BEFORE
  chunk ``k``'s host gather;
- **StreamStore chunk API**: atomic per-chunk append, transparent
  concatenated ``get``, resume skip of existing chunks, completeness
  check at finalize;
- **ChunkFeed**: bounded (high-watermark ≤ maxsize), blocking with no
  busy-wait on both sides; a stalled chunk iterator stalls the chunked
  replay walk without spinning, and fault injection over chunked
  replay preserves the delivery reconciliation identity
  ``delivered == emitted - dropped + duplicated``;
- **multi-day sweeps**: ``duration_s`` grows every scenario's span to
  ``max_range`` per day; chunk-size variants agree bit-exactly; host
  residency stays bounded (``feed_hwm_chunks <= 2``) over the 7-day
  8-scenario acceptance sweep;
- **regression gate**: ``benchmarks/check_regression.py`` fails with a
  clean one-line message (no traceback) on a missing baseline file and
  enforces per-row ratio gates.
"""

import importlib.util
import json
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.streamsim import (
    ChunkFeed,
    Controller,
    FaultPlan,
    FaultSpec,
    MultiQueueProducer,
    QueueGroup,
    RetryPolicy,
    StreamStore,
    VirtualClock,
    make_stream,
    nsa,
    plan_sweep,
    preprocess,
)
from repro.streamsim import engine
from repro.streamsim.plan import DAY_S
from repro.streamsim.preprocess import Stream

CHAOS = FaultSpec(drop_rate=0.2, duplicate_rate=0.15, reorder_rate=0.25,
                  reorder_window=3, delay_jitter_s=0.01)


def _consumer(queue):
    return {"records_seen": sum(len(b) for b in queue)}


def _reconciles(m):
    return m["buckets_in"] == (m["emitted_buckets"]
                               - m.get("fault_dropped", 0)
                               + m.get("fault_duplicated", 0))


def _mini_stream(name="traffic", scale=0.002, seed=9):
    return preprocess(make_stream(name, scale=scale, seed=seed))


def _slice(sim, lo, hi):
    a, b = np.searchsorted(sim.scale_stamp, [lo, hi])
    return Stream(name=sim.name, t=sim.t[a:b],
                  payload={k: v[a:b] for k, v in sim.payload.items()},
                  scale_stamp=sim.scale_stamp[a:b])


# ------------------------------------------------------------ store chunks
class TestStoreChunks:
    def _chunks(self, n=3, rows=30):
        rng = np.random.default_rng(3)
        t = np.sort(rng.uniform(0, 60, size=rows))
        ss = np.sort(rng.integers(0, 60, size=rows)).astype(np.int64)
        full = Stream(name="s", t=t, payload={"x": rng.normal(size=rows)},
                      scale_stamp=ss)
        edges = np.linspace(0, rows, n + 1).astype(int)
        parts = [Stream(name="s", t=t[a:b],
                        payload={"x": full.payload["x"][a:b]},
                        scale_stamp=ss[a:b])
                 for a, b in zip(edges[:-1], edges[1:])]
        return full, parts

    def test_append_finalize_get_roundtrip(self, tmp_path):
        store = StreamStore(tmp_path)
        full, parts = self._chunks()
        for i, p in enumerate(parts):
            assert store.append_chunk("k", i, p) is True
        assert not store.exists("k")     # invisible until finalized
        store.finalize_chunks("k", name="s", n_chunks=len(parts))
        assert store.exists("k")
        got = store.get("k")
        np.testing.assert_array_equal(got.t, full.t)
        np.testing.assert_array_equal(got.scale_stamp, full.scale_stamp)
        np.testing.assert_array_equal(got.payload["x"], full.payload["x"])
        man = store.manifest("k")
        assert man["chunks"] == len(parts) and man["rows"] == len(full)

    def test_append_chunk_resume_skips_existing(self, tmp_path):
        store = StreamStore(tmp_path)
        _, parts = self._chunks()
        assert store.append_chunk("k", 0, parts[0]) is True
        f = store._chunk_file(store._dir("k"), 0)
        before = f.stat().st_mtime_ns
        # the resume path: an existing chunk is NOT rewritten
        assert store.append_chunk("k", 0, parts[1]) is False
        assert f.stat().st_mtime_ns == before
        assert store.append_chunk("k", 0, parts[0], overwrite=True) is True
        assert store.has_chunk("k", 0) and not store.has_chunk("k", 1)
        assert store.list_chunks("k") == [0]

    def test_finalize_missing_chunk_raises(self, tmp_path):
        store = StreamStore(tmp_path)
        _, parts = self._chunks()
        store.append_chunk("k", 0, parts[0])
        store.append_chunk("k", 2, parts[2])
        with pytest.raises(ValueError, match="missing chunk"):
            store.finalize_chunks("k", name="s", n_chunks=3)
        assert not store.exists("k")     # key stays invisible

    def test_finalize_stats_matches_reread(self, tmp_path):
        # the runner's precomputed-stats path must write the same
        # manifest the re-read path assembles from the chunk files
        store = StreamStore(tmp_path)
        full, parts = self._chunks()
        for i, p in enumerate(parts):
            store.append_chunk("a", i, p)
            store.append_chunk("b", i, p)
        store.finalize_chunks("a", name="s", n_chunks=len(parts))
        store.finalize_chunks(
            "b", name="s", n_chunks=len(parts),
            stats={"rows": len(full), "nbytes": full.nbytes(),
                   "time_range_s": full.time_range})
        ma, mb = store.manifest("a"), store.manifest("b")
        for field in ("rows", "nbytes", "chunks"):
            assert ma[field] == mb[field]
        assert ma["time_range_s"] == pytest.approx(mb["time_range_s"])

    def test_delete_removes_chunk_files(self, tmp_path):
        store = StreamStore(tmp_path)
        _, parts = self._chunks()
        for i, p in enumerate(parts):
            store.append_chunk("k", i, p)
        store.finalize_chunks("k", name="s", n_chunks=len(parts))
        store.delete("k")
        assert not store.exists("k") and store.list_chunks("k") == []


# -------------------------------------------------------------- chunk feed
class TestChunkFeed:
    def _chunk(self, n=4):
        t = np.arange(float(n))
        return Stream(name="c", t=t, payload={"x": t.copy()},
                      scale_stamp=np.arange(n, dtype=np.int64))

    @pytest.mark.timeout(30)
    def test_bounded_put_blocks_until_get(self):
        feed = ChunkFeed(maxsize=2)
        feed.put(self._chunk())
        feed.put(self._chunk())
        with pytest.raises(TimeoutError):
            feed.put(self._chunk(), timeout=0.05)
        got = []
        th = threading.Thread(target=lambda: feed.put(self._chunk()),
                              daemon=True)
        th.start()
        got.append(feed.get())
        th.join(timeout=5)
        assert not th.is_alive()         # put unblocked by the get
        assert feed.stats()["feed_hwm_chunks"] <= 2

    @pytest.mark.timeout(30)
    def test_empty_get_blocks_then_drains_after_close(self):
        feed = ChunkFeed(maxsize=2)
        with pytest.raises(TimeoutError):
            feed.get(timeout=0.05)       # blocking wait, not a spin
        feed.put(self._chunk())
        feed.close()
        assert feed.get() is not None    # close still drains the queue
        assert feed.get() is None        # then signals end-of-timeline
        with pytest.raises(RuntimeError):
            feed.put(self._chunk())

    def test_maxsize_validated(self):
        with pytest.raises(ValueError):
            ChunkFeed(maxsize=0)


# ------------------------------------------------- chunk/monolith equality
def _assert_equivalent(rep, ref, store_a, store_b):
    assert [(r.dataset, r.max_range) for r in rep] == \
        [(r.dataset, r.max_range) for r in ref]
    for a, b in zip(rep, ref):
        assert a.simulated_rows == b.simulated_rows
        assert a.consumer_metrics["records_seen"] == \
            b.consumer_metrics["records_seen"]
        assert a.trend_corr == pytest.approx(b.trend_corr, abs=1e-3)
        for f in ("average", "variance", "std_variance"):
            assert getattr(a.simulated_volatility, f) == pytest.approx(
                getattr(b.simulated_volatility, f), rel=1e-3, abs=1e-6)
    for r in rep:
        sa = store_a.get(f"{r.dataset}__sim{r.max_range}")
        sb = store_b.get(f"{r.dataset}__sim{r.max_range}")
        np.testing.assert_array_equal(sa.t, sb.t)
        np.testing.assert_array_equal(sa.scale_stamp, sb.scale_stamp)


class TestChunkedEquivalence:
    DATASETS = ["sogouq", "traffic"]
    RANGES = [20, 45]                    # 45 % 7 != 0: ragged last chunk

    @pytest.mark.timeout(120)
    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    @pytest.mark.parametrize("chunk_s", [1, 7, 3600, 86400])
    def test_chunked_reproduces_monolithic(self, tmp_path, backend,
                                           chunk_s):
        c = Controller(str(tmp_path / "chunked"))
        rep = c.run_many(self.DATASETS, self.RANGES, _consumer,
                         scale=0.002, seed=9, backend=backend,
                         chunk_s=chunk_s)
        ref_c = Controller(str(tmp_path / "mono"))
        ref = ref_c.run_many(self.DATASETS, self.RANGES, _consumer,
                             scale=0.002, seed=9, backend=backend)
        _assert_equivalent(rep, ref, c.store, ref_c.store)
        # the bounded-residency stat rides on every chunked report
        for r in rep:
            assert r.consumer_metrics["feed_hwm_chunks"] <= 2

    @pytest.mark.timeout(120)
    def test_carry_resets_per_run_and_resume_skips_chunks(self, tmp_path):
        # two fresh runners over the SAME plan: run 2 recomputes device
        # work but must (a) start from a fresh carry — identical stats —
        # and (b) skip rewriting the chunk files run 1 left behind
        originals = {"traffic": _mini_stream()}
        store = StreamStore(str(tmp_path / "store"))
        plan = plan_sweep(store, ["traffic"], [20, 45],
                          {"traffic": len(originals["traffic"])},
                          scale=0.002, seed=9, n_devices=1, host_index=0,
                          n_hosts=1, chunk_s=7)
        r1 = engine.ChunkedSweepRunner(plan, originals, store,
                                       backend="pallas").run()
        key = plan.scenarios[0].store_key
        mtimes = {i: store._chunk_file(store._dir(key), i).stat().st_mtime_ns
                  for i in store.list_chunks(key)}
        r2 = engine.ChunkedSweepRunner(plan, originals, store,
                                       backend="pallas").run()
        for a, b in zip(r1.shard_results, r2.shard_results):
            np.testing.assert_array_equal(a.totals, b.totals)
            np.testing.assert_array_equal(np.asarray(a.hist),
                                          np.asarray(b.hist))
            np.testing.assert_array_equal(a.mom, b.mom)
        for i, m in mtimes.items():
            assert store._chunk_file(store._dir(key),
                                     i).stat().st_mtime_ns == m, \
                f"chunk {i} was rewritten on resume"

    @pytest.mark.timeout(120)
    def test_device_resident_and_double_buffered(self, tmp_path,
                                                 monkeypatch):
        # (a) the metrics carry consumes jax arrays straight from the
        # chunk dispatch — no host transfer between chunks; (b) chunk
        # k+1's NSA dispatch is issued BEFORE chunk k's host gather
        import jax

        import repro.kernels.ops as ops_mod
        import repro.streamsim.engine as engine_mod

        events = []
        real_sample = ops_mod.stream_sample_pallas
        real_metrics = ops_mod.stream_metrics_chunk
        real_mat = engine_mod.materialize_sweep_chunk

        def counting_sample(*args, **kwargs):
            events.append("sample")
            return real_sample(*args, **kwargs)

        def checking_metrics(carry, ss, totals, lo, hi):
            assert isinstance(ss, jax.Array), \
                f"chunk metrics fed host data: {type(ss)}"
            assert isinstance(totals, jax.Array), \
                f"chunk totals crossed to host early: {type(totals)}"
            events.append("metrics")
            return real_metrics(carry, ss, totals, lo, hi)

        def tracking_mat(*args, **kwargs):
            events.append("mat")
            return real_mat(*args, **kwargs)

        monkeypatch.setattr(ops_mod, "stream_sample_pallas",
                            counting_sample)
        monkeypatch.setattr(ops_mod, "stream_metrics_chunk",
                            checking_metrics)
        monkeypatch.setattr(engine_mod, "materialize_sweep_chunk",
                            tracking_mat)

        originals = {"traffic": _mini_stream()}
        store = StreamStore(str(tmp_path / "store"))
        plan = plan_sweep(store, ["traffic"], [30],
                          {"traffic": len(originals["traffic"])},
                          scale=0.002, seed=9, n_devices=1, host_index=0,
                          n_hosts=1, chunk_s=10)
        runner = engine.ChunkedSweepRunner(plan, originals, store,
                                           backend="pallas")
        assert runner.mode == "device"
        runner.run()
        n = plan.n_chunks
        assert events.count("sample") == n == events.count("metrics")
        assert events.count("mat") == n
        # double buffering: the i-th host gather happens only after the
        # (i+1)-th chunk's NSA dispatch (the last chunk has no successor)
        mat_seen = 0
        for j, e in enumerate(events):
            if e != "mat":
                continue
            samples_before = sum(x == "sample" for x in events[:j])
            if mat_seen < n - 1:
                assert samples_before >= mat_seen + 2, \
                    f"host gather {mat_seen} ran before dispatch " \
                    f"{mat_seen + 1}: {events}"
            mat_seen += 1


# ---------------------------------------------------------------- multi-day
class TestMultiDay:
    @pytest.mark.timeout(300)
    def test_7day_8sc_bounded_and_chunk_size_invariant(self, tmp_path):
        # the acceptance sweep: 7 days x 8 scenarios, two chunk sizes —
        # reports and stored streams must agree bit-exactly, and every
        # report must prove bounded residency (<= 2 chunks buffered)
        datasets = ["sogouq", "traffic"]
        ranges = [15, 30, 45, 60]
        dur = 7 * DAY_S
        reps = {}
        ctrls = {}
        for cs in (45, 150):
            c = Controller(str(tmp_path / f"c{cs}"))
            reps[cs] = c.run_many(datasets, ranges, _consumer, scale=0.001,
                                  seed=5, chunk_s=cs, duration_s=dur)
            ctrls[cs] = c
        for a, b in zip(reps[45], reps[150]):
            assert a.simulated_rows == b.simulated_rows
            assert a.consumer_metrics["records_seen"] == \
                b.consumer_metrics["records_seen"]
            assert a.consumer_metrics["feed_hwm_chunks"] <= 2
            assert b.consumer_metrics["feed_hwm_chunks"] <= 2
        for r in reps[45]:
            key = f"{r.dataset}__sim{r.max_range}__d{dur}"
            sa = ctrls[45].store.get(key)
            sb = ctrls[150].store.get(key)
            np.testing.assert_array_equal(sa.t, sb.t)
            np.testing.assert_array_equal(sa.scale_stamp, sb.scale_stamp)
            # the simulated timeline really spans all 7 days
            assert sa.scale_stamp[-1] >= 6 * r.max_range

    def test_duration_requires_chunking(self, tmp_path):
        c = Controller(str(tmp_path / "s"))
        with pytest.raises(ValueError, match="chunk_s"):
            c.run_many(["traffic"], [20], _consumer, scale=0.002,
                       duration_s=DAY_S)

    def test_chunked_rejects_rewind_features(self, tmp_path):
        # consumed chunks cannot rewind: scenario-grain retry/deadline
        # are monolithic-path features and must be rejected loudly
        c = Controller(str(tmp_path / "s"))
        with pytest.raises(ValueError):
            c.run_many(["traffic"], [20], _consumer, scale=0.002,
                       chunk_s=10, retry_policy=RetryPolicy(max_attempts=2))
        with pytest.raises(ValueError):
            c.run_many(["traffic"], [20], _consumer, scale=0.002,
                       chunk_s=10, consumer_deadline_s=5.0)


# ------------------------------------------------------------ chunked chaos
class TestChunkedFaults:
    @pytest.mark.timeout(120)
    def test_fault_injected_chunked_replay_reconciles(self, tmp_path):
        # the chunked walk must keep the delivery identity under chaos
        c = Controller(str(tmp_path / "s"))
        reports = c.run_many(["traffic"], [20, 40, 60], _consumer,
                             scale=0.002, seed=9, chunk_s=7,
                             fault_plan=FaultPlan(5, default=CHAOS))
        assert len(reports) == 3
        dropped = 0
        for r in reports:
            m = r.consumer_metrics
            assert _reconciles(m), f"{r.dataset} does not reconcile: {m}"
            assert m["records_seen"] == m["records_in"]
            dropped += m.get("fault_dropped", 0)
        assert dropped > 0               # the schedule actually fired

    @pytest.mark.timeout(60)
    def test_stalled_feed_blocks_walk_without_busy_wait(self):
        # round-locked walk: until EVERY scenario's chunk k lands, the
        # producer sleeps in Condition.wait — no records emitted, no CPU
        # burned — then completes normally once the stall resolves
        sim = nsa(_mini_stream(), 20)
        chunks = [_slice(sim, 0, 10), _slice(sim, 10, 20)]
        feeds = {"a": ChunkFeed(maxsize=2), "b": ChunkFeed(maxsize=2)}
        group = QueueGroup(feeds, maxsize=1_000_000)
        producer = MultiQueueProducer(feeds, group.queues,
                                      clock=VirtualClock())
        assert producer.chunked
        status = []
        th = threading.Thread(target=lambda: status.append(producer.run()),
                              daemon=True)
        th.start()
        for ch in chunks:
            feeds["a"].put(ch)
        feeds["a"].close()
        cpu0 = time.process_time()
        time.sleep(0.3)                  # feed "b" is stalled
        cpu_burn = time.process_time() - cpu0
        assert th.is_alive()             # walk is blocked, not finished
        assert group["a"].stats()["buckets_in"] == 0, \
            "round lock broken: scenario emitted before the sweep's round"
        assert cpu_burn < 0.2, \
            f"stalled walk burned {cpu_burn:.2f}s CPU — busy-wait"
        for ch in chunks:
            feeds["b"].put(ch)
        feeds["b"].close()
        th.join(timeout=10)
        assert not th.is_alive() and status == [0]
        for k in ("a", "b"):
            assert group[k].stats()["records_in"] == len(sim)


# -------------------------------------------------------- regression gate
def _load_check_regression():
    path = (Path(__file__).resolve().parent.parent / "benchmarks"
            / "check_regression.py")
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestRegressionGate:
    def test_missing_file_is_clean_one_line_failure(self, tmp_path,
                                                    capsys):
        cr = _load_check_regression()
        missing = tmp_path / "BENCH_PR7.json"
        assert cr.check([str(missing)]) == 1     # returns, never raises
        err = capsys.readouterr().err
        assert "missing" in err and str(missing) in err

    def _rows(self, name, us, derived):
        return [{"name": name, "us_per_call": us, "derived": derived}]

    def test_speedup_ratio_gate(self, tmp_path):
        cr = _load_check_regression()
        path = tmp_path / "BENCH_PR7.json"
        # 1.25x over the sequential loop: inside the >=1.2x gate
        ok = self._rows("PR7/chunked_pipeline_7day_8sc@scale0.002", 80.0,
                        "sequential_chunk_path_us=100")
        path.write_text(json.dumps(
            ok + self._rows("PR7/chunk_vs_monolith_1day", 100.0,
                            "monolithic_path_us=100")))
        assert cr.check([str(path)]) == 0
        # only 1.1x: misses the >=1.2x gate
        bad = self._rows("PR7/chunked_pipeline_7day_8sc@scale0.002", 91.0,
                         "sequential_chunk_path_us=100")
        path.write_text(json.dumps(
            bad + self._rows("PR7/chunk_vs_monolith_1day", 100.0,
                             "monolithic_path_us=100")))
        assert cr.check([str(path)]) == 1

    def test_overhead_ratio_gate(self, tmp_path):
        cr = _load_check_regression()
        path = tmp_path / "BENCH_PR7.json"
        fast = self._rows("PR7/chunked_pipeline_7day_8sc", 50.0,
                          "sequential_chunk_path_us=100")
        path.write_text(json.dumps(
            fast + self._rows("PR7/chunk_vs_monolith_1day", 104.0,
                              "monolithic_path_us=100")))
        assert cr.check([str(path)]) == 0        # 1.04x <= 1.05x
        path.write_text(json.dumps(
            fast + self._rows("PR7/chunk_vs_monolith_1day", 107.0,
                              "monolithic_path_us=100")))
        assert cr.check([str(path)]) == 1        # 1.07x > 1.05x
