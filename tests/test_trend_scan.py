"""Device-resident trend & S×S correlation engine tests.

Contract under test (see kernels/trend_scan.py + streamsim/metrics.py):
the prefix-sum scan kernel is bit-exact against its cumsum oracle; the
trend produced from it matches the host cumsum sliding mean within 1e-3
(window sums int32-exact, divide f32); the S×S correlation matrix is
symmetric with a unit diagonal and agrees with the float64 numpy mirror
within 1e-3; out-of-domain inputs raise PallasDomainError at the ops
layer and fall back to numpy in the metrics layer.
"""

import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.trend_scan import (PAIR_TILE, TILE, pair_stats_pallas,
                                      trend_scan_pallas)
from repro.streamsim import Controller, trend_correlation_matrix
from repro.streamsim.metrics import (_corr_matrix_numpy, sliding_mean,
                                     trend_correlation_from_counts)


def _counts(n, seed=0, lam=25.0):
    return np.random.default_rng(seed).poisson(lam, n).astype(np.int64)


class TestScanKernel:
    @pytest.mark.parametrize("S,tiles", [(1, 1), (1, 3), (4, 2), (7, 1)])
    def test_prefix_sum_bit_exact_vs_oracle(self, S, tiles):
        rng = np.random.default_rng(S * 10 + tiles)
        q = rng.integers(0, 1000, (S, tiles * TILE)).astype(np.int32)
        import jax.numpy as jnp
        got = np.asarray(trend_scan_pallas(jnp.asarray(q), interpret=True))
        exp = np.asarray(ref.trend_scan_ref(jnp.asarray(q)))
        np.testing.assert_array_equal(got, exp)
        # and both equal the int64 host cumsum (no int32 wrap at this scale)
        np.testing.assert_array_equal(got, np.cumsum(q, axis=1))

    def test_carry_resets_between_streams(self):
        # stream 1's scan must not inherit stream 0's carry
        import jax.numpy as jnp
        q = np.ones((2, 2 * TILE), np.int32)
        got = np.asarray(trend_scan_pallas(jnp.asarray(q), interpret=True))
        np.testing.assert_array_equal(got[1], np.arange(1, 2 * TILE + 1))

    @pytest.mark.parametrize("S,k_tiles", [(1, 1), (2, 2), (5, 3)])
    def test_pair_stats_vs_oracle(self, S, k_tiles):
        rng = np.random.default_rng(S + k_tiles)
        x = rng.normal(0, 3, (S, k_tiles * PAIR_TILE)).astype(np.float32)
        import jax.numpy as jnp
        sums, gram = pair_stats_pallas(jnp.asarray(x), interpret=True)
        sums_r, gram_r = ref.pair_stats_ref(jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(sums), np.asarray(sums_r),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(gram), np.asarray(gram_r),
                                   rtol=1e-4, atol=1e-3)


class TestTrendScanOps:
    @pytest.mark.parametrize("n,w", [(0, 5), (1, 1), (1, 600), (10, 1),
                                     (10, 4), (100, 600), (7, 7), (2, 5),
                                     (5000, 60)])
    def test_matches_host_sliding_mean(self, n, w):
        q = _counts(n, seed=n * 100 + w)
        got = np.asarray(ops.trend_scan(q, w))
        exp = sliding_mean(q.astype(np.float64), w)
        assert got.shape == exp.shape
        np.testing.assert_allclose(got, exp, rtol=1e-3, atol=1e-5)

    def test_ragged_batch_equals_per_stream(self):
        qs = [_counts(n, seed=n) for n in (0, 1, 17, 600, 3600)]
        trend_b, lengths = ops.trend_scan_batched(qs, 60)
        trend_b = np.asarray(trend_b)
        np.testing.assert_array_equal(lengths, [len(q) for q in qs])
        for s, q in enumerate(qs):
            np.testing.assert_allclose(
                trend_b[s, :len(q)],
                sliding_mean(q.astype(np.float64), 60),
                rtol=1e-3, atol=1e-5)
            # padded tail stays zero
            assert not trend_b[s, len(q):].any()

    def test_bad_window_rejected(self):
        with pytest.raises(ValueError):
            ops.trend_scan(_counts(10), 0)

    def test_negative_counts_are_a_domain_violation(self):
        # PallasDomainError (not a plain ValueError) so the metrics layer
        # falls back to numpy instead of diverging between backends
        with pytest.raises(ops.PallasDomainError):
            ops.trend_scan(np.array([1, -2, 3]), 2)
        qs = [np.array([5, -3, 2, 1]), np.array([1, 2, 3, 4])]
        np.testing.assert_array_equal(
            trend_correlation_matrix(qs, 2, backend="pallas"),
            trend_correlation_matrix(qs, 2, backend="numpy"))

    def test_domain_guard_raises(self):
        # total past 2**31 would wrap the int32 prefix sum -> refuse
        with pytest.raises(ops.PallasDomainError):
            ops.trend_scan(np.array([2 ** 31 - 1, 5], np.int64), 3)


class TestCorrelationMatrix:
    def _qs(self):
        base = _counts(3600, seed=1)
        phase = np.roll(base, 600)
        noise = _counts(1200, seed=2)
        return [base, phase, noise]

    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    def test_symmetry_and_unit_diagonal(self, backend):
        m = trend_correlation_matrix(self._qs(), 60, backend=backend)
        assert m.shape == (3, 3)
        np.testing.assert_array_equal(m, m.T)
        np.testing.assert_array_equal(np.diag(m), np.ones(3))
        assert (np.abs(m) <= 1.0).all()

    def test_backends_agree_within_tolerance(self):
        mn = trend_correlation_matrix(self._qs(), 60, backend="numpy")
        mp = trend_correlation_matrix(self._qs(), 60, backend="pallas")
        np.testing.assert_allclose(mn, mp, atol=1e-3)

    def test_pair_entry_matches_pairwise_host_convention(self):
        # with the default grid (shortest series) and S = 2 the matrix
        # reproduces trend_correlation_from_counts
        qa, qb = _counts(3600, seed=3), _counts(900, seed=4)
        host = trend_correlation_from_counts(qa, qb, 60)
        for backend in ("numpy", "pallas"):
            m = trend_correlation_matrix([qa, qb], 60, backend=backend)
            assert m[0, 1] == pytest.approx(host, abs=1e-3)

    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    def test_empty_and_zero_variance_rows_are_nan(self, backend):
        # empty series + all-zero counts (the zero-padded "same"-mode edges
        # give a CONSTANT series a ramping trend, so only all-zero counts
        # have truly zero trend variance)
        qs = [_counts(600, seed=5), np.zeros(0, np.int64),
              np.zeros(300, np.int64)]
        m = trend_correlation_matrix(qs, 60, backend=backend)
        assert np.isnan(m[1]).all() and np.isnan(m[:, 1]).all()
        assert np.isnan(m[2]).all() and np.isnan(m[:, 2]).all()
        assert m[0, 0] == 1.0

    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    def test_n_points_override(self, backend):
        qs = [_counts(3600, seed=6), _counts(1800, seed=7)]
        m = trend_correlation_matrix(qs, 60, n_points=256, backend=backend)
        ref_m = _corr_matrix_numpy([np.asarray(q) for q in qs], 60, 256)
        np.testing.assert_allclose(m, ref_m, atol=1e-3)

    def test_bad_window_rejected(self):
        with pytest.raises(ValueError):
            trend_correlation_matrix([_counts(10)], 0)

    def test_out_of_domain_falls_back_to_numpy(self):
        # totals past the int32 scan domain must produce the numpy answer,
        # not an error and not a silently wrong device result
        qs = [np.array([2 ** 31 - 1, 5, 9], np.int64), _counts(3, seed=8)]
        m = trend_correlation_matrix(qs, 2, backend="pallas")
        np.testing.assert_array_equal(
            m, trend_correlation_matrix(qs, 2, backend="numpy"))

    def test_pallas_path_never_runs_host_cumsum(self, monkeypatch):
        # the acceptance criterion: no host cumsum / per-pair loop in the
        # device path — sliding_mean (the host cumsum) must never fire
        import repro.streamsim.metrics as metrics

        def _boom(*a, **k):
            raise AssertionError("host sliding_mean used in pallas path")

        monkeypatch.setattr(metrics, "sliding_mean", _boom)
        m = trend_correlation_matrix(self._qs(), 60, backend="pallas")
        assert np.isfinite(m).all()
        with pytest.raises(AssertionError):
            trend_correlation_matrix(self._qs(), 60, backend="numpy")


class TestTrendFallback:
    def test_trend_falls_back_when_ops_rejects(self, monkeypatch):
        from repro.streamsim import make_stream, preprocess
        from repro.streamsim.metrics import trend

        def _reject(*a, **k):
            raise ops.PallasDomainError("forced for test")

        monkeypatch.setattr(ops, "trend_scan", _reject)
        s = preprocess(make_stream("traffic", scale=0.005, seed=2))
        np.testing.assert_allclose(trend(s, 60, backend="pallas"),
                                   trend(s, 60, backend="numpy"),
                                   rtol=1e-12)


class TestRunManyFidelity:
    @staticmethod
    def _consumer(queue):
        return {"records_seen": sum(len(b) for b in queue)}

    def test_one_matrix_dispatch_per_sweep(self, tmp_path, monkeypatch):
        # S×S fidelity comes from ONE batched matrix call per max_range —
        # not a per-pair (or per-scenario) host loop (the matrix call
        # lives in the engine's report layer since the plan/engine split)
        import repro.streamsim.engine as engine

        calls = []
        real = engine.trend_correlation_matrix

        def _counting(counts, *a, **k):
            calls.append(len(counts))
            return real(counts, *a, **k)

        monkeypatch.setattr(engine, "trend_correlation_matrix",
                            _counting)
        datasets, max_ranges = ["traffic", "sogouq"], [40, 80]
        c = Controller(str(tmp_path / "fid"))
        reports = c.run_many(datasets, max_ranges, self._consumer,
                             scale=0.002, seed=9)
        assert len(reports) == len(datasets) * len(max_ranges)
        assert calls == [2 * len(datasets)] * len(max_ranges)

        assert len(c.last_fidelity) == len(max_ranges)
        for fr, mr in zip(c.last_fidelity, max_ranges):
            m = np.asarray(fr.trend_corr)
            S = 2 * len(datasets)
            assert fr.max_range == mr and m.shape == (S, S)
            assert fr.labels[:len(datasets)] == \
                [f"{d}/original" for d in datasets]
            np.testing.assert_array_equal(m, m.T)
            np.testing.assert_allclose(np.diag(m), 1.0)
        # persisted one JSON per sweep, outside list_metrics()'s glob
        assert len(c.list_fidelity()) == len(max_ranges)
        assert len(c.list_metrics()) == len(reports)
        loaded = c.load_fidelity()
        assert sorted(d["max_range"] for d in loaded) == sorted(max_ranges)

    def test_fidelity_json_is_strict(self, tmp_path):
        # NaN entries (empty / zero-variance streams) must serialize as
        # null — bare NaN tokens are not valid JSON
        import json

        from repro.streamsim.controller import FidelityReport

        c = Controller(str(tmp_path / "strict"))
        fr = FidelityReport(60, 60, ["a", "b"],
                            [[1.0, float("nan")], [float("nan"), 1.0]])
        path = c.save_fidelity(fr)

        def _no_constants(s):
            raise AssertionError(f"non-strict JSON token {s!r}")

        loaded = json.loads(path.read_text(), parse_constant=_no_constants)
        assert loaded["trend_corr"] == [[1.0, None], [None, 1.0]]
