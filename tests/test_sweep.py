"""Single-dispatch sweep tests: range-padded NSA over the (dataset ×
max_range) grid, multi-queue batched PSDA replay, and the Controller
integration (ONE NSA dispatch + ONE replay loop per ``run_many`` sweep).

Contracts under test:
- ``nsa_sweep`` is bit-identical per scenario to the per-range
  ``nsa_batched`` / per-scenario ``nsa`` paths, for every backend, across
  ragged bucket counts (including ``max_range = 1`` and rows whose table
  tail is > 90 % padding);
- ``MultiQueueProducer`` is consumer-observation-equivalent to sequential
  ``Producer.run`` per scenario (bucket sequence, emit_time stamps, queue
  stats, producer stats);
- ``Controller.run_many`` performs exactly ONE device NSA dispatch and ONE
  producer virtual-time loop for a whole sweep (monkeypatch-counted).
"""

import threading
import time

import numpy as np
import pytest

from repro.streamsim import (
    Controller,
    Producer,
    StreamQueue,
    VirtualClock,
    make_stream,
    nsa,
    nsa_batched,
    preprocess,
)
from repro.streamsim.nsa import nsa_sweep
from repro.streamsim.producer import MultiQueueProducer
from repro.streamsim.queue import QueueGroup


def _streams(scale=0.005, seed=13):
    return {name: preprocess(make_stream(name, scale=scale, seed=seed))
            for name in ("sogouq", "traffic", "userbehavior")}


def _streams_equal(a, b):
    assert np.array_equal(a.t, b.t)
    assert np.array_equal(a.scale_stamp, b.scale_stamp)
    assert set(a.payload) == set(b.payload)
    for k in a.payload:
        assert np.array_equal(a.payload[k], b.payload[k])


# --------------------------------------------------------- range-padded NSA
class TestNSASweep:
    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    def test_bit_identical_to_per_range_batched(self, backend):
        # ragged bucket counts in ONE dispatch: max_range = 1 (a single
        # bucket), 20 (> 90 % of the 600-wide table is masked tail), 600
        streams = _streams()
        max_ranges = [1, 20, 600]
        sweep = nsa_sweep(streams, max_ranges, backend=backend)
        assert set(sweep) == {(n, mr) for n in streams for mr in max_ranges}
        for mr in max_ranges:
            per_range = nsa_batched(streams, mr, backend=backend)
            for name in streams:
                _streams_equal(sweep[(name, mr)], per_range[name])

    def test_backends_bit_identical(self):
        streams = _streams(scale=0.002, seed=7)
        a = nsa_sweep(streams, [7, 600], backend="pallas")
        b = nsa_sweep(streams, [7, 600], backend="numpy")
        for key in a:
            _streams_equal(a[key], b[key])

    def test_pairs_subset(self):
        # the Controller passes only store-missing scenarios
        streams = _streams(scale=0.002, seed=3)
        pairs = [("traffic", 40), ("sogouq", 600)]
        out = nsa_sweep(streams, [], pairs=pairs, backend="pallas")
        assert set(out) == set(pairs)
        for name, mr in pairs:
            _streams_equal(out[(name, mr)], nsa(streams[name], mr))

    def test_bad_max_range_rejected(self):
        streams = _streams(scale=0.002, seed=3)
        with pytest.raises(ValueError):
            nsa_sweep(streams, [600, 0])

    def test_out_of_domain_falls_back_to_numpy(self):
        # a giant single bucket ((c-1)*k >= 2**31) poisons the device sweep;
        # it must fall back to the numpy path wholesale, bit-identically
        from repro.streamsim.preprocess import Stream
        streams = {
            "burst": Stream("burst", np.full(100_000, 5.0),
                            {"x": np.arange(100_000)}),
            "ok": _streams(scale=0.002, seed=3)["traffic"],
        }
        out = nsa_sweep(streams, [600], backend="pallas")
        for name, s in streams.items():
            _streams_equal(out[(name, 600)], nsa(s, 600, backend="numpy"))

    def test_empty_stream_falls_back(self):
        from repro.streamsim.preprocess import Stream
        streams = {"empty": Stream("empty", np.zeros(0), {}),
                   "ok": _streams(scale=0.002, seed=3)["traffic"]}
        out = nsa_sweep(streams, [60], backend="pallas")
        assert len(out[("empty", 60)]) == 0
        _streams_equal(out[("ok", 60)], nsa(streams["ok"], 60))


class TestOpsPerRowRanges:
    def test_per_row_ranges_equal_single_dispatches(self):
        # the ops layer: one call with a per-row max_range vector must be
        # bit-identical, row by row, to per-row single-range dispatches
        from repro.kernels import ops
        rng = np.random.default_rng(0)
        ts = [np.sort(rng.uniform(0, 86_400.0, n))
              for n in (100, 5000, 1237)]
        ranges = [1, 37, 600]
        mults = [86_400.0 / mr for mr in ranges]
        ss_b, keep_b, lens = ops.stream_sample_batched(ts, ranges, mults)
        for s, t in enumerate(ts):
            ss_1, keep_1 = ops.stream_sample(t, ranges[s], mults[s])
            n = lens[s]
            np.testing.assert_array_equal(np.asarray(ss_b[s, :n]),
                                          np.asarray(ss_1))
            np.testing.assert_array_equal(np.asarray(keep_b[s, :n]),
                                          np.asarray(keep_1))
            assert not np.asarray(keep_b[s, n:]).any()

    def test_nonpositive_range_rejected(self):
        from repro.kernels import ops
        with pytest.raises(ValueError):
            ops.stream_sample_batched([np.arange(10.0)], [0], [1.0])


# ------------------------------------------------------- multi-queue replay
class TestMultiQueueProducer:
    def _sims(self, max_ranges=(7, 40, 5000)):
        s = preprocess(make_stream("traffic", scale=0.003, seed=5))
        return {("traffic", mr): nsa(s, mr) for mr in max_ranges}

    def test_equivalent_to_sequential_runs(self):
        # per scenario: same bucket sequence, same emit_time stamps, same
        # queue stats, same producer stats as a sequential Producer.run
        sims = self._sims()
        group = QueueGroup(sims, maxsize=100_000)
        mp = MultiQueueProducer(sims, group.queues, clock=VirtualClock())
        assert mp.run() == 0
        for key, sim in sims.items():
            q_ref = StreamQueue(maxsize=100_000)
            p_ref = Producer(sim, q_ref, clock=VirtualClock())
            assert p_ref.run() == 0
            got, exp = list(group[key]), list(q_ref)
            assert [b.scale_stamp for b in got] == \
                [b.scale_stamp for b in exp]
            assert [b.emit_time for b in got] == [b.emit_time for b in exp]
            assert group[key].stats() == q_ref.stats()
            assert mp.stats(key) == p_ref.stats()

    def test_shared_backpressure_with_concurrent_consumers(self):
        # tiny bounded queues: the single loop must stall on a full queue
        # and still deliver everything once consumers drain concurrently
        sims = self._sims((30, 60))
        group = QueueGroup(sims, maxsize=2)
        mp = MultiQueueProducer(sims, group.queues)
        got = {}

        def drain(key):
            got[key] = sum(len(b) for b in group[key])

        consumers = [threading.Thread(target=drain, args=(k,), daemon=True)
                     for k in sims]
        producer = threading.Thread(target=mp.run, daemon=True)
        for th in consumers + [producer]:
            th.start()
        for th in consumers + [producer]:
            th.join(timeout=30)
            assert not th.is_alive()
        for key, sim in sims.items():
            assert got[key] == len(sim)

    def test_scenario_queue_closes_at_its_last_bucket(self):
        # a short scenario's consumer must not wait for the sweep to end
        sims = self._sims((7, 5000))
        group = QueueGroup(sims, maxsize=100_000)
        mp = MultiQueueProducer(sims, group.queues)
        assert mp.run() == 0
        short = ("traffic", 7)
        assert group[short].get() is not None  # buckets + close both landed

    def test_mismatched_keys_rejected(self):
        sims = self._sims((7,))
        with pytest.raises(ValueError):
            MultiQueueProducer(sims, {"other": StreamQueue()})

    def test_real_clock_timer_wheel_equivalent_to_virtual(self):
        # the wall-clock wheel (heap of due times, one loop for S queues)
        # must deliver per scenario exactly what the virtual-clock walk
        # delivers: same bucket sequence, same queue stats, same producer
        # stats — only emit_time becomes wall time
        from repro.streamsim.producer import RealClock
        sims = self._sims((7, 23))
        group = QueueGroup(sims, maxsize=100_000)
        mp = MultiQueueProducer(sims, group.queues, clock=RealClock(),
                                tick_s=0.002)
        got = {}

        def drain(key):
            got[key] = [b.scale_stamp for b in group[key]]

        consumers = [threading.Thread(target=drain, args=(k,), daemon=True)
                     for k in sims]
        producer = threading.Thread(target=mp.run, daemon=True)
        for th in consumers + [producer]:
            th.start()
        for th in consumers + [producer]:
            th.join(timeout=30)
            assert not th.is_alive()
        for key, sim in sims.items():
            q_ref = StreamQueue(maxsize=100_000)
            p_ref = Producer(sim, q_ref, clock=VirtualClock())
            assert p_ref.run() == 0
            assert got[key] == [b.scale_stamp for b in q_ref]
            assert mp.stats(key) == p_ref.stats()
            assert group[key].stats() == q_ref.stats()

    def test_real_clock_wheel_respects_due_times(self):
        # bucket b must not fire before (b + 1) ticks of wall time
        from repro.streamsim.producer import RealClock
        sims = self._sims((5,))
        group = QueueGroup(sims, maxsize=100_000)
        tick = 0.005
        mp = MultiQueueProducer(sims, group.queues, clock=RealClock(),
                                tick_s=tick)
        t0 = time.monotonic()
        producer = threading.Thread(target=mp.run, daemon=True)
        producer.start()
        buckets = list(group[("traffic", 5)])
        producer.join(timeout=30)
        elapsed = time.monotonic() - t0
        last = max(b.scale_stamp for b in buckets)
        # the last bucket is due at (last + 1) * tick of wall time; allow
        # generous scheduler slack below but the wheel must not finish
        # early
        assert elapsed >= (last + 1) * tick * 0.9

    def test_queue_group_stats_keys(self):
        sims = self._sims((7, 40))
        group = QueueGroup(sims, maxsize=10)
        assert set(group.stats()) == set(sims)
        assert len(group) == 2


# ------------------------------------------------------ controller sweeps
class TestRunManySingleDispatch:
    @staticmethod
    def _consumer(queue):
        return {"records_seen": sum(len(b) for b in queue)}

    def test_one_nsa_dispatch_and_one_replay_loop(self, tmp_path,
                                                  monkeypatch):
        # the acceptance assertion: on a ONE-device plan a (3 datasets × 6
        # max_ranges) grid must cost exactly ONE device NSA dispatch and
        # ONE producer loop (n_devices pinned: other tests in the suite
        # force multi-device topologies via XLA_FLAGS, and the planner
        # then shards by design — see test_plan_engine.py)
        import repro.kernels.stream_sample as sskern
        import repro.streamsim.producer as prod

        dispatches = []
        real_kernel = sskern.stream_sample_pallas

        def counting_kernel(*args, **kwargs):
            dispatches.append(args[0].shape)
            return real_kernel(*args, **kwargs)

        monkeypatch.setattr(sskern, "stream_sample_pallas", counting_kernel)
        # ops imported the symbol by value — patch its reference too
        import repro.kernels.ops as ops_mod
        monkeypatch.setattr(ops_mod, "stream_sample_pallas", counting_kernel)

        loops = []
        real_run = prod.MultiQueueProducer.run

        def counting_run(self):
            loops.append(len(self.streams))
            return real_run(self)

        monkeypatch.setattr(prod.MultiQueueProducer, "run", counting_run)

        datasets = ["sogouq", "traffic", "userbehavior"]
        max_ranges = [10, 20, 30, 40, 50, 60]
        c = Controller(str(tmp_path / "store"))
        reports = c.run_many(datasets, max_ranges, self._consumer,
                             scale=0.002, seed=9, backend="pallas",
                             n_devices=1)
        assert len(reports) == 18
        assert len(dispatches) == 1, \
            f"expected ONE NSA device dispatch, saw {len(dispatches)}"
        assert dispatches[0][0] == 18, "all 18 scenarios in the one launch"
        assert len(loops) == 1, \
            f"expected ONE producer virtual-time loop, saw {len(loops)}"
        assert loops[0] == 18, "all 18 scenarios in the one replay loop"

    def test_sweep_report_equivalent_to_run(self, tmp_path):
        # the single-dispatch sweep must still report exactly what
        # sequential per-scenario Controller.run reports
        datasets, max_ranges = ["traffic", "sogouq"], [40, 80]
        c = Controller(str(tmp_path / "sweep"))
        reports = c.run_many(datasets, max_ranges, self._consumer,
                             scale=0.002, seed=9)
        ref_c = Controller(str(tmp_path / "sequential"))
        for r in reports:
            ref = ref_c.run(r.dataset, r.max_range, self._consumer,
                            scale=0.002, seed=9)
            assert r.simulated_rows == ref.simulated_rows
            assert r.trend_corr == pytest.approx(ref.trend_corr, rel=1e-9)
            for key in ("records_seen", "records_in", "buckets_in",
                        "bytes_in", "emitted_buckets", "emitted_records"):
                assert r.consumer_metrics[key] == ref.consumer_metrics[key]

    def test_consumer_exception_propagates(self, tmp_path):
        def bad_consumer(queue):
            raise RuntimeError("consumer exploded")

        c = Controller(str(tmp_path / "store"))
        with pytest.raises(RuntimeError, match="consumer exploded"):
            c.run_many(["traffic"], [40], bad_consumer, scale=0.002, seed=9)

    def test_all_consumer_failures_aggregated(self, tmp_path):
        # a multi-consumer failure must surface EVERY failed scenario in
        # one RuntimeError (no error swallowed), with the per-scenario
        # exceptions chained via __cause__ in scenario order
        fails = {("traffic", 20), ("traffic", 60)}

        def consumer_factory(queue):
            # identify the scenario by its largest scale stamp (== mr - 1)
            buckets = list(queue)
            mr = buckets[-1].scale_stamp + 1 if buckets else 0
            if ("traffic", mr) in fails:
                raise ValueError(f"scenario {mr} exploded")
            return {"records_seen": sum(len(b) for b in buckets)}

        c = Controller(str(tmp_path / "store"))
        with pytest.raises(RuntimeError) as ei:
            c.run_many(["traffic"], [20, 40, 60], consumer_factory,
                       scale=0.002, seed=9)
        msg = str(ei.value)
        assert "2 of 3" in msg
        assert "('traffic', 20)" in msg and "('traffic', 60)" in msg
        assert "('traffic', 40)" not in msg
        # __cause__ chain: first failed scenario outermost, second behind it
        cause = ei.value.__cause__
        assert isinstance(cause, ValueError)
        assert "scenario 20" in str(cause)
        assert isinstance(cause.__cause__, ValueError)
        assert "scenario 60" in str(cause.__cause__)
