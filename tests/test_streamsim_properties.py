"""Property-based NSA tests (hypothesis). Skipped wholesale when hypothesis
is not installed (``pip install -r requirements-dev.txt``); the deterministic
suite in ``test_streamsim.py`` runs regardless."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.streamsim import nsa, nsa_paper
from repro.streamsim.nsa import systematic_keep_mask
from repro.streamsim.preprocess import Stream


@st.composite
def sorted_timestamps(draw):
    n = draw(st.integers(min_value=2, max_value=400))
    deltas = draw(st.lists(st.floats(0.0, 50.0, allow_nan=False),
                           min_size=n, max_size=n))
    t0 = draw(st.floats(0, 1e9, allow_nan=False))
    t = np.cumsum(np.asarray(deltas, np.float64)) + t0
    return t


class TestNSAProperties:
    @settings(max_examples=60, deadline=None)
    @given(t=sorted_timestamps(), max_range=st.integers(2, 200))
    def test_invariants(self, t, max_range):
        s = Stream("h", t, {"x": np.arange(len(t))})
        d = nsa(s, max_range)
        # 1. output is a subsequence (order + subset)
        assert np.all(np.diff(d.t) >= 0)
        xs = d.payload["x"]
        assert np.all(np.diff(xs) > 0)
        # 2. scale stamps bounded + non-decreasing
        if len(d):
            assert d.scale_stamp.min() >= 0
            assert d.scale_stamp.max() < max_range
            assert np.all(np.diff(d.scale_stamp) >= 0)
        # 3. never drops everything, never grows
        assert 1 <= len(d) <= len(s)
        # 4. deterministic
        d2 = nsa(s, max_range)
        assert np.array_equal(d.t, d2.t)

    @settings(max_examples=30, deadline=None)
    @given(t=sorted_timestamps(), max_range=st.integers(2, 100))
    def test_paper_loop_agrees(self, t, max_range):
        s = Stream("h", t, {"x": np.arange(len(t))})
        a, b = nsa(s, max_range), nsa_paper(s, max_range)
        assert np.array_equal(a.t, b.t)

    @settings(max_examples=10, deadline=None)
    @given(t=sorted_timestamps(), max_range=st.sampled_from([3, 60, 600]))
    def test_pallas_backend_agrees(self, t, max_range):
        s = Stream("h", t, {"x": np.arange(len(t))})
        a = nsa(s, max_range, backend="pallas")
        b = nsa(s, max_range, backend="numpy")
        assert np.array_equal(a.t, b.t)
        assert np.array_equal(a.scale_stamp, b.scale_stamp)

    @settings(max_examples=30, deadline=None)
    @given(counts=st.lists(st.integers(0, 50), min_size=1, max_size=60),
           mult=st.floats(1.0, 40.0))
    def test_keep_mask_counts(self, counts, mult):
        # per bucket with c records, exactly clip(round(c/mult),1) survive
        ss = np.repeat(np.arange(len(counts)), counts)
        mask = systematic_keep_mask(ss, len(counts), mult)
        kept = np.bincount(ss[mask], minlength=len(counts))
        for b, c in enumerate(counts):
            if c:
                assert kept[b] == max(int(round(c / mult)), 1)
            else:
                assert kept[b] == 0
