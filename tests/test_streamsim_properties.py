"""Property-based NSA + stream-task tests (hypothesis). Skipped wholesale
when hypothesis is not installed (``pip install -r requirements-dev.txt``);
the deterministic suites in ``test_streamsim.py``/``test_tasks.py`` run
regardless."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.streamsim import nsa, nsa_paper
from repro.streamsim.nsa import systematic_keep_mask
from repro.streamsim.preprocess import Stream
from repro.streamsim.queue import Bucket, StreamQueue
from repro.streamsim.tasks import EventDetectTask, WindowedStatsTask


@st.composite
def sorted_timestamps(draw):
    n = draw(st.integers(min_value=2, max_value=400))
    deltas = draw(st.lists(st.floats(0.0, 50.0, allow_nan=False),
                           min_size=n, max_size=n))
    t0 = draw(st.floats(0, 1e9, allow_nan=False))
    t = np.cumsum(np.asarray(deltas, np.float64)) + t0
    return t


class TestNSAProperties:
    @settings(max_examples=60, deadline=None)
    @given(t=sorted_timestamps(), max_range=st.integers(2, 200))
    def test_invariants(self, t, max_range):
        s = Stream("h", t, {"x": np.arange(len(t))})
        d = nsa(s, max_range)
        # 1. output is a subsequence (order + subset)
        assert np.all(np.diff(d.t) >= 0)
        xs = d.payload["x"]
        assert np.all(np.diff(xs) > 0)
        # 2. scale stamps bounded + non-decreasing
        if len(d):
            assert d.scale_stamp.min() >= 0
            assert d.scale_stamp.max() < max_range
            assert np.all(np.diff(d.scale_stamp) >= 0)
        # 3. never drops everything, never grows
        assert 1 <= len(d) <= len(s)
        # 4. deterministic
        d2 = nsa(s, max_range)
        assert np.array_equal(d.t, d2.t)

    @settings(max_examples=30, deadline=None)
    @given(t=sorted_timestamps(), max_range=st.integers(2, 100))
    def test_paper_loop_agrees(self, t, max_range):
        s = Stream("h", t, {"x": np.arange(len(t))})
        a, b = nsa(s, max_range), nsa_paper(s, max_range)
        assert np.array_equal(a.t, b.t)

    @settings(max_examples=10, deadline=None)
    @given(t=sorted_timestamps(), max_range=st.sampled_from([3, 60, 600]))
    def test_pallas_backend_agrees(self, t, max_range):
        s = Stream("h", t, {"x": np.arange(len(t))})
        a = nsa(s, max_range, backend="pallas")
        b = nsa(s, max_range, backend="numpy")
        assert np.array_equal(a.t, b.t)
        assert np.array_equal(a.scale_stamp, b.scale_stamp)

    @settings(max_examples=30, deadline=None)
    @given(counts=st.lists(st.integers(0, 50), min_size=1, max_size=60),
           mult=st.floats(1.0, 40.0))
    def test_keep_mask_counts(self, counts, mult):
        # per bucket with c records, exactly clip(round(c/mult),1) survive
        ss = np.repeat(np.arange(len(counts)), counts)
        mask = systematic_keep_mask(ss, len(counts), mult)
        kept = np.bincount(ss[mask], minlength=len(counts))
        for b, c in enumerate(counts):
            if c:
                assert kept[b] == max(int(round(c / mult)), 1)
            else:
                assert kept[b] == 0


# ------------------------------------------------------- stream-task tier
def _bucket(stamp, count):
    return Bucket(scale_stamp=int(stamp),
                  t=np.full(int(count), float(stamp)),
                  payload={"v": np.ones(int(count))}, emit_time=0.0)


def _queue_of(buckets):
    q = StreamQueue(maxsize=max(len(buckets), 1))
    for b in buckets:
        q.put(b)
    q.close()
    return q


def _tumbling_oracle(q, w):
    """O(n*w) literal tumbling mean (true-length trailing window)."""
    return np.array([np.mean(q[i:i + w]) for i in range(0, len(q), w)])


def _sliding_oracle(q, w):
    """O(n*w) literal sliding mean — constant 1/w weight, zero-padded
    edges, window [i - (w - half - 1), i + half] (the convolve
    mode=\"same\" convention sliding_mean promises; for even windows the
    extra element sits on the LEFT)."""
    n = len(q)
    w = max(min(w, n), 1)
    half = (w - 1) // 2
    out = np.empty(n)
    for i in range(n):
        lo, hi = i + half + 1 - w, i + half + 1
        out[i] = q[max(lo, 0):min(hi, n)].sum() / w
    return out


class TestWindowedStatsProperties:
    @settings(max_examples=50, deadline=None)
    @given(counts=st.lists(st.integers(0, 40), min_size=1, max_size=200),
           window=st.integers(1, 50))
    def test_sliding_vs_quadratic_oracle(self, counts, window):
        q = np.asarray(counts, np.float64)
        task = WindowedStatsTask(window_s=window)
        np.testing.assert_allclose(task.aggregate(q),
                                   _sliding_oracle(q, window), atol=1e-9)

    @settings(max_examples=50, deadline=None)
    @given(counts=st.lists(st.integers(0, 40), min_size=1, max_size=200),
           window=st.integers(1, 50))
    def test_tumbling_vs_quadratic_oracle(self, counts, window):
        q = np.asarray(counts, np.float64)
        task = WindowedStatsTask(window_s=window, mode="tumbling")
        np.testing.assert_allclose(task.aggregate(q),
                                   _tumbling_oracle(q, window), atol=1e-9)


@st.composite
def reordered_buckets(draw):
    """(in-order buckets, reordered buckets, window): a bucket-preserving
    reorder displacing every bucket < window positions (the fault layer's
    bounded-reorder contract)."""
    counts = draw(st.lists(st.integers(0, 12), min_size=2, max_size=120))
    window = draw(st.integers(1, 10))
    buckets = [_bucket(i, c) for i, c in enumerate(counts)]
    shuffled = []
    for i in range(0, len(buckets), window):
        block = list(buckets[i:i + window])
        perm = draw(st.permutations(range(len(block))))
        shuffled.extend(block[j] for j in perm)
    return buckets, shuffled, window


class TestEventDetectProperties:
    @settings(max_examples=40, deadline=None)
    @given(data=reordered_buckets(), drift=st.floats(0.0, 2.0),
           h=st.floats(0.5, 10.0))
    def test_cusum_invariant_with_watermark(self, data, drift, h):
        """CUSUM detection with reorder_tolerance >= the reorder window is
        INVARIANT under any bucket-preserving reorder inside that window:
        the watermark heap re-sorts a w-displaced arrival sequence
        exactly."""
        ordered, shuffled, window = data
        kw = dict(mode="cusum", drift=drift, h=h,
                  reorder_tolerance=window)
        a = EventDetectTask(**kw)(_queue_of(ordered))
        b = EventDetectTask(**kw)(_queue_of(shuffled))
        assert a["task_events"].tolist() == b["task_events"].tolist()
        assert a["detect_events"] == b["detect_events"]

    @settings(max_examples=40, deadline=None)
    @given(data=reordered_buckets(), threshold=st.floats(0.0, 12.0))
    def test_threshold_event_set_invariant(self, data, threshold):
        """Threshold detection stamps events with the triggering bucket's
        own scale stamp, so the event SET survives ANY reorder even with
        no watermark buffer."""
        ordered, shuffled, _ = data
        a = EventDetectTask(mode="threshold",
                            threshold=threshold)(_queue_of(ordered))
        b = EventDetectTask(mode="threshold",
                            threshold=threshold)(_queue_of(shuffled))
        assert sorted(a["task_events"]) == sorted(b["task_events"])


class TestTileChooserProperties:
    """The autotuner's heuristic chooser must emit only configs the
    kernels can actually dispatch: lane-aligned bucket blocks that
    divide the padded bucket axis, sublane-aligned record tiles, and a
    bounded VMEM footprint — for every shape and device kind."""

    @settings(max_examples=120, deadline=None)
    @given(s=st.integers(1, 512),
           n=st.integers(1, 1 << 22),
           r=st.integers(0, 1 << 20),
           kind=st.sampled_from(["cpu-interpret", "tpu-v4", "tpu-v5e",
                                 "gpu-a100", "gpu-h100", "gpu-mi300x"]),
           kernel=st.sampled_from(["stream_sample", "metrics_fused",
                                   "trend_scan", "pair_stats", "compact"]))
    def test_heuristic_config_invariants(self, s, n, r, kind, kernel):
        from repro.kernels import tuning
        key = tuning.TuneKey.from_shape(kernel, s=s, n=n, r=r)
        cfg = tuning.heuristic_config(key, kind)
        # record tile: positive (sublane, LANE) multiple
        assert cfg.record_tile > 0
        assert cfg.record_tile % tuning.MIN_RECORD_TILE == 0
        assert cfg.sublane % 8 == 0
        # bucket block: lane multiple that divides the padded bucket
        # axis (ops pads the axis to a bucket_block multiple, so this
        # is exactly "padded % block == 0")
        assert cfg.bucket_block % tuning.LANE == 0
        if r > 0:
            padded = -(-r // cfg.bucket_block) * cfg.bucket_block
            assert padded % cfg.bucket_block == 0
            assert padded >= r
        # VMEM bound: the one-hot (record_tile, bucket_block) i32 tile
        # fits the budget
        assert cfg.record_tile * cfg.bucket_block * 4 \
            <= tuning.VMEM_BUDGET_BYTES

    @settings(max_examples=60, deadline=None)
    @given(s=st.integers(1, 64), n=st.integers(1, 1 << 20),
           r=st.integers(0, 1 << 18), kind=st.sampled_from(
               ["cpu-interpret", "tpu-v4", "gpu-a100"]))
    def test_candidate_lattice_all_dispatchable(self, s, n, r, kind):
        from repro.kernels import tuning
        key = tuning.TuneKey.from_shape("metrics_fused", s=s, n=n, r=r)
        cands = tuning.candidate_lattice(key, kind)
        assert cands, "lattice always contains the heuristic default"
        assert len(set(cands)) == len(cands), "no duplicate candidates"
        for cfg in cands:
            assert cfg.record_tile % tuning.MIN_RECORD_TILE == 0
            assert cfg.bucket_block % tuning.LANE == 0
            assert cfg.vmem_bytes() <= tuning.VMEM_BUDGET_BYTES
