"""CI link check: every intra-repo link in docs/**/*.md and README.md must
resolve — both the target file/directory and (when given) its heading
anchor. Runs dependency-free so the docs CI job needs only pytest."""

import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
PAGES = sorted(REPO.glob("docs/**/*.md")) + [REPO / "README.md"]
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _anchors(md_path: Path):
    """GitHub-style slugs for every heading in a markdown file."""
    slugs = set()
    for line in md_path.read_text().splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            slug = re.sub(r"[^a-z0-9 \-]", "", m.group(1).strip().lower())
            slugs.add(slug.replace(" ", "-"))
    return slugs


def _links():
    for page in PAGES:
        for target in _LINK.findall(page.read_text()):
            if not target.startswith(("http://", "https://", "mailto:")):
                yield pytest.param(page, target,
                                   id=f"{page.relative_to(REPO)}:{target}")


@pytest.mark.parametrize("page,target", list(_links()))
def test_intra_repo_link_resolves(page, target):
    path, _, anchor = target.partition("#")
    dest = page if not path else (page.parent / path).resolve()
    assert dest.exists(), f"{page.name} links to missing {path}"
    if anchor and dest.suffix == ".md":
        assert anchor in _anchors(dest), \
            f"{page.name} links to missing anchor #{anchor} in {dest.name}"


def test_docs_pages_exist():
    for name in ("architecture.md", "kernels.md", "benchmarks.md",
                 "backends.md", "robustness.md", "tasks.md"):
        assert (REPO / "docs" / name).is_file(), f"docs/{name} missing"
