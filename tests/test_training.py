"""Training substrate tests: optimizer, checkpointing, fault tolerance,
stream-fed loop, gradient compression."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_stream import consumer_lm
from repro.models import transformer as T
from repro.training.checkpoint import CheckpointManager
from repro.training.data import StreamBatcher, SyntheticBatcher
from repro.training.ft import FailureInjector, StragglerMonitor, elastic_plan
from repro.training.optimizer import AdamW, adamw_init, adamw_update
from repro.training.steps import jit_train_step
from repro.training.train_loop import TrainLoop, TrainLoopConfig


def tiny_lm():
    return consumer_lm().replace(n_layers=2, d_model=64, n_heads=4,
                                 n_kv_heads=2, head_dim=16, d_ff=128,
                                 vocab_size=512, loss_chunk=16)


def make_state(cfg, seed=0):
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    return params, adamw_init(params)


class TestOptimizer:
    def test_descends_on_fixed_batch(self):
        cfg = tiny_lm()
        params, opt_state = make_state(cfg)
        opt = AdamW(lr=3e-3, warmup_steps=2, total_steps=60)
        step = jit_train_step(cfg, opt, mesh=None, donate=False)
        batch = next(iter(SyntheticBatcher(4, 32, cfg.vocab_size)))
        losses = []
        for _ in range(25):
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.7, f"no descent: {losses[::6]}"

    def test_grad_clip(self):
        cfg = tiny_lm()
        params, opt_state = make_state(cfg)
        g = jax.tree.map(lambda p: jnp.full(p.shape, 100.0, jnp.float32),
                         params)
        opt = AdamW(grad_clip=1.0)
        _, _, stats = adamw_update(opt, g, opt_state, params)
        assert float(stats["grad_norm"]) > 1.0  # recorded pre-clip


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = tiny_lm()
        params, opt_state = make_state(cfg)
        mgr = CheckpointManager(tmp_path, keep=2)
        mgr.save(3, {"params": params, "opt": opt_state})
        state = mgr.restore({"params": params, "opt": opt_state})
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gc_and_latest(self, tmp_path):
        cfg = tiny_lm()
        params, _ = make_state(cfg)
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"p": params})
        assert mgr.steps() == [3, 4]
        assert mgr.latest_step() == 4

    def test_async_save(self, tmp_path):
        cfg = tiny_lm()
        params, _ = make_state(cfg)
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"p": params}, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"w": jnp.zeros((4, 4))})
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.zeros((8, 8))})


class TestFaultTolerance:
    def _loop(self, tmp_path, injector=None, steps=30, seed=0):
        cfg = tiny_lm()
        params, opt_state = make_state(cfg, seed)
        opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=steps)
        step = jit_train_step(cfg, opt, mesh=None, donate=False)
        batches = iter(SyntheticBatcher(4, 32, cfg.vocab_size, seed=seed))
        mgr = CheckpointManager(tmp_path, keep=3)
        return TrainLoop(step, params, opt_state, batches, mgr,
                         TrainLoopConfig(total_steps=steps,
                                         checkpoint_every=10,
                                         async_checkpoint=False),
                         injector=injector)

    def test_failure_recovery_completes(self, tmp_path):
        inj = FailureInjector({17: "process-death", 23: "device-loss"})
        loop = self._loop(tmp_path / "a", injector=inj)
        summary = loop.run()
        assert summary["final_step"] == 30
        assert summary["restarts"] == 2
        assert np.isfinite(summary["final_loss"])

    def test_straggler_monitor(self):
        mon = StragglerMonitor(tolerance=2.0, window=10)
        for i in range(10):
            mon.observe(i, 0.1)
        assert mon.observe(10, 0.5) is True
        assert mon.observe(11, 0.11) is False
        assert mon.summary()["mitigated"] == 1

    def test_elastic_plan(self):
        # lose a host: 512 -> 480 chips, model axis 16 stays
        shape, per_shard = elastic_plan(480, (2, 16, 16),
                                        ("pod", "data", "model"), 256)
        assert shape[2] == 16
        assert 256 % per_shard == 0
        assert shape[0] * shape[1] * shape[2] <= 480
        with pytest.raises(ValueError):
            elastic_plan(8, (16, 16), ("data", "model"), 256)

    def test_nan_quarantine(self, tmp_path):
        cfg = tiny_lm()
        params, opt_state = make_state(cfg)

        calls = {"n": 0}

        def poisoned_step(p, o, b):
            calls["n"] += 1
            loss = jnp.float32(np.nan if calls["n"] == 3 else 1.0)
            return p, o, {"loss": loss}

        mgr = CheckpointManager(tmp_path)
        loop = TrainLoop(poisoned_step, params, opt_state,
                         iter(SyntheticBatcher(2, 16, cfg.vocab_size)), mgr,
                         TrainLoopConfig(total_steps=5, checkpoint_every=100,
                                         async_checkpoint=False))
        summary = loop.run()
        assert summary["skipped_nan"] == 1
        assert summary["final_step"] == 5


class TestStreamTraining:
    def test_stream_batcher_feeds_loop(self):
        from repro.streamsim import (Producer, StreamQueue, VirtualClock,
                                     make_stream, nsa, preprocess)
        cfg = tiny_lm()
        sim = nsa(preprocess(make_stream("traffic", scale=0.01, seed=3)), 60)
        q = StreamQueue(maxsize=64)
        threading.Thread(
            target=Producer(sim, q, clock=VirtualClock()).run,
            daemon=True).start()
        batcher = StreamBatcher(q, batch=2, seq=32, vocab=cfg.vocab_size)
        batches = list(batcher)
        assert len(batches) >= 3
        for b in batches[:3]:
            assert b["inputs"].shape == (2, 32)
            assert b["inputs"].min() >= 1
            assert b["inputs"].max() < cfg.vocab_size
            # labels are inputs shifted by one position
            np.testing.assert_array_equal(b["inputs"][:, 1:],
                                          b["labels"][:, :-1])


class TestCompression:
    def test_int8_compressed_dp_matches_fp32(self):
        if len(jax.devices()) < 2:
            pytest.skip("needs >=2 devices (run via subprocess test)")

    def test_quantize_roundtrip(self):
        from repro.distributed.compression import dequantize, quantize
        g = jnp.asarray(np.random.default_rng(0).normal(0, 2, (256,)),
                        jnp.float32)
        q, s = quantize(g)
        err = np.abs(np.asarray(dequantize(q, s) - g))
        assert err.max() <= float(s) * 0.5 + 1e-6

    def test_compressed_training_converges_subprocess(self, tmp_path):
        """Run a 2-device DP compressed-gradient training in a subprocess
        (needs its own XLA device-count flag)."""
        import subprocess
        import sys
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.paper_stream import consumer_lm
from repro.models import transformer as T
from repro.distributed.compression import make_compressed_dp_grad, ef_init
from repro.training.optimizer import AdamW, adamw_init, adamw_update
cfg = consumer_lm().replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            head_dim=16, d_ff=128, vocab_size=512,
                            loss_chunk=16)
from repro.launch.mesh import _axis_types_kwargs
mesh = jax.make_mesh((2,), ("data",), **_axis_types_kwargs(1))
params = T.init_params(cfg, jax.random.PRNGKey(0))
ef = ef_init(params)
opt = AdamW(lr=3e-3, warmup_steps=2, total_steps=40)
opt_state = adamw_init(params)
grad_fn = make_compressed_dp_grad(
    lambda p, b: T.loss_fn(cfg, p, b)[0], mesh, "data")
rng = np.random.default_rng(0)
chunk = rng.integers(1, 512, (4, 33), dtype=np.int32)
batch = {"inputs": jnp.asarray(chunk[:, :-1]),
         "labels": jnp.asarray(chunk[:, 1:])}
first = last = None
for i in range(30):
    loss, grads, ef = grad_fn(params, batch, ef)
    params, opt_state, _ = adamw_update(opt, grads, opt_state, params)
    if i == 0: first = float(loss)
    last = float(loss)
assert last < first * 0.7, (first, last)
print("OK", first, last)
"""
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, timeout=600,
                           env={**__import__("os").environ,
                                "PYTHONPATH": "src"},
                           cwd=__import__("pathlib").Path(
                               __file__).parent.parent)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout
