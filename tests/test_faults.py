"""Chaos + resilience layer tests (the robustness PR's acceptance gates).

Contracts under test:

- seeded fault schedules are **deterministic**: same ``(seed, key)`` ⇒
  bit-identical schedule, across runs AND across the sequential-vs-merged
  producer walks (each scenario owns its RNG stream);
- a no-op :class:`FaultPlan` leaves the replay **bit-equal** to the
  fault-free pipeline (stats dict equality, not approximation);
- per-scenario delivery reconciles: ``delivered == emitted - dropped +
  duplicated``, under every fault mix;
- ``StreamQueue.close()`` wakes producers blocked in ``put()`` — on a
  full queue AND on the group byte budget — with ``RuntimeError("queue
  closed")`` instead of a hang;
- a wedged consumer surfaces as a *named* ``TimeoutError`` under
  ``consumer_deadline_s`` while sibling scenarios complete;
- transient injected consumer crashes heal via :class:`RetryPolicy`;
  persistent ones trip the :class:`CircuitBreaker` and degrade to
  ``status="partial"`` reports under ``on_failure="degrade"``;
- a sweep killed after k reports resumes via checkpoint markers with
  reports equal to an uninterrupted run.

Hang-prone tests carry ``@pytest.mark.timeout`` — enforced in CI's
chaos-smoke job via pytest-timeout (a no-op marker locally).
"""

import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.streamsim import (
    ByteBudget,
    CircuitBreaker,
    Controller,
    Deadline,
    EventDetectTask,
    FaultPlan,
    FaultSpec,
    MultiQueueProducer,
    Producer,
    QueueGroup,
    RetryPolicy,
    StreamQueue,
    StreamStore,
    SweepCheckpoint,
    VirtualClock,
    make_stream,
    nsa,
    preprocess,
)
from repro.streamsim import engine
from repro.streamsim.faults import InjectedConsumerCrash
from repro.streamsim.queue import Bucket

CHAOS = FaultSpec(drop_rate=0.2, duplicate_rate=0.15, reorder_rate=0.25,
                  reorder_window=3, delay_jitter_s=0.01, stall_rate=0.05,
                  stall_s=0.02)


def _sims(max_ranges=(20, 40, 60), scale=0.002, seed=9):
    s = preprocess(make_stream("traffic", scale=scale, seed=seed))
    return {("traffic", mr): nsa(s, mr) for mr in max_ranges}


def _bucket(stamp=0, n=4):
    t = np.arange(float(n))
    return Bucket(scale_stamp=stamp, t=t, payload={"x": t.copy()},
                  emit_time=0.0)


def _drain(queue):
    return {"records_seen": sum(len(b) for b in queue)}


def _reconciles(m):
    return m["buckets_in"] == (m["emitted_buckets"]
                               - m.get("fault_dropped", 0)
                               + m.get("fault_duplicated", 0))


# ------------------------------------------------------------- determinism
class TestScheduleDeterminism:
    def test_same_seed_same_schedule(self):
        a = FaultPlan(7, default=CHAOS).injector(("traffic", 40))
        b = FaultPlan(7, default=CHAOS).injector(("traffic", 40))
        for _ in range(500):
            assert a.draw() == b.draw()
        assert a.stats() == b.stats()

    def test_different_seed_or_key_differs(self):
        base = [FaultPlan(7, default=CHAOS).injector(("traffic", 40)).draw()
                for _ in range(200)]
        other_seed = FaultPlan(8, default=CHAOS).injector(("traffic", 40))
        other_key = FaultPlan(7, default=CHAOS).injector(("traffic", 60))
        assert [other_seed.draw() for _ in range(200)] != base
        assert [other_key.draw() for _ in range(200)] != base

    def test_drop_schedule_stable_under_other_rates(self):
        # fixed draw order: changing duplicate_rate must not shift WHICH
        # buckets the drop schedule selects
        def drops(spec):
            inj = FaultPlan(3, default=spec).injector("k")
            return [i for i in range(300) if inj.draw().drop]

        only_drop = FaultSpec(drop_rate=0.3)
        with_dups = FaultSpec(drop_rate=0.3, duplicate_rate=0.5,
                              reorder_rate=0.2)
        assert drops(only_drop) == drops(with_dups)

    def test_reset_rewinds_schedule(self):
        inj = FaultPlan(7, default=CHAOS).injector("k")
        first = [inj.draw() for _ in range(100)]
        inj.reset()
        assert [inj.draw() for _ in range(100)] == first
        assert inj.next_attempt() == 1
        inj.reset()
        assert inj.next_attempt() == 2   # attempts survive reset

    @pytest.mark.timeout(60)
    def test_merged_walk_matches_sequential_schedule(self):
        # per scenario, the interleaved MultiQueueProducer walk must apply
        # the EXACT schedule a sequential Producer replay applies
        sims = _sims()
        plan_a = FaultPlan(11, default=CHAOS)
        group = QueueGroup(sims, maxsize=1_000_000)
        mp = MultiQueueProducer(sims, group.queues, clock=VirtualClock(),
                                fault_plan=plan_a)
        assert mp.run() == 0
        for key, sim in sims.items():
            plan_b = FaultPlan(11, default=CHAOS)
            q_ref = StreamQueue(maxsize=1_000_000)
            p_ref = Producer(sim, q_ref, clock=VirtualClock(),
                             faults=plan_b.injector(key))
            assert p_ref.run() == 0
            got = [b.scale_stamp for b in group[key]]
            exp = [b.scale_stamp for b in q_ref]
            assert got == exp
            assert mp.stats(key) == p_ref.stats()
            assert group[key].stats() == q_ref.stats()


# ----------------------------------------------------------- noop == clean
class TestNoopBitEquality:
    @pytest.mark.timeout(60)
    def test_noop_plan_stats_bit_equal_to_fault_free(self):
        sims = _sims()
        clean, t1 = engine.replay_many(sims, _drain, 64)
        chaotic, t2 = engine.replay_many(sims, _drain, 64,
                                         fault_plan=FaultPlan(0))
        assert clean == chaotic

    def test_noop_spec_short_circuits(self):
        assert FaultSpec().is_noop
        assert not CHAOS.is_noop
        assert not FaultSpec(consumer_crash_attempts=(1,)).is_noop

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(drop_rate=1.5)
        with pytest.raises(ValueError):
            FaultSpec(reorder_window=0)
        with pytest.raises(ValueError):
            FaultSpec(stall_s=-1.0)
        with pytest.raises(ValueError):
            FaultSpec(consumer_crash_attempts=(0,))


# ------------------------------------------------------------ reconciliation
class TestDeliveryReconciliation:
    @pytest.mark.timeout(60)
    def test_single_producer_reconciles(self):
        sims = _sims((60,))
        key = ("traffic", 60)
        plan = FaultPlan(5, default=CHAOS)
        q = StreamQueue(maxsize=1_000_000)
        p = Producer(sims[key], q, clock=VirtualClock(),
                     faults=plan.injector(key))
        assert p.run() == 0
        m = {**q.stats(), **p.stats()}
        assert m["fault_dropped"] > 0 and m["fault_duplicated"] > 0
        assert _reconciles(m)

    @pytest.mark.timeout(60)
    def test_replay_many_reconciles_every_scenario(self):
        sims = _sims()
        metrics, _ = engine.replay_many(sims, _drain, 64,
                                        fault_plan=FaultPlan(5,
                                                             default=CHAOS))
        for key, m in metrics.items():
            assert _reconciles(m), f"{key} does not reconcile: {m}"
            assert m["records_seen"] == m["records_in"]

    def test_reorder_is_loss_free_and_counted(self):
        sims = _sims((60,))
        key = ("traffic", 60)
        spec = FaultSpec(reorder_rate=1.0, reorder_window=2)
        q = StreamQueue(maxsize=1_000_000)
        p = Producer(sims[key], q, clock=VirtualClock(),
                     faults=FaultPlan(2, default=spec).injector(key))
        assert p.run() == 0
        m = {**q.stats(), **p.stats()}
        assert m["fault_reordered"] == m["emitted_buckets"]
        assert _reconciles(m)   # holds flush at close: never a drop
        # multiset of stamps preserved exactly (bounded loss-free reorder)
        got = [b.scale_stamp for b in q]
        assert sorted(got) == sorted(
            int(s) for s in np.unique(sims[key].scale_stamp))

    def test_reorder_actually_perturbs_order_within_window(self):
        # a mixed schedule (held buckets overtaken by inline successors)
        # must produce out-of-order delivery, displaced by <= window
        sims = _sims((60,))
        key = ("traffic", 60)
        spec = FaultSpec(reorder_rate=0.5, reorder_window=3)
        q = StreamQueue(maxsize=1_000_000)
        p = Producer(sims[key], q, clock=VirtualClock(),
                     faults=FaultPlan(2, default=spec).injector(key))
        assert p.run() == 0
        got = [b.scale_stamp for b in q]
        src = sorted(int(s) for s in np.unique(sims[key].scale_stamp))
        assert sorted(got) == src
        assert got != src, "reorder_rate=0.5 must perturb delivery order"
        # bounded: a bucket lands at most `window` emissions late
        for pos, stamp in enumerate(got):
            assert pos - src.index(stamp) <= spec.reorder_window


# ----------------------------------------------------- queue close semantics
class TestCloseWakesProducers:
    @pytest.mark.timeout(30)
    def test_close_wakes_put_blocked_on_full_queue(self):
        q = StreamQueue(maxsize=1)
        q.put(_bucket(0))
        caught = []

        def blocked_producer():
            try:
                q.put(_bucket(1))      # no timeout: blocks on backpressure
            except RuntimeError as e:
                caught.append(e)

        th = threading.Thread(target=blocked_producer, daemon=True)
        th.start()
        time.sleep(0.1)
        assert th.is_alive()           # parked in put()
        q.close()
        th.join(5.0)
        assert not th.is_alive(), "close() must wake a blocked put()"
        assert caught and "queue closed" in str(caught[0])

    @pytest.mark.timeout(30)
    def test_close_wakes_put_blocked_on_byte_budget(self):
        b = _bucket(0)
        group = QueueGroup(["a", "b"], maxsize=64,
                           max_bytes=int(b.nbytes() * 1.5))
        group["a"].put(_bucket(0))     # budget nearly exhausted
        caught = []

        def blocked_producer():
            try:
                group["b"].put(_bucket(1))   # blocks on the shared budget
            except RuntimeError as e:
                caught.append(e)

        th = threading.Thread(target=blocked_producer, daemon=True)
        th.start()
        time.sleep(0.1)
        assert th.is_alive()           # parked on the byte budget
        group["b"].close()
        th.join(5.0)
        assert not th.is_alive(), "close() must wake a budget-blocked put()"
        assert caught and "queue closed" in str(caught[0])


# ------------------------------------------------------------- byte budget
class TestByteBudget:
    @pytest.mark.timeout(30)
    def test_block_policy_is_shared_backpressure(self):
        b = _bucket()
        group = QueueGroup(["a"], maxsize=1000,
                           max_bytes=int(b.nbytes() * 1.5))
        n = 20

        def produce():
            for i in range(n):
                group["a"].put(_bucket(i))
            group["a"].close()

        th = threading.Thread(target=produce, daemon=True)
        th.start()
        got = list(group["a"])
        th.join(5.0)
        assert len(got) == n           # everything delivered, throttled
        assert group.budget_stats()["bytes_used"] == 0
        assert group.budget_stats()["dropped_retention"] == 0

    def test_drop_oldest_evicts_globally_oldest(self):
        b = _bucket()
        group = QueueGroup(["a", "b"], maxsize=1000,
                           max_bytes=int(b.nbytes() * 3.5),
                           retention_policy="drop_oldest")
        for i in range(3):
            group["a"].put(_bucket(i))
        for i in range(3):             # budget full: a's oldest evicted
            group["b"].put(_bucket(10 + i))
        bs = group.budget_stats()
        assert bs["dropped_retention"] > 0
        assert bs["bytes_used"] <= bs["max_bytes"]
        assert group["a"].dropped_retention > 0
        assert group["b"].dropped_retention == 0
        assert group["a"].stats()["dropped_retention"] == \
            group["a"].dropped_retention

    def test_oversized_bucket_admitted_alone(self):
        big = _bucket(0, n=1000)
        group = QueueGroup(["a"], maxsize=10,
                           max_bytes=max(1, big.nbytes() // 2))
        group["a"].put(big)            # empty group: admit over cap
        assert group["a"].get() is not None

    def test_budget_validation(self):
        with pytest.raises(ValueError):
            ByteBudget(0)
        with pytest.raises(ValueError):
            ByteBudget(100, policy="lifo")
        with pytest.raises(ValueError):
            QueueGroup(["a"], max_bytes=100, retention_policy="nope")

    @pytest.mark.timeout(60)
    def test_replay_many_under_byte_budget_delivers_everything(self):
        sims = _sims((20, 40))
        metrics, _ = engine.replay_many(sims, _drain, 64,
                                        max_bytes=1 << 16)
        for key, sim in sims.items():
            assert metrics[key]["records_seen"] == len(sim)
            assert metrics[key]["dropped_retention"] == 0


# --------------------------------------------------------- consumer deadline
class TestConsumerDeadline:
    @pytest.mark.timeout(60)
    def test_wedged_consumer_is_a_named_timeout(self):
        sims = _sims((20, 40))
        wedged_key = ("traffic", 40)

        def consumer(queue):
            buckets = list(queue)      # drain to EOS
            if buckets[-1].scale_stamp + 1 == 40:
                time.sleep(30)         # wedge well past the deadline
            return {"records_seen": sum(len(b) for b in buckets)}

        with pytest.raises(RuntimeError) as ei:
            engine.replay_many(sims, consumer, 64,
                               consumer_deadline_s=0.5)
        msg = str(ei.value)
        assert repr(wedged_key) in msg
        assert repr(("traffic", 20)) not in msg
        assert isinstance(ei.value.__cause__, TimeoutError)

    @pytest.mark.timeout(60)
    def test_wedged_consumer_degrades_and_siblings_complete(self):
        sims = _sims((20, 40))

        def consumer(queue):
            buckets = list(queue)
            if buckets[-1].scale_stamp + 1 == 40:
                time.sleep(30)
            return {"records_seen": sum(len(b) for b in buckets)}

        metrics, _ = engine.replay_many(sims, consumer, 64,
                                        consumer_deadline_s=0.5,
                                        on_failure="degrade")
        ok = metrics[("traffic", 20)]
        bad = metrics[("traffic", 40)]
        assert ok["records_seen"] == len(sims[("traffic", 20)])
        assert "degraded" not in ok
        assert bad["degraded"] and "TimeoutError" in bad["failed"]
        assert bad["attempts"] == 1

    def test_bad_on_failure_rejected(self):
        with pytest.raises(ValueError):
            engine.replay_many({}, _drain, 64, on_failure="ignore")


# ------------------------------------------------------------ retry/breaker
class TestRetryAndBreaker:
    RETRY = RetryPolicy(max_attempts=3, base_delay_s=0.001,
                        max_delay_s=0.002, seed=1)

    @pytest.mark.timeout(60)
    def test_transient_crash_heals_with_retry(self):
        sims = _sims((20, 40))
        flaky = ("traffic", 40)
        plan = FaultPlan(3, overrides={
            flaky: FaultSpec(consumer_crash_attempts=(1,))})
        metrics, _ = engine.replay_many(sims, _drain, 64, fault_plan=plan,
                                        retry_policy=self.RETRY)
        assert metrics[flaky]["records_seen"] == len(sims[flaky])
        assert metrics[flaky]["retries"] == 1
        assert "retries" not in metrics[("traffic", 20)]

    @pytest.mark.timeout(60)
    def test_persistent_crash_trips_breaker_and_degrades(self):
        sims = _sims((20, 40))
        broken = ("traffic", 40)
        plan = FaultPlan(3, overrides={
            broken: FaultSpec(consumer_crash_attempts=(1, 2, 3, 4, 5))})
        metrics, _ = engine.replay_many(sims, _drain, 64, fault_plan=plan,
                                        retry_policy=self.RETRY,
                                        breaker_threshold=3,
                                        on_failure="degrade")
        bad = metrics[broken]
        assert bad["degraded"]
        assert "InjectedConsumerCrash" in bad["failed"]
        assert bad["attempts"] == 3
        assert bad["breaker"] == "open"
        assert metrics[("traffic", 20)]["records_seen"] == \
            len(sims[("traffic", 20)])

    @pytest.mark.timeout(60)
    def test_persistent_crash_raises_by_default(self):
        sims = _sims((20,))
        plan = FaultPlan(3, default=FaultSpec(
            consumer_crash_attempts=(1, 2, 3)))
        with pytest.raises(RuntimeError) as ei:
            engine.replay_many(sims, _drain, 64, fault_plan=plan,
                               retry_policy=self.RETRY)
        assert isinstance(ei.value.__cause__, InjectedConsumerCrash)

    @pytest.mark.timeout(60)
    def test_retry_preserves_transport_schedule(self):
        # the retried replay must reconcile with the SAME drop/dup counts
        # as a clean one-shot replay of the same schedule (reset(), not a
        # new stream)
        sims = _sims((60,))
        key = ("traffic", 60)
        chaos_crash = dataclasses.replace(CHAOS,
                                          consumer_crash_attempts=(1,))
        metrics, _ = engine.replay_many(
            sims, _drain, 64,
            fault_plan=FaultPlan(5, overrides={key: chaos_crash}),
            retry_policy=self.RETRY)
        ref_q = StreamQueue(maxsize=1_000_000)
        ref_p = Producer(sims[key], ref_q, clock=VirtualClock(),
                         faults=FaultPlan(5, default=CHAOS).injector(key))
        assert ref_p.run() == 0
        m = metrics[key]
        assert _reconciles(m)
        for f in ("fault_dropped", "fault_duplicated", "fault_reordered"):
            assert m[f] == ref_p.stats()[f]

    def test_retry_policy_deterministic_and_capped(self):
        p = RetryPolicy(max_attempts=5, base_delay_s=0.1, max_delay_s=0.5,
                        multiplier=4.0, jitter=0.5, seed=42)
        assert p.delay(2, "k") == p.delay(2, "k")
        assert p.delay(2, "k") != p.delay(2, "other")
        for a in range(1, 5):
            assert p.delay(a, "k") <= 0.5 * 1.5
        assert len(p.delays("k")) == 4
        with pytest.raises(ValueError):
            p.delay(0)
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(jitter=2.0)

    def test_circuit_breaker_transitions(self):
        t = [0.0]
        br = CircuitBreaker(failure_threshold=2, recovery_s=10.0,
                            clock=lambda: t[0])
        assert br.allow()
        br.record_failure()
        assert br.state == "closed" and br.allow()
        br.record_failure()
        assert br.state == "open" and not br.allow()
        t[0] = 11.0                     # recovery window elapsed
        assert br.allow() and br.state == "half-open"
        br.record_failure()             # probe fails: re-open
        assert br.state == "open"
        t[0] = 22.0
        assert br.allow()
        br.record_success()             # probe heals: closed
        assert br.state == "closed" and br.allow()

    def test_deadline(self):
        t = [0.0]
        d = Deadline(2.0, clock=lambda: t[0])
        assert d.remaining() == pytest.approx(2.0) and not d.expired
        t[0] = 3.0
        assert d.remaining() == 0.0 and d.expired
        assert Deadline(None).remaining() is None
        assert not Deadline(None).expired


# --------------------------------------------------------- checkpoint/resume
class TestCheckpointResume:
    @staticmethod
    def _report_key_fields(r):
        d = dataclasses.asdict(r)
        for f in ("preprocess_s", "nsa_s", "produce_s"):
            d.pop(f)
        return d

    def test_store_markers_roundtrip(self, tmp_path):
        store = StreamStore(str(tmp_path / "store"))
        store.put_marker("sweep1", "report__traffic__40", {"x": 1})
        assert store.has_marker("sweep1", "report__traffic__40")
        assert store.get_marker("sweep1", "report__traffic__40") == {"x": 1}
        assert store.list_markers("sweep1") == ["report__traffic__40"]
        assert store.list_markers("other") == []
        store.clear_markers("sweep1")
        assert store.list_markers("sweep1") == []
        assert store.list() == []       # markers invisible to streams
        # nested namespaces (the sweep service's layout) are legal, but
        # empty or dot-prefixed segments stay out of the namespace
        store.put_marker("a/b", "n", {})
        assert store.list_markers("a/b") == ["n"]
        with pytest.raises(ValueError):
            store.put_marker("a//b", "n", {})
        with pytest.raises(ValueError):
            store.put_marker("a/.trash-x", "n", {})
        with pytest.raises(ValueError):
            store.put_marker("ok", "../n", {})

    def test_sweep_id_stable_and_config_sensitive(self, tmp_path):
        from repro.streamsim.plan import plan_sweep
        store = StreamStore(str(tmp_path / "store"))
        kw = dict(scale=1.0, seed=0, n_devices=1, host_index=0, n_hosts=1)
        a = plan_sweep(store, ["traffic"], [20, 40], {"traffic": 10}, **kw)
        b = plan_sweep(store, ["traffic"], [20, 40], {"traffic": 10}, **kw)
        c = plan_sweep(store, ["traffic"], [20, 60], {"traffic": 10}, **kw)
        d = plan_sweep(store, ["traffic"], [20, 40], {"traffic": 10},
                       pairs=[("traffic", 40)], **kw)
        assert a.sweep_id == b.sweep_id
        assert a.sweep_id != c.sweep_id
        assert a.sweep_id == d.sweep_id   # pairs resume: same namespace

    @pytest.mark.timeout(120)
    def test_kill_after_k_reports_resumes_equal(self, tmp_path,
                                                monkeypatch):
        datasets, max_ranges = ["traffic"], [20, 40, 60]
        kw = dict(scale=0.002, seed=9, checkpoint=True)

        ref = Controller(str(tmp_path / "ref"))
        ref_reports = ref.run_many(datasets, max_ranges, _drain, scale=0.002,
                                   seed=9)

        class SimulatedKill(BaseException):
            pass

        c = Controller(str(tmp_path / "store"))
        real_build = engine.build_report
        built = []

        def dying_build(*args, **kwargs):
            if len(built) == 2:        # kill after k=2 completed reports
                raise SimulatedKill()
            r = real_build(*args, **kwargs)
            built.append(r)
            return r

        monkeypatch.setattr(engine, "build_report", dying_build)
        with pytest.raises(SimulatedKill):
            c.run_many(datasets, max_ranges, _drain, **kw)
        monkeypatch.setattr(engine, "build_report", real_build)

        # exactly k report markers survived the kill
        markers_root = tmp_path / "store" / "_markers"
        sweep_dirs = list(markers_root.iterdir())
        assert len(sweep_dirs) == 1
        reports_marked = [p for p in sweep_dirs[0].iterdir()
                         if p.name.startswith("report__")]
        assert len(reports_marked) == 2

        resumed = c.run_many(datasets, max_ranges, _drain, **kw)
        assert len(resumed) == len(ref_reports) == 3
        for got, exp in zip(resumed, ref_reports):
            assert self._report_key_fields(got) == \
                self._report_key_fields(exp)
        # completed sweep clears its markers
        assert not any(markers_root.iterdir())

    @pytest.mark.timeout(120)
    def test_uninterrupted_checkpoint_run_equals_plain(self, tmp_path):
        datasets, max_ranges = ["traffic"], [20, 40]
        a = Controller(str(tmp_path / "plain")).run_many(
            datasets, max_ranges, _drain, scale=0.002, seed=9)
        b = Controller(str(tmp_path / "ckpt")).run_many(
            datasets, max_ranges, _drain, scale=0.002, seed=9,
            checkpoint=True)
        for got, exp in zip(b, a):
            assert self._report_key_fields(got) == \
                self._report_key_fields(exp)

    def test_checkpoint_marker_roundtrip_of_reports(self, tmp_path):
        store = StreamStore(str(tmp_path / "store"))
        ckpt = SweepCheckpoint(store, "s1")
        vol = engine.Volatility(average=1.0, variance=2.0,
                                std_variance=0.5, time_range=60)
        r = engine.SimulationReport(
            dataset="traffic", max_range=40, original_rows=100,
            simulated_rows=50, compression=2.0, original_volatility=vol,
            simulated_volatility=vol, trend_corr=0.9, preprocess_s=0.1,
            nsa_s=0.2, produce_s=0.3,
            consumer_metrics={"records_seen": 50}, status="partial",
            failure="RuntimeError('x')", attempts=2)
        ckpt.mark_report(r)
        assert ckpt.done_scenarios() == [("traffic", 40)]
        loaded = ckpt.load_reports()[("traffic", 40)]
        assert loaded == r
        ckpt.mark_materialized([("traffic", 40)])
        assert ckpt.materialized_scenarios() == [("traffic", 40)]
        ckpt.clear()
        assert ckpt.done_scenarios() == []


# ----------------------------------------------------- controller integration
class TestControllerResilience:
    @pytest.mark.timeout(120)
    def test_run_many_degrades_to_partial_report(self, tmp_path):
        broken = ("traffic", 40)
        plan = FaultPlan(3, overrides={
            broken: FaultSpec(consumer_crash_attempts=(1, 2, 3, 4, 5))})
        c = Controller(str(tmp_path / "store"))
        reports = c.run_many(
            ["traffic"], [20, 40], _drain, scale=0.002, seed=9,
            fault_plan=plan,
            retry_policy=RetryPolicy(max_attempts=2, base_delay_s=0.001),
            on_failure="degrade")
        by_sc = {(r.dataset, r.max_range): r for r in reports}
        assert by_sc[("traffic", 20)].status == "ok"
        assert by_sc[("traffic", 20)].failure is None
        bad = by_sc[broken]
        assert bad.status == "partial"
        assert "InjectedConsumerCrash" in bad.failure
        assert bad.attempts == 2
        # the partial report still carries real simulation statistics
        assert bad.simulated_rows > 0
        # and round-trips through the metrics repository JSON
        loaded = [m for m in c.load_metrics()
                  if m.get("status") == "partial"]
        assert len(loaded) == 1 and loaded[0]["max_range"] == 40

    @pytest.mark.timeout(120)
    def test_run_many_chaos_reports_reconcile(self, tmp_path):
        c = Controller(str(tmp_path / "store"))
        reports = c.run_many(
            ["traffic"], [20, 40], _drain, scale=0.002, seed=9,
            fault_plan=FaultPlan(5, default=CHAOS))
        for r in reports:
            assert r.status == "ok" and r.attempts == 1
            assert _reconciles(r.consumer_metrics)


# ----------------------------------------------------- stream-task chaos tier
class TestTaskChaosIntegration:
    """The task tier meets the chaos layer: :class:`EventDetectTask` run
    through ``replay_many`` under a non-noop :class:`FaultPlan` must (a)
    satisfy the delivery reconciliation identity ``buckets_in ==
    emitted - dropped + duplicated`` per scenario, and (b) keep its
    detections displaced by at most the reorder window under a loss-free
    bounded reorder — exactly zero displacement once the watermark buffer
    (``reorder_tolerance``) is sized to that window."""

    REORDER = FaultSpec(reorder_rate=1.0, reorder_window=4)

    @staticmethod
    def _detect_sims():
        # CUSUM needs bucket-count variation to alarm at all; the sliced
        # sogouq morning ramp gives it (traffic at tiny scale compresses
        # to a flat one-record-per-bucket series).
        if not hasattr(TestTaskChaosIntegration, "_cache"):
            from repro.streamsim import slice_stream
            s = slice_stream(
                preprocess(make_stream("sogouq", scale=0.3, seed=0)), 7200)
            TestTaskChaosIntegration._cache = {("sogouq", 100): nsa(s, 100)}
        return TestTaskChaosIntegration._cache

    @pytest.mark.timeout(120)
    def test_detect_reconciles_under_full_chaos(self):
        sims = _sims((40, 60))
        task = EventDetectTask(mode="threshold", threshold=2.0)
        metrics, _ = engine.replay_many(
            sims, task, 64, fault_plan=FaultPlan(11, default=CHAOS))
        for key, m in metrics.items():
            assert _reconciles(m), f"{key} does not reconcile: {m}"
            assert m["task"] == "event-detect"
            # every delivered bucket reached the task
            assert m["task_buckets"] == m["buckets_in"]

    @pytest.mark.timeout(120)
    def test_threshold_event_set_survives_bounded_reorder(self):
        # threshold events carry the triggering bucket's OWN stamp, so a
        # loss-free reorder leaves the event SET identical (stamp
        # displacement zero <= window) even with no watermark buffer.
        sims = _sims((60,))
        key = ("traffic", 60)
        base, _ = engine.replay_many(
            sims, EventDetectTask(mode="threshold", threshold=2.0), 64)
        chaos, _ = engine.replay_many(
            sims, EventDetectTask(mode="threshold", threshold=2.0), 64,
            fault_plan=FaultPlan(3, default=self.REORDER))
        assert chaos[key]["fault_reordered"] > 0
        assert _reconciles(chaos[key])
        assert sorted(chaos[key]["task_events"].tolist()) == \
            sorted(base[key]["task_events"].tolist())

    @pytest.mark.timeout(120)
    def test_cusum_displacement_bounded_by_reorder_window(self):
        # CUSUM is order-sensitive; with the watermark buffer sized to
        # the fault plan's reorder window the faulted event list is
        # bit-equal to the unfaulted one (displacement bound met at 0).
        sims = self._detect_sims()
        key = ("sogouq", 100)
        w = self.REORDER.reorder_window
        kw = dict(mode="cusum", drift=0.5, h=2.0, reorder_tolerance=w)
        base, _ = engine.replay_many(sims, EventDetectTask(**kw), 64)
        chaos, _ = engine.replay_many(
            sims, EventDetectTask(**kw), 64,
            fault_plan=FaultPlan(3, default=self.REORDER))
        assert chaos[key]["fault_reordered"] > 0
        assert base[key]["detect_events"] > 0   # non-vacuous comparison
        assert chaos[key]["task_events"].tolist() == \
            base[key]["task_events"].tolist()

    @pytest.mark.timeout(120)
    def test_cusum_without_watermark_stays_within_window(self):
        # even with NO watermark buffer, every faulted detection sits
        # within the reorder window of some unfaulted detection: the
        # bounded-displacement half of the acceptance gate.
        sims = self._detect_sims()
        key = ("sogouq", 100)
        w = self.REORDER.reorder_window
        kw = dict(mode="cusum", drift=0.5, h=2.0)
        base, _ = engine.replay_many(sims, EventDetectTask(**kw), 64)
        chaos, _ = engine.replay_many(
            sims, EventDetectTask(**kw), 64,
            fault_plan=FaultPlan(3, default=self.REORDER))
        ref = base[key]["task_events"]
        assert len(ref) > 0
        for stamp in chaos[key]["task_events"]:
            assert np.abs(ref - stamp).min() <= w, \
                f"event at {stamp} displaced beyond window {w}: {ref}"
