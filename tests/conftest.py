import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS device-count here — smoke tests and benches
# must see 1 device; only launch/dryrun.py forces 512 placeholder devices.


def pytest_configure(config):
    # the chaos suite (test_faults.py) marks hang-prone tests with
    # @pytest.mark.timeout(...); CI installs pytest-timeout to enforce it
    # (the chaos-smoke job), but local environments without the plugin
    # must not warn on the unknown marker
    config.addinivalue_line(
        "markers",
        "timeout(seconds): hard per-test timeout (enforced when "
        "pytest-timeout is installed, e.g. the CI chaos-smoke job)")


@pytest.fixture(scope="session")
def small_stream():
    """A preprocessed small-but-real stream (diurnal shape intact)."""
    from repro.streamsim import make_stream, preprocess
    return preprocess(make_stream("traffic", scale=0.01, seed=7))
