import numpy as np
import pytest

# NOTE: do NOT set XLA_FLAGS device-count here — smoke tests and benches
# must see 1 device; only launch/dryrun.py forces 512 placeholder devices.


@pytest.fixture(scope="session")
def small_stream():
    """A preprocessed small-but-real stream (diurnal shape intact)."""
    from repro.streamsim import make_stream, preprocess
    return preprocess(make_stream("traffic", scale=0.01, seed=7))
