"""Metrics-layer tests: backend equivalence (numpy vs pallas-interpret),
cumsum trend semantics, batched metrics, trend correlation.

Contract under test (see repro/streamsim/metrics.py): per-second counts are
bit-exact across backends; derived moments agree within 1e-3 relative
tolerance (the device engine reduces in f32).
"""

import numpy as np
import pytest

from repro.streamsim import make_stream, metrics_batched, nsa, preprocess
from repro.streamsim.metrics import (per_second_counts, sliding_mean, trend,
                                     trend_correlation,
                                     trend_correlation_from_counts,
                                     volatility)
from repro.streamsim.preprocess import Stream


def _stream(t, name="s"):
    t = np.asarray(t, np.float64)
    return Stream(name, t, {"v": np.arange(len(t))})


def _edge_streams():
    """The degenerate shapes the engine must agree on across backends."""
    rng = np.random.default_rng(0)
    return {
        "empty": _stream([]),
        "single": _stream([1234.5]),
        "zero_span": _stream(np.full(257, 42.0)),   # all timestamps equal
        "dense": _stream(np.sort(rng.uniform(0, 3600.0, 5000))),
        "sparse": _stream(np.sort(rng.uniform(0, 86_400.0, 37))),
    }


def _vol_close(a, b, rtol=1e-3):
    assert a.time_range == b.time_range
    for f in ("average", "variance", "std_variance"):
        x, y = getattr(a, f), getattr(b, f)
        assert abs(x - y) <= rtol * max(abs(x), abs(y), 1e-9), (f, a, b)


class TestBackendEquivalence:
    @pytest.mark.parametrize("name", ["empty", "single", "zero_span",
                                      "dense", "sparse"])
    def test_counts_bit_exact(self, name):
        s = _edge_streams()[name]
        qn = per_second_counts(s, backend="numpy")
        qp = per_second_counts(s, backend="pallas")
        np.testing.assert_array_equal(qn, qp)

    @pytest.mark.parametrize("name", ["empty", "single", "zero_span",
                                      "dense", "sparse"])
    def test_volatility_within_tolerance(self, name):
        s = _edge_streams()[name]
        _vol_close(volatility(s, backend="numpy"),
                   volatility(s, backend="pallas"))

    def test_simulated_stream_counts(self):
        s = preprocess(make_stream("traffic", scale=0.01, seed=3))
        sim = nsa(s, 600)
        np.testing.assert_array_equal(
            per_second_counts(sim, 600, backend="numpy"),
            per_second_counts(sim, 600, backend="pallas"))
        _vol_close(volatility(sim, 600, backend="numpy"),
                   volatility(sim, 600, backend="pallas"))

    def test_bad_backend_rejected(self):
        with pytest.raises(ValueError):
            volatility(_stream([1.0]), backend="cuda")

    def test_time_range_below_max_stamp_expands(self):
        # scale stamps are never clipped to a user time range: a too-small
        # tr must expand to max stamp + 1 on BOTH backends (seed bincount
        # semantics), not mis-bin on numpy or raise on pallas
        s = preprocess(make_stream("traffic", scale=0.005, seed=8))
        sim = nsa(s, 600)
        assert int(sim.scale_stamp.max()) > 300
        qn = per_second_counts(sim, 300, backend="numpy")
        qp = per_second_counts(sim, 300, backend="pallas")
        np.testing.assert_array_equal(qn, qp)
        assert len(qn) == int(sim.scale_stamp.max()) + 1
        vn = volatility(sim, 300, backend="numpy")
        vp = volatility(sim, 300, backend="pallas")
        assert vn.time_range == vp.time_range == len(qn)
        _vol_close(vn, vp)
        assert vn.average == pytest.approx(qn.mean())

    def test_auto_backend_matches_numpy(self):
        s = _edge_streams()["dense"]
        np.testing.assert_array_equal(per_second_counts(s, backend="auto"),
                                      per_second_counts(s, backend="numpy"))

    def test_volatility_tight_on_day_scale(self):
        # the engine's pairwise-block + Kahan moment reduction tightens the
        # day-scale (86 400-bucket) backend agreement from the historical
        # 1e-3 to 1e-5
        rng = np.random.default_rng(9)
        s = _stream(np.sort(rng.uniform(0, 86_400.0, 200_000)))
        _vol_close(volatility(s, backend="numpy"),
                   volatility(s, backend="pallas"), rtol=1e-5)


class TestMetricsBatched:
    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    def test_ragged_batch_equals_per_stream(self, backend):
        # ragged lengths + mixed time ranges + empty/degenerate members in
        # ONE batched call must equal per-stream evaluation
        streams = list(_edge_streams().values())
        sim = nsa(preprocess(make_stream("sogouq", scale=0.005, seed=5)), 60)
        streams.append(sim)
        ranges = [None] * (len(streams) - 1) + [60]
        ms = metrics_batched(streams, ranges, backend=backend)
        assert len(ms) == len(streams)
        for s, tr, m in zip(streams, ranges, ms):
            np.testing.assert_array_equal(
                m.counts, per_second_counts(s, tr, backend="numpy"))
            _vol_close(m.volatility, volatility(s, tr, backend="numpy"))

    def test_backends_agree(self):
        streams = [s for s in _edge_streams().values() if len(s)]
        mn = metrics_batched(streams, [None] * len(streams),
                             backend="numpy")
        mp = metrics_batched(streams, [None] * len(streams),
                             backend="pallas")
        for a, b in zip(mn, mp):
            np.testing.assert_array_equal(a.counts, b.counts)
            _vol_close(a.volatility, b.volatility)

    def test_misaligned_args_rejected(self):
        with pytest.raises(ValueError):
            metrics_batched([_stream([1.0])], [None, 5])


class TestTrend:
    @pytest.mark.parametrize("n,w", [(1, 1), (10, 1), (10, 3), (10, 4),
                                     (100, 600), (7, 7), (50, 49), (3, 2),
                                     (2, 5)])
    def test_sliding_mean_matches_convolve(self, n, w):
        # the O(n) cumsum path must reproduce the seed's
        # np.convolve(q, ones(w)/w, mode="same") semantics exactly,
        # including w = 1 (identity) and w > n (clamped to n)
        rng = np.random.default_rng(n * 100 + w)
        q = rng.poisson(25.0, n).astype(np.float64)
        we = min(w, n)
        expected = np.convolve(q, np.ones(we) / we, mode="same")
        np.testing.assert_allclose(sliding_mean(q, w), expected,
                                   rtol=1e-12, atol=1e-12)

    def test_window_one_is_identity(self):
        q = np.arange(20, dtype=np.float64)
        np.testing.assert_array_equal(sliding_mean(q, 1), q)

    def test_window_larger_than_series(self):
        q = np.array([2.0, 4.0, 6.0])
        # clamped to w = n = 3: same-mode edges divide by w, not the
        # truncated overlap
        np.testing.assert_allclose(sliding_mean(q, 100),
                                   [(2 + 4) / 3, (2 + 4 + 6) / 3,
                                    (4 + 6) / 3])

    def test_empty(self):
        assert len(sliding_mean(np.zeros(0), 5)) == 0

    def test_trend_of_stream(self):
        s = _edge_streams()["dense"]
        t_np = trend(s, 60, backend="numpy")
        t_pl = trend(s, 60, backend="pallas")
        # window sums are int32-exact on device; the final divide is f32,
        # so backends agree within the documented 1e-3 (observed ~1e-7)
        np.testing.assert_allclose(t_np, t_pl, rtol=1e-3, atol=1e-5)
        assert len(t_np) == len(per_second_counts(s))


class TestTrendCorrelation:
    def test_from_counts_matches_streams(self):
        s = preprocess(make_stream("traffic", scale=0.01, seed=1))
        sim = nsa(s, 300)
        direct = trend_correlation(s, sim, window_s=60)
        from_counts = trend_correlation_from_counts(
            per_second_counts(s), per_second_counts(sim, 300), window_s=60)
        assert direct == pytest.approx(from_counts, rel=1e-12)
        assert -1.0 <= direct <= 1.0

    def test_self_correlation_is_one(self):
        s = _edge_streams()["dense"]
        assert trend_correlation(s, s) == pytest.approx(1.0)

    def test_empty_is_nan(self):
        assert np.isnan(trend_correlation(_stream([]), _stream([1.0])))
