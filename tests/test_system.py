"""End-to-end behaviour tests: the paper's full pipeline against its claims.

These mirror the paper's evaluation (§5): volatility preservation across the
six time ranges, trend similarity of what the SPS receives (Fig. 6), and
the >=24x efficiency claim (Fig. 7 / §6) — executed at reduced dataset scale
so the suite runs on CPU in seconds.
"""

import threading

import numpy as np
import pytest

from repro.core import simulate_stream
from repro.streamsim import (
    Controller,
    Producer,
    StreamQueue,
    VirtualClock,
    make_stream,
    nsa,
    nsa_paper,
    preprocess,
    volatility,
)
from repro.streamsim.metrics import trend_correlation
from repro.streamsim.nsa import compression_factor

TIME_RANGES = (600, 1200, 1800, 2400, 3000, 3600)


class TestPaperClaims:
    @pytest.fixture(scope="class")
    def streams(self):
        # realistic arrival rates (>= ~5/s) so per-bucket keep counts are
        # not dominated by integer rounding; userbehavior is downscaled
        # more because its base rate is ~5x the others'
        scales = {"sogouq": 0.3, "traffic": 0.3, "userbehavior": 0.1}
        return {name: preprocess(make_stream(name, scale=sc, seed=0))
                for name, sc in scales.items()}

    def test_tables_1_2_3_volatility(self, streams):
        """Simulated volatility ~constant across the six ranges and close to
        the original (paper Tables 1-3)."""
        for name, s in streams.items():
            v0 = volatility(s)
            avgs = []
            for mr in TIME_RANGES:
                v = volatility(nsa(s, mr), mr)
                avgs.append(v.average)
            for a in avgs:
                assert abs(a - v0.average) / v0.average < 0.06, (name, a)
            assert (max(avgs) - min(avgs)) / v0.average < 0.05

    def test_fig6_trend_preserved(self, streams):
        """What the SPS receives correlates with the original trend."""
        s = streams["userbehavior"]
        sim = nsa(s, 1200)
        corr = trend_correlation(s, sim, window_s=60)
        assert corr > 0.9, f"trend correlation too low: {corr}"

    def test_fig7_simulation_cost_shrinks_with_range(self, streams):
        """Table 4: smaller time range -> fewer records -> cheaper run."""
        s = streams["userbehavior"]
        sizes = [len(nsa(s, mr)) for mr in TIME_RANGES]
        assert sizes == sorted(sizes), "records grow with time range"
        assert sizes[0] < sizes[-1] / 3

    def test_24x_acceleration(self, streams):
        """§6: task time compresses by original/max >= 24 at max <= 3600."""
        s = streams["sogouq"]
        for mr in TIME_RANGES:
            assert compression_factor(s, mr) >= 86_400 / mr * 0.99
        assert compression_factor(s, 3600) >= 23.9

    def test_vectorized_speedup_over_paper_loop(self, streams):
        """The framework's NSA is dramatically faster than the paper's
        per-record loops at equal output (beyond-paper §Perf)."""
        import time
        s = streams["traffic"]
        t0 = time.perf_counter()
        a = nsa(s, 600)
        t_vec = time.perf_counter() - t0
        t0 = time.perf_counter()
        b = nsa_paper(s, 600)
        t_paper = time.perf_counter() - t0
        assert np.array_equal(a.t, b.t)
        assert t_paper / max(t_vec, 1e-9) > 5, (t_paper, t_vec)


class TestEndToEnd:
    def test_stream_to_training_pipeline(self, tmp_path):
        """POSD -> NSA -> PSDA -> StreamBatcher -> 3 train steps."""
        import jax
        from repro.configs.paper_stream import consumer_lm
        from repro.models import transformer as T
        from repro.training.data import StreamBatcher
        from repro.training.optimizer import AdamW, adamw_init
        from repro.training.steps import jit_train_step

        cfg = consumer_lm().replace(n_layers=2, d_model=64, n_heads=4,
                                    n_kv_heads=2, head_dim=16, d_ff=128,
                                    vocab_size=512, loss_chunk=16)
        sim = simulate_stream("traffic", 60, scale=0.01, seed=11)
        q = StreamQueue(maxsize=64)
        threading.Thread(target=Producer(sim, q, clock=VirtualClock()).run,
                         daemon=True).start()
        batcher = StreamBatcher(q, batch=2, seq=32, vocab=cfg.vocab_size)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        opt_state = adamw_init(params)
        step = jit_train_step(cfg, opt, mesh=None, donate=False)
        it = iter(batcher)
        losses = []
        for _ in range(3):
            params, opt_state, m = step(params, opt_state, next(it))
            losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses)

    def test_controller_metrics_repository(self, tmp_path):
        c = Controller(str(tmp_path / "store"))

        def consumer(queue):
            return {"buckets": sum(1 for _ in queue)}

        rep = c.run("sogouq", 30, consumer, scale=0.002, seed=1)
        loaded = c.load_metrics()
        assert len(loaded) == 1
        assert loaded[0]["dataset"] == "sogouq"
        assert loaded[0]["consumer_metrics"]["buckets"] > 0
