"""Plan/engine layer tests: sweep planning, sharded execution, and the
device-residency contract.

Contracts under test:
- ``plan_sweep`` enumerates the grid in report order, resolves store-cache
  hits, and partitions missing scenarios into balanced, padding-aware
  shards (per host AND per device) without ever splitting a scenario;
- a sharded pallas sweep reports equivalently to the numpy path (NSA
  bit-identical rows, statistics within the documented 1e-3 tolerance)
  and costs exactly one NSA dispatch per shard;
- between NSA and metrics no per-scenario data crosses to host: the fused
  metrics engine consumes jax arrays, and the single ``materialize()``
  host pass happens strictly after every metrics dispatch;
- under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (run in a
  subprocess — the flag must precede jax initialization) the shards land
  on four REAL distinct devices and the 8×6 grid executes as ≤ 4 NSA
  dispatches.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.streamsim import (Controller, make_stream, plan_sweep,
                             preprocess)
from repro.streamsim.plan import ROW_TILE, ScenarioSpec, Shard


def _consumer(queue):
    return {"records_seen": sum(len(b) for b in queue)}


class _FakeStore:
    """exists() from a fixed key set — planner tests need no disk."""

    def __init__(self, keys=()):
        self.keys = set(keys)

    def exists(self, key):
        return key in self.keys


# ------------------------------------------------------------------ planner
class TestPlanSweep:
    ROWS = {"a": 10_000, "b": 9_000, "c": 900, "d": 800}

    def test_grid_order_and_cache_resolution(self):
        store = _FakeStore({"b__sim20"})
        plan = plan_sweep(store, ["a", "b"], [10, 20], self.ROWS,
                          n_devices=2, host_index=0, n_hosts=1)
        assert [s.scenario for s in plan.scenarios] == \
            [("a", 10), ("a", 20), ("b", 10), ("b", 20)]
        assert [s.scenario for s in plan.cached] == [("b", 20)]
        assert len(plan.missing) == 3
        # shards cover exactly the missing scenarios, none split/duplicated
        covered = sorted(s.scenario for sh in plan.shards for s in sh.specs)
        assert covered == sorted(s.scenario for s in plan.missing)

    def test_force_marks_everything_missing(self):
        store = _FakeStore({"a__sim10", "a__sim20"})
        plan = plan_sweep(store, ["a"], [10, 20], self.ROWS, force=True,
                          n_devices=1, host_index=0, n_hosts=1)
        assert not plan.cached and len(plan.missing) == 2

    def test_shards_group_similar_sizes_and_balance(self):
        # two big (10k/9k rows) + two small (900/800) streams: the
        # padding-aware partition must not mix a big with a small (that
        # pads the small to the big's width)
        plan = plan_sweep(_FakeStore(), list(self.ROWS), [60], self.ROWS,
                          n_devices=2, host_index=0, n_hosts=1)
        assert len(plan.shards) == 2
        groups = [sorted(s.dataset for s in sh.specs) for sh in plan.shards]
        assert ["a", "b"] in groups and ["c", "d"] in groups
        # planned area beats the monolithic single-launch padding
        assert plan.padded_area() < plan.monolithic_area()

    def test_more_devices_than_scenarios(self):
        plan = plan_sweep(_FakeStore(), ["a"], [60], self.ROWS,
                          n_devices=8, host_index=0, n_hosts=1)
        assert len(plan.shards) == 1
        assert plan.shards[0].specs[0].scenario == ("a", 60)

    def test_host_partition_is_a_disjoint_cover(self):
        plans = [plan_sweep(_FakeStore(), list(self.ROWS), [10, 20],
                            self.ROWS, n_devices=2, host_index=h,
                            n_hosts=3) for h in range(3)]
        per_host = [sorted(s.scenario for s in p.local_missing)
                    for p in plans]
        merged = sorted(sc for host in per_host for sc in host)
        assert merged == sorted(s.scenario for s in plans[0].missing)
        # strided slicing keeps host loads similar (within one scenario)
        sizes = [len(h) for h in per_host]
        assert max(sizes) - min(sizes) <= 1

    def test_shard_cost_properties(self):
        spec = ScenarioSpec("a", 60, 1.0, 0, rows=ROW_TILE + 1,
                            cached=False)
        sh = Shard(0, (spec,))
        assert sh.padded_rows == 2 * ROW_TILE
        assert sh.cost == 2 * ROW_TILE
        assert sh.max_range == 60

    def test_bad_inputs_rejected(self):
        with pytest.raises(ValueError):
            plan_sweep(_FakeStore(), ["a"], [0], self.ROWS,
                       n_devices=1, host_index=0, n_hosts=1)
        with pytest.raises(ValueError):
            plan_sweep(_FakeStore(), ["a"], [10], self.ROWS,
                       n_devices=1, host_index=2, n_hosts=2)


# ----------------------------------------------------------- sharded engine
def _hetero_streams(n=8, seed=3):
    """n streams of very different sizes (the planner's target shape)."""
    base = ["sogouq", "traffic", "userbehavior"]
    out = {}
    for i in range(n):
        scale = 0.0008 * (1 + (i % 4))
        s = preprocess(make_stream(base[i % 3], scale=scale, seed=seed + i))
        s.name = f"s{i}"
        out[f"s{i}"] = s
    return out


class TestShardedEngine:
    def test_sharded_pallas_equivalent_to_numpy(self, tmp_path):
        # 3 datasets x 4 ranges forced across 4 shards on however many
        # devices exist: rows must stay bit-identical to the numpy path,
        # statistics within the documented tolerance
        datasets = ["sogouq", "traffic", "userbehavior"]
        ranges = [10, 20, 40, 80]
        c = Controller(str(tmp_path / "sharded"))
        rep = c.run_many(datasets, ranges, _consumer, scale=0.002, seed=9,
                         backend="pallas", n_devices=4)
        ref_c = Controller(str(tmp_path / "ref"))
        ref = ref_c.run_many(datasets, ranges, _consumer, scale=0.002,
                             seed=9, backend="numpy")
        assert [(r.dataset, r.max_range) for r in rep] == \
            [(r.dataset, r.max_range) for r in ref]
        for a, b in zip(rep, ref):
            assert a.simulated_rows == b.simulated_rows
            assert a.consumer_metrics["records_seen"] == \
                b.consumer_metrics["records_seen"]
            assert a.trend_corr == pytest.approx(b.trend_corr, abs=1e-3)
            for f in ("average", "variance", "std_variance"):
                assert getattr(a.simulated_volatility, f) == pytest.approx(
                    getattr(b.simulated_volatility, f), rel=1e-3, abs=1e-6)
        # stored sims are the bit-identical NSA output
        for r in rep:
            a = c.store.get(f"{r.dataset}__sim{r.max_range}")
            b = ref_c.store.get(f"{r.dataset}__sim{r.max_range}")
            np.testing.assert_array_equal(a.t, b.t)
            np.testing.assert_array_equal(a.scale_stamp, b.scale_stamp)
        # fidelity matrices agree across backends too
        for fa, fb in zip(c.last_fidelity, ref_c.last_fidelity):
            np.testing.assert_allclose(np.asarray(fa.trend_corr),
                                       np.asarray(fb.trend_corr),
                                       atol=1e-3)

    def test_one_dispatch_per_shard(self, tmp_path, monkeypatch):
        # a 4-shard plan must cost exactly 4 NSA device dispatches —
        # one per shard, never one per scenario
        import repro.kernels.ops as ops_mod
        import repro.kernels.stream_sample as sskern

        dispatches = []
        real_kernel = sskern.stream_sample_pallas

        def counting_kernel(*args, **kwargs):
            dispatches.append(args[0].shape)
            return real_kernel(*args, **kwargs)

        monkeypatch.setattr(sskern, "stream_sample_pallas", counting_kernel)
        monkeypatch.setattr(ops_mod, "stream_sample_pallas", counting_kernel)

        datasets = ["sogouq", "traffic", "userbehavior"]
        ranges = [10, 20, 30, 40, 50, 60]
        c = Controller(str(tmp_path / "store"))
        reports = c.run_many(datasets, ranges, _consumer, scale=0.002,
                             seed=9, backend="pallas", n_devices=4)
        assert len(reports) == 18
        assert len(dispatches) == 4, \
            f"expected 4 NSA dispatches (one per shard), saw {dispatches}"
        assert sum(shape[0] for shape in dispatches) == 18, \
            "shards must cover all 18 scenarios exactly once"

    def test_no_host_transfer_between_nsa_and_metrics(self, tmp_path,
                                                      monkeypatch):
        # the device-residency contract: the fused metrics engine consumes
        # jax arrays straight from the NSA chain, and the single
        # materialize() host pass happens strictly AFTER every metrics
        # dispatch
        import jax

        import repro.kernels.ops as ops_mod
        import repro.streamsim.engine as engine_mod

        events = []
        real_metrics = ops_mod.stream_metrics_batched_device
        real_mat = engine_mod.materialize_sweep

        def checking_metrics(ss, totals, max_range):
            assert isinstance(ss, jax.Array), \
                f"metrics engine fed host data: {type(ss)}"
            events.append("metrics")
            return real_metrics(ss, totals, max_range)

        def tracking_materialize(*args, **kwargs):
            events.append("materialize")
            return real_mat(*args, **kwargs)

        monkeypatch.setattr(ops_mod, "stream_metrics_batched_device",
                            checking_metrics)
        monkeypatch.setattr(engine_mod, "materialize_sweep",
                            tracking_materialize)

        c = Controller(str(tmp_path / "store"))
        c.run_many(["sogouq", "traffic"], [20, 40], _consumer, scale=0.002,
                   seed=9, backend="pallas", n_devices=2)
        assert "metrics" in events and "materialize" in events
        first_mat = events.index("materialize")
        assert all(e != "metrics" for e in events[first_mat:]), \
            f"metrics dispatched after the host pass: {events}"

    def test_engine_direct_hetero_sweep(self, tmp_path):
        # the engine consumes arbitrary named streams (not just the
        # Controller's datasets): 8 heterogeneous streams x 2 ranges
        from repro.streamsim import engine
        from repro.streamsim.store import StreamStore

        originals = _hetero_streams(8)
        store = StreamStore(str(tmp_path / "store"))
        plan = plan_sweep(store, list(originals), [30, 60],
                          {k: len(v) for k, v in originals.items()},
                          n_devices=4, host_index=0, n_hosts=1)
        assert len(plan.shards) == 4
        result = engine.execute_sweep(plan, originals, store,
                                      backend="pallas")
        assert result.mode == "device"
        sims = result.materialize()
        from repro.streamsim import nsa
        for (name, mr), sim in sims.items():
            ref = nsa(originals[name], mr, backend="numpy")
            np.testing.assert_array_equal(sim.t, ref.t)
            np.testing.assert_array_equal(sim.scale_stamp, ref.scale_stamp)
        # sims were persisted by materialize
        assert store.exists("s0__sim30") and store.exists("s7__sim60")

    def test_domain_error_falls_back_to_host_mode(self, tmp_path):
        # a poisoned scenario (giant single bucket) must send the WHOLE
        # sweep to host mode, bit-identically — never silently wrong
        from repro.streamsim import engine
        from repro.streamsim.preprocess import Stream
        from repro.streamsim.store import StreamStore

        originals = {
            "burst": Stream("burst", np.full(100_000, 5.0),
                            {"x": np.arange(100_000)}),
            "ok": preprocess(make_stream("traffic", scale=0.002, seed=3)),
        }
        store = StreamStore(str(tmp_path / "store"))
        plan = plan_sweep(store, list(originals), [600],
                          {k: len(v) for k, v in originals.items()},
                          n_devices=2, host_index=0, n_hosts=1)
        result = engine.execute_sweep(plan, originals, store,
                                      backend="pallas")
        assert result.mode == "host"
        sims = result.materialize()
        from repro.streamsim import nsa
        for (name, mr), sim in sims.items():
            ref = nsa(originals[name], mr, backend="numpy")
            np.testing.assert_array_equal(sim.t, ref.t)


    def test_multi_host_slice_reports_and_partial_fidelity(self, tmp_path):
        # host 0 of 2 reports only its scenario slice, and fidelity rows
        # for its owned sims are emitted as partial matrices (labels
        # record the subset) instead of being silently dropped
        datasets, ranges = ["sogouq", "traffic"], [20, 40]
        c = Controller(str(tmp_path / "h0"))
        reports = c.run_many(datasets, ranges, _consumer, scale=0.002,
                             seed=9, backend="numpy", n_devices=2,
                             host_index=0, n_hosts=2)
        all_sc = {(d, mr) for d in datasets for mr in ranges}
        got = {(r.dataset, r.max_range) for r in reports}
        assert got and got < all_sc, "host 0 owns a strict subset"
        assert c.last_fidelity, "partial fidelity rows must be emitted"
        for fr in c.last_fidelity:
            m = np.asarray(fr.trend_corr)
            D = len(fr.labels) // 2
            assert 1 <= D <= len(datasets)
            assert m.shape == (2 * D, 2 * D)
            assert all(lb.endswith("/original") for lb in fr.labels[:D])
            assert all(f"/sim{fr.max_range}" in lb
                       for lb in fr.labels[D:])

    def test_materialize_persists_after_earlier_peek(self, tmp_path):
        # materialize(store=False) then materialize() must still persist
        from repro.streamsim import engine
        from repro.streamsim.store import StreamStore

        originals = {"s": preprocess(make_stream("traffic", scale=0.002,
                                                 seed=3))}
        store = StreamStore(str(tmp_path / "store"))
        plan = plan_sweep(store, ["s"], [30], {"s": len(originals["s"])},
                          n_devices=1, host_index=0, n_hosts=1)
        result = engine.execute_sweep(plan, originals, store,
                                      backend="pallas")
        result.materialize(store=False)
        assert not store.exists("s__sim30"), "peek must not persist"
        result.materialize()
        assert store.exists("s__sim30"), "later default call must persist"


# ----------------------------------------------------------- replay errors
def test_replay_many_chains_through_existing_causes():
    # a consumer exception that already carries its own __cause__ must not
    # make LATER failures unreachable: the next failure links to the
    # existing chain's tail
    from repro.streamsim import nsa
    from repro.streamsim.engine import replay_many

    s = preprocess(make_stream("traffic", scale=0.002, seed=5))
    sims = {("traffic", mr): nsa(s, mr) for mr in (5, 11)}

    def consumer(queue):
        buckets = list(queue)
        mr = buckets[-1].scale_stamp + 1 if buckets else 0
        if mr == 5:
            try:
                raise KeyError("inner")
            except KeyError as inner:
                raise ValueError("first") from inner
        raise OSError("second")

    with pytest.raises(RuntimeError) as ei:
        replay_many(sims, consumer, 64)
    chain, exc = [], ei.value.__cause__
    while exc is not None:
        chain.append(type(exc).__name__)
        exc = exc.__cause__
    assert chain == ["ValueError", "KeyError", "OSError"], chain


# ---------------------------------------------------- device-input ops layer
class TestDeviceInputOps:
    def test_stream_metrics_device_matches_host_input(self):
        import jax.numpy as jnp

        from repro.kernels import ops

        rng = np.random.default_rng(0)
        W = 90
        rows = [np.sort(rng.integers(0, W, n).astype(np.int32))
                for n in (700, 1, 2500)]
        N = max(len(r) for r in rows)
        # device layout: garbage (out-of-range stamps allowed) past totals
        ssb = np.full((3, N), W - 1, np.int32)
        for s, r in enumerate(rows):
            ssb[s, :len(r)] = r
        totals = np.array([len(r) for r in rows])
        hist_d, mom_d = ops.stream_metrics_batched_device(
            jnp.asarray(ssb), totals, W)
        hist_h, mom_h, _ = ops.stream_metrics_batched(rows, W)
        np.testing.assert_array_equal(np.asarray(hist_d),
                                      np.asarray(hist_h))
        np.testing.assert_allclose(np.asarray(mom_d), np.asarray(mom_h),
                                   rtol=1e-6)

    def test_stream_metrics_device_rejects_huge_rows(self):
        from repro.kernels import ops
        with pytest.raises(ValueError):
            ops.stream_metrics_batched_device(np.zeros((2, 8), np.int32),
                                              [8, 8], 0)

    def test_trend_corr_pairwise_matches_host_pairs(self):
        from repro.kernels import ops
        from repro.streamsim.metrics import trend_correlation_from_counts

        rng = np.random.default_rng(1)
        D, P = 3, 9
        la = np.array([400, 73, 1])
        qa = np.zeros((D, 400), np.int32)
        for d in range(D):
            qa[d, :la[d]] = rng.integers(0, 40, la[d])
        lb = np.array([60, 200, 400, 17, 1, 60, 90, 5, 300])
        a_index = np.array([0, 0, 0, 1, 1, 1, 2, 2, 2])
        qb = np.zeros((P, 400), np.int32)
        for p in range(P):
            qb[p, :lb[p]] = rng.integers(0, 40, lb[p])
        got = ops.trend_corr_pairwise(qa, la, qb, lb, 60, a_index=a_index)
        for p in range(P):
            exp = trend_correlation_from_counts(
                qa[a_index[p], :la[a_index[p]]], qb[p, :lb[p]])
            if np.isnan(exp):
                assert np.isnan(got[p])
            else:
                assert got[p] == pytest.approx(exp, abs=1e-3)

    def test_trend_corr_pairwise_empty_and_flat_are_nan(self):
        from repro.kernels import ops

        qa = np.array([[3, 3, 3, 3], [1, 2, 3, 4]], np.int32)
        qb = np.array([[1, 2, 3, 4], [0, 0, 0, 0]], np.int32)
        # pair 0: flat left trend (zero variance at window 1) -> NaN;
        # pair 1: empty right series (length 0) -> NaN
        r = ops.trend_corr_pairwise(qa, [4, 4], qb, [4, 0], 1)
        assert np.isnan(r).all()

    def test_trend_corr_pairwise_domain_guard(self):
        from repro.kernels import ops
        with pytest.raises(ops.PallasDomainError):
            ops.trend_corr_pairwise(np.ones((1, 4), np.int32), [4],
                                    np.ones((1, 4), np.int32), [4], 60,
                                    totals=[2 ** 31])

    def test_trend_correlation_batched_device_matches_host_input(self):
        import jax.numpy as jnp

        from repro.kernels import ops

        rng = np.random.default_rng(2)
        lens = [300, 120, 1, 300]
        qs = [rng.integers(0, 30, n) for n in lens]
        qmat = np.zeros((len(qs), max(lens)), np.int32)
        for s, q in enumerate(qs):
            qmat[s, :len(q)] = q
        got = ops.trend_correlation_batched_device(
            jnp.asarray(qmat), lens, 60,
            totals=[int(q.sum()) for q in qs])
        exp = ops.trend_correlation_batched(qs, 60)
        np.testing.assert_allclose(got, exp, atol=1e-6, equal_nan=True)


# ------------------------------------------------- forced 4-device topology
_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    assert jax.local_device_count() == 4, jax.local_device_count()

    import repro.kernels.ops as ops_mod
    import repro.kernels.stream_sample as sskern
    from repro.streamsim import Controller

    dispatch_devices = []
    real = sskern.stream_sample_pallas

    def counting(*args, **kwargs):
        dispatch_devices.append(tuple(args[0].devices())[0].id)
        return real(*args, **kwargs)

    sskern.stream_sample_pallas = counting
    ops_mod.stream_sample_pallas = counting

    def consumer(queue):
        return {"records_seen": sum(len(b) for b in queue)}

    datasets = ["sogouq", "traffic", "userbehavior"]
    ranges = [10, 20, 30, 40, 50, 60]
    c = Controller("@STORE@")
    reports = c.run_many(datasets, ranges, consumer, scale=0.002, seed=9,
                         backend="pallas")
    assert len(reports) == 18
    assert len(dispatch_devices) <= 4, dispatch_devices
    assert len(set(dispatch_devices)) == len(dispatch_devices), \\
        "each shard must land on its own device: " + repr(dispatch_devices)

    ref = Controller("@REF_STORE@")
    ref_reports = ref.run_many(datasets, ranges, consumer, scale=0.002,
                               seed=9, backend="numpy")
    for a, b in zip(reports, ref_reports):
        assert a.simulated_rows == b.simulated_rows
        assert abs(a.trend_corr - b.trend_corr) < 1e-3 or \\
            (a.trend_corr != a.trend_corr and b.trend_corr != b.trend_corr)
    print("OK devices=" + repr(sorted(set(dispatch_devices))))
""")


def test_sharded_sweep_on_four_forced_devices(tmp_path):
    """The acceptance shape: 4 forced host-platform devices, the grid
    executes as <= 4 NSA dispatches on 4 DISTINCT devices, reports match
    the single-process numpy path. Runs in a subprocess because
    ``XLA_FLAGS`` must be set before jax initializes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    script = _SUBPROCESS_SCRIPT \
        .replace("@STORE@", str(tmp_path / "store")) \
        .replace("@REF_STORE@", str(tmp_path / "ref"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "OK devices=" in proc.stdout
