"""Sweep-service tests: leased work queue, failover, poison, and merge.

Contracts under test:
- the store's atomic coordination primitives: exclusive create elects ONE
  winner, queue→lease claims have exactly one winner under races, and
  ``clear_markers`` removes a whole nested namespace atomically (a
  concurrent observer sees it fully present or fully absent, never half);
- the lease protocol: heartbeats renew deadlines while a worker lives,
  only a DEAD worker's lease expires, the reaper requeues expired leases,
  and ``breaker_threshold`` strikes on one scenario quarantine it as
  ``status="poisoned"`` instead of retrying forever;
- service-mode sweeps produce reports and fidelity matrices equal to the
  direct single-host ``run_many`` path — including after a worker
  subprocess is SIGKILLed mid-lease (kill → lease expiry → requeue →
  completion), and across a real 2-process ``jax.distributed`` run.
"""

import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.streamsim import Controller
from repro.streamsim.resilience import Heartbeat, Lease
from repro.streamsim.service import (SweepService, merge_fidelity,
                                     run_service_sweep, scenario_marker)
from repro.streamsim.store import StreamStore

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _consumer(queue):
    return {"records_seen": sum(len(b) for b in queue)}


def _assert_reports_equal(got, want, *, allow_status=("ok",)):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert (a.dataset, a.max_range) == (b.dataset, b.max_range)
        assert a.status in allow_status
        assert a.original_rows == b.original_rows
        assert a.simulated_rows == b.simulated_rows
        assert a.compression == pytest.approx(b.compression)
        assert a.simulated_volatility.average == \
            pytest.approx(b.simulated_volatility.average, abs=1e-9)
        assert a.trend_corr == pytest.approx(b.trend_corr, abs=1e-9,
                                             nan_ok=True)
        assert a.consumer_metrics["records_seen"] == \
            b.consumer_metrics["records_seen"]


# ----------------------------------------------------- store coordination
class TestStorePrimitives:
    def test_nested_namespaces_and_validation(self, tmp_path):
        store = StreamStore(str(tmp_path))
        store.put_marker("g1/queue", "a__10", {"x": 1})
        assert store.list_markers("g1/queue") == ["a__10"]
        assert store.get_marker("g1/queue", "a__10") == {"x": 1}
        for bad in ("", "/q", "g//q", "g/.hidden", "g/..", ".g/q"):
            with pytest.raises(ValueError):
                store.put_marker(bad, "n", {})

    def test_exclusive_put_single_winner_under_race(self, tmp_path):
        store = StreamStore(str(tmp_path))
        wins = []
        barrier = threading.Barrier(8)

        def racer(i):
            barrier.wait()
            if store.put_marker("g/meta", "claimant", {"w": i},
                                exclusive=True):
                wins.append(i)

        threads = [threading.Thread(target=racer, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(wins) == 1
        assert store.get_marker("g/meta", "claimant")["w"] == wins[0]
        # non-exclusive put still overwrites
        assert store.put_marker("g/meta", "claimant", {"w": -1})
        assert store.get_marker("g/meta", "claimant")["w"] == -1

    def test_claim_single_winner_under_race(self, tmp_path):
        store = StreamStore(str(tmp_path))
        store.put_marker("g/queue", "item", {"attempts": 0})
        wins = []
        barrier = threading.Barrier(8)

        def racer(i):
            barrier.wait()
            if store.claim_marker("g/queue", "item", "g/leases", "item"):
                wins.append(i)

        threads = [threading.Thread(target=racer, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(wins) == 1
        assert store.list_markers("g/queue") == []
        assert store.list_markers("g/leases") == ["item"]
        # claiming a vanished source is a clean False, not an error
        assert not store.claim_marker("g/queue", "item", "g/leases", "x")

    def test_remove_and_mtime(self, tmp_path):
        store = StreamStore(str(tmp_path))
        assert store.marker_mtime("g", "m") is None
        store.put_marker("g", "m", {})
        assert store.marker_mtime("g", "m") == pytest.approx(
            time.time(), abs=30)
        assert store.remove_marker("g", "m")
        assert not store.remove_marker("g", "m")

    def test_clear_markers_is_atomic_and_recursive(self, tmp_path):
        # the whole nested namespace vanishes in one observable step:
        # after clear_markers returns (or even mid-clear), no sub-
        # namespace survives — the rename happened before any deletion
        store = StreamStore(str(tmp_path))
        for ns in ("g", "g/queue", "g/leases", "g/results"):
            store.put_marker(ns, "m", {"ns": ns})
        store.clear_markers("g")
        for ns in ("g", "g/queue", "g/leases", "g/results"):
            assert store.list_markers(ns) == []
        # a sibling namespace is untouched, trash dirs are invisible
        store.put_marker("h", "m", {})
        store.clear_markers("g")          # idempotent on a missing ns
        assert store.list_markers("h") == ["m"]
        mroot = tmp_path / "_markers"
        assert [p.name for p in mroot.iterdir()
                if not p.name.startswith(".")] == ["h"]

    def test_concurrent_clear_never_exposes_half_namespace(self, tmp_path):
        # one thread clears while another polls: every observation is
        # all-20-markers or zero markers, never a partial count
        store = StreamStore(str(tmp_path))
        for i in range(20):
            store.put_marker("g/queue", f"m{i:02d}", {})
        seen = []
        done = threading.Event()

        def poller():
            while not done.is_set():
                seen.append(len(store.list_markers("g/queue")))

        t = threading.Thread(target=poller)
        t.start()
        time.sleep(0.02)
        store.clear_markers("g")
        done.set()
        t.join()
        assert set(seen) <= {0, 20}, f"partial namespace observed: {set(seen)}"


# ----------------------------------------------------------- lease protocol
class TestLeaseProtocol:
    def test_lease_expiry_and_renewal(self):
        lease = Lease(worker="w", dataset="d", max_range=10,
                      ttl_s=5.0, deadline=time.time() + 5.0)
        assert not lease.expired()
        assert lease.expired(now=time.time() + 6.0)
        renewed = lease.renew()
        assert renewed.beat == 1 and renewed.deadline > lease.deadline - 1
        rt = Lease.from_json(dict(renewed.to_json(), junk=1))
        assert rt == renewed

    def test_heartbeat_renews_and_drops_reaped(self, tmp_path):
        store = StreamStore(str(tmp_path))
        leases = {}
        for name in ("a__10", "b__10"):
            lease = Lease(worker="w", dataset=name[0], max_range=10,
                          ttl_s=0.3, deadline=time.time() + 0.3)
            store.put_marker("g/leases", name, lease.to_json())
            leases[name] = lease
        with Heartbeat(store, "g/leases", leases) as hb:
            time.sleep(0.5)
            # a reaper steals one lease mid-run: the heartbeat must NOT
            # resurrect it, and must report it lost
            store.remove_marker("g/leases", "b__10")
            time.sleep(0.5)
        assert store.get_marker("g/leases", "a__10")["beat"] >= 2
        assert Lease.from_json(
            store.get_marker("g/leases", "a__10")).expired() is False
        assert "b__10" in hb.lost
        assert not store.has_marker("g/leases", "b__10")

    def test_reap_requeues_expired_and_preserves_live(self, tmp_path):
        store = StreamStore(str(tmp_path))
        svc = SweepService(store, ["a", "b"], [10], lease_ttl_s=5.0,
                           breaker_threshold=3, worker_id="me")
        dead = Lease(worker="gone", dataset="a", max_range=10,
                     ttl_s=5.0, deadline=time.time() - 1.0, attempts=1)
        live = Lease(worker="alive", dataset="b", max_range=10,
                     ttl_s=5.0, deadline=time.time() + 60.0, attempts=1)
        store.put_marker(svc.ns_leases, "a__10", dead.to_json())
        store.put_marker(svc.ns_leases, "b__10", live.to_json())
        assert svc.reap() == ["a__10"]
        q = store.get_marker(svc.ns_queue, "a__10")
        assert q["attempts"] == 1 and q["dataset"] == "a"
        assert store.list_markers(svc.ns_leases) == ["b__10"]
        # re-claim carries the strike count forward
        claimed = svc.claim_batch(1)
        assert claimed["a__10"].attempts == 2

    def test_reap_poisons_after_breaker_threshold(self, tmp_path):
        store = StreamStore(str(tmp_path))
        svc = SweepService(store, ["a"], [10], lease_ttl_s=5.0,
                           breaker_threshold=3, worker_id="me")
        doomed = Lease(worker="gone", dataset="a", max_range=10,
                       ttl_s=5.0, deadline=time.time() - 1.0, attempts=3)
        store.put_marker(svc.ns_leases, "a__10", doomed.to_json())
        svc.reap()
        assert store.list_markers(svc.ns_queue) == []
        p = store.get_marker(svc.ns_poison, "a__10")
        assert p["attempts"] == 3 and p["last_worker"] == "gone"
        assert svc.outstanding() == []

    def test_reap_handles_claim_window_crash(self, tmp_path):
        # worker died between the queue→lease move and the lease rewrite:
        # the lease file still holds the QUEUE payload (no deadline);
        # the reaper falls back to file age vs the service TTL
        store = StreamStore(str(tmp_path))
        svc = SweepService(store, ["a"], [10], lease_ttl_s=0.05,
                           breaker_threshold=3, worker_id="me")
        store.put_marker(svc.ns_leases, "a__10",
                         {"dataset": "a", "max_range": 10, "attempts": 0})
        time.sleep(0.1)
        assert svc.reap() == ["a__10"]
        assert store.get_marker(svc.ns_queue, "a__10")["attempts"] == 1


# ------------------------------------------------------- end-to-end service
class TestServiceSweep:
    GRID = (["sogouq", "traffic"], [20, 40])

    def _direct(self, tmp_path):
        ref = Controller(str(tmp_path / "ref"))
        reports = ref.run_many(*self.GRID, _consumer, scale=0.002,
                               seed=9, backend="numpy")
        return reports, ref.last_fidelity

    @pytest.mark.timeout(120)
    def test_single_process_service_equals_direct(self, tmp_path):
        want, fid_want = self._direct(tmp_path)
        c = Controller(str(tmp_path / "svc"))
        got = c.run_many(*self.GRID, _consumer, scale=0.002, seed=9,
                         backend="numpy", service=True, lease_ttl_s=60,
                         service_poll_s=0.05)
        _assert_reports_equal(got, want)
        assert len(c.last_fidelity) == len(fid_want)
        for fa, fb in zip(fid_want, c.last_fidelity):
            assert fa.labels == fb.labels
            np.testing.assert_allclose(np.asarray(fa.trend_corr),
                                       np.asarray(fb.trend_corr),
                                       atol=1e-9)
            assert fb.provenance is not None and \
                len(fb.provenance) == len(fb.labels)
        # cooperative cleanup: no service state left behind
        mroot = tmp_path / "svc" / "_markers"
        assert not mroot.exists() or not any(
            not p.name.startswith(".") for p in mroot.iterdir())
        # only self-computed reports were persisted locally — here that
        # is all of them (single participant)
        assert len(c.list_metrics()) == len(got)

    @pytest.mark.timeout(120)
    def test_lease_batch_covers_whole_grid_in_one_claim(self, tmp_path):
        want, _ = self._direct(tmp_path)
        c = Controller(str(tmp_path / "svc"))
        got = c.run_many(*self.GRID, _consumer, scale=0.002, seed=9,
                         backend="numpy", service=True, lease_ttl_s=60,
                         lease_batch=4)
        _assert_reports_equal(got, want)

    @pytest.mark.timeout(180)
    def test_kill_worker_failover(self, tmp_path):
        """SIGKILL a worker subprocess mid-lease: its heartbeat stops,
        the lease expires, the surviving participant reaps + requeues,
        and the sweep completes with reports equal to an uninterrupted
        run (the killed scenario shows the extra lease attempt)."""
        want, fid_want = self._direct(tmp_path)
        store_dir = str(tmp_path / "svc")
        script = _ROGUE_WORKER.replace("@STORE@", store_dir)
        env = dict(os.environ, PYTHONPATH=SRC + os.pathsep +
                   os.environ.get("PYTHONPATH", ""))
        proc = subprocess.Popen([sys.executable, "-c", script], env=env,
                                stdout=subprocess.PIPE, text=True)
        try:
            line = proc.stdout.readline().strip()
            assert line.startswith("LEASED "), f"rogue said: {line!r}"
            leased = line.split(" ", 1)[1]
            os.kill(proc.pid, signal.SIGKILL)
            proc.wait(timeout=30)
            c = Controller(store_dir)
            got = c.run_many(*self.GRID, _consumer, scale=0.002, seed=9,
                             backend="numpy", service=True,
                             lease_ttl_s=2.0, service_poll_s=0.1,
                             service_deadline_s=120)
        finally:
            if proc.poll() is None:
                proc.kill()
        _assert_reports_equal(got, want)
        # the rogue never executed its scenario, so a complete grid is
        # only possible if the survivor reaped + requeued the dead
        # worker's lease (otherwise it would idle until the 120 s
        # service deadline and raise TimeoutError)
        by_name = {scenario_marker(r.dataset, r.max_range): r
                   for r in got}
        assert by_name[leased].status == "ok"
        # merged fidelity equals the uninterrupted single-host artifact
        assert len(c.last_fidelity) == len(fid_want)
        for fa, fb in zip(fid_want, c.last_fidelity):
            assert fa.labels == fb.labels
            np.testing.assert_allclose(np.asarray(fa.trend_corr),
                                       np.asarray(fb.trend_corr),
                                       atol=1e-9)

    @pytest.mark.timeout(120)
    def test_poisoned_scenario_quarantined_siblings_survive(self,
                                                            tmp_path):
        """A scenario that has already burned ``breaker_threshold``
        leases (repeated worker kills) is quarantined — surfaced as ONE
        ``status="poisoned"`` report — while its siblings complete and
        match the direct run."""
        want, _ = self._direct(tmp_path)
        store_dir = str(tmp_path / "svc")
        c = Controller(store_dir)
        # manufacture the killed-thrice state: queue published, target
        # scenario holds an expired lease with attempts == threshold
        svc = SweepService(c.store, *self.GRID, scale=0.002, seed=9,
                           breaker_threshold=3, worker_id="setup")
        svc.publish_queue()
        target = scenario_marker("sogouq", 20)
        assert c.store.claim_marker(svc.ns_queue, target,
                                    svc.ns_leases, target)
        doomed = Lease(worker="crashy", dataset="sogouq", max_range=20,
                       ttl_s=1.0, deadline=time.time() - 1.0, attempts=3)
        c.store.put_marker(svc.ns_leases, target, doomed.to_json())
        got = c.run_many(*self.GRID, _consumer, scale=0.002, seed=9,
                         backend="numpy", service=True, lease_ttl_s=60,
                         breaker_threshold=3, service_poll_s=0.05,
                         service_deadline_s=60)
        assert [r.status for r in got].count("poisoned") == 1
        poisoned = next(r for r in got if r.status == "poisoned")
        assert (poisoned.dataset, poisoned.max_range) == ("sogouq", 20)
        assert poisoned.attempts == 3
        assert poisoned.failure
        ok = [r for r in got if r.status == "ok"]
        ref = [r for r in want
               if (r.dataset, r.max_range) != ("sogouq", 20)]
        _assert_reports_equal(ok, ref)
        # the merged fidelity omits the quarantined row instead of
        # fabricating it
        for fr in c.last_fidelity:
            if fr.max_range == 20:
                assert "sogouq/sim20" not in fr.labels

    @pytest.mark.timeout(120)
    def test_service_rejects_chunk_and_checkpoint(self, tmp_path):
        c = Controller(str(tmp_path))
        with pytest.raises(ValueError, match="service"):
            c.run_many(["sogouq"], [20], _consumer, scale=0.002,
                       backend="numpy", service=True, chunk_s=10)
        with pytest.raises(ValueError, match="service"):
            c.run_many(["sogouq"], [20], _consumer, scale=0.002,
                       backend="numpy", service=True, checkpoint=True)


# -------------------------------------------------- static multi-host merge
@pytest.mark.timeout(180)
def test_static_multi_host_fidelity_merges_to_full_matrix(tmp_path):
    """Satellite: the PR 5 gap. Static hosts share one store; each run
    publishes its exact count rows under the host-independent group
    namespace, and the run that completes the grid gets the merged FULL
    S×S matrix on ``last_fidelity`` — equal to the single-host artifact,
    with per-row worker provenance. (Static slicing re-partitions the
    REMAINING scenarios each run, so sequential host runs converge on
    the grid over a few passes — the dynamic work queue that fixes that
    is the service path, tested above.)"""
    datasets, ranges = ["sogouq", "traffic"], [20, 40]
    ref = Controller(str(tmp_path / "ref"))
    ref.run_many(datasets, ranges, _consumer, scale=0.002, seed=9,
                 backend="numpy")
    fid_ref = ref.last_fidelity

    shared = str(tmp_path / "shared")
    c0 = Controller(shared, metrics_dir=str(tmp_path / "m0"))
    c0.run_many(datasets, ranges, _consumer, scale=0.002, seed=9,
                backend="numpy", n_devices=1, host_index=0, n_hosts=2)
    # rows are still missing, so this host keeps its partial per-host
    # matrices (pre-PR 9 behavior) — no provenance, not the full set
    assert c0.last_fidelity and (
        len(c0.last_fidelity) < len(ranges) or
        any(fr.provenance is None for fr in c0.last_fidelity))
    done = {(r["dataset"], r["max_range"]) for r in
            (json.load(open(p)) for p in c0.list_metrics())}
    # alternate host runs until the grid is covered (2-3 passes: each
    # pass re-slices what remains)
    last = c0
    for attempt in range(1, 5):
        host = attempt % 2
        c = Controller(shared, metrics_dir=str(tmp_path / f"m{attempt}"))
        reports = c.run_many(datasets, ranges, _consumer, scale=0.002,
                             seed=9, backend="numpy", n_devices=1,
                             host_index=host, n_hosts=2)
        done |= {(r.dataset, r.max_range) for r in reports}
        last = c
        if len(done) == len(datasets) * len(ranges):
            break
    assert len(done) == len(datasets) * len(ranges)
    assert len(last.last_fidelity) == len(fid_ref)
    for fa, fb in zip(fid_ref, last.last_fidelity):
        assert fb.labels == fa.labels, "merged matrix must be FULL"
        np.testing.assert_allclose(np.asarray(fb.trend_corr),
                                   np.asarray(fa.trend_corr), atol=1e-9)
        assert fb.provenance is not None
    # both hosts contributed rows somewhere in the merged artifact set
    # (first-writer-wins: a host re-reporting a cache hit never claims
    # the row its peer computed)
    contributors = {w for fr in last.last_fidelity
                    for w in fr.provenance}
    assert {"host0", "host1"} <= contributors


def test_merge_fidelity_tolerates_missing_rows(tmp_path):
    store = StreamStore(str(tmp_path))
    rng = np.random.default_rng(0)
    row = rng.integers(0, 50, size=600)
    store.put_marker("g/fidelity", "orig__a",
                     {"counts": row.tolist(), "worker": "w0"})
    store.put_marker("g/fidelity", "sim__a__10",
                     {"counts": row[::2].tolist(), "worker": "w1"})
    # dataset b has no rows at all; max_range 20 has none either
    out = merge_fidelity(store, "g", ["a", "b"], [10, 20])
    assert len(out) == 1
    assert out[0].labels == ["a/original", "a/sim10"]
    assert out[0].provenance == ["w0", "w1"]
    m = np.asarray(out[0].trend_corr)
    assert m.shape == (2, 2)
    assert np.allclose(np.diag(m), 1.0)


# ------------------------------------------------- jax.distributed 2-proc
@pytest.mark.timeout(300)
def test_two_process_jax_distributed_service(tmp_path):
    """The ROADMAP's 2-process CPU integration test: two REAL processes
    under ``jax.distributed.initialize`` run ``run_many(service=True)``
    against one shared store. Both must return the full grid, their
    reports must agree, the per-scenario work must be split between
    them, and the merged artifact must equal a single-host run."""
    want_ctrl = Controller(str(tmp_path / "ref"))
    want = want_ctrl.run_many(["sogouq", "traffic"], [20, 40], _consumer,
                              scale=0.002, seed=9, backend="numpy")
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep +
               os.environ.get("PYTHONPATH", ""),
               JAX_PLATFORMS="cpu")
    store_dir = str(tmp_path / "shared")
    procs = []
    for pid in range(2):
        script = _DISTRIBUTED_WORKER \
            .replace("@STORE@", store_dir) \
            .replace("@OUT@", str(tmp_path / f"out{pid}.json")) \
            .replace("@PORT@", str(port)) \
            .replace("@PID@", str(pid))
        procs.append(subprocess.Popen([sys.executable, "-c", script],
                                      env=env, stdout=subprocess.PIPE,
                                      stderr=subprocess.STDOUT,
                                      text=True))
    outs = [p.communicate(timeout=240)[0] for p in procs]
    for p, out in zip(procs, outs):
        assert p.returncode == 0, f"worker failed:\n{out}"
    payloads = [json.load(open(tmp_path / f"out{i}.json"))
                for i in range(2)]
    for payload in payloads:
        got = payload["reports"]
        assert len(got) == len(want)
        for a, b in zip(got, want):
            assert a["dataset"] == b.dataset
            assert a["max_range"] == b.max_range
            assert a["simulated_rows"] == b.simulated_rows
            assert a["trend_corr"] == pytest.approx(b.trend_corr,
                                                    abs=1e-9)
            assert a["status"] == "ok"
        assert payload["n_hosts"] == 2
    # the grid was actually SPLIT: each participant computed a disjoint,
    # jointly exhaustive subset
    mine0, mine1 = (set(p["mine"]) for p in payloads)
    assert mine0.isdisjoint(mine1)
    assert len(mine0 | mine1) == len(want)
    # both saw the merged FULL fidelity matrix
    for payload in payloads:
        for fr in payload["fidelity"]:
            assert len(fr["labels"]) == 2 * 2
            assert len(fr["provenance"]) == len(fr["labels"])


_ROGUE_WORKER = '''
import sys, time
from repro.streamsim.service import SweepService
from repro.streamsim.resilience import Heartbeat
from repro.streamsim.store import StreamStore

store = StreamStore("@STORE@")
svc = SweepService(store, ["sogouq", "traffic"], [20, 40], scale=0.002,
                   seed=9, lease_ttl_s=2.0, worker_id="rogue")
svc.publish_queue()
leases = svc.claim_batch(1)
assert leases, "rogue claimed nothing"
name = next(iter(leases))
hb = Heartbeat(store, svc.ns_leases, leases).__enter__()
print("LEASED " + name, flush=True)
time.sleep(600)   # hold the lease until SIGKILL stops the heartbeat
'''

_DISTRIBUTED_WORKER = '''
import json

import jax

jax.distributed.initialize(coordinator_address="127.0.0.1:@PORT@",
                           num_processes=2, process_id=@PID@)
from repro.streamsim import Controller
from repro.streamsim.service import scenario_marker


def consumer(queue):
    return {"records_seen": sum(len(b) for b in queue)}


c = Controller("@STORE@", metrics_dir="@STORE@/_metrics@PID@")
reports = c.run_many(["sogouq", "traffic"], [20, 40], consumer,
                     scale=0.002, seed=9, backend="numpy", service=True,
                     host_index=jax.process_index(),
                     n_hosts=jax.process_count(),
                     lease_ttl_s=60.0, service_poll_s=0.1,
                     service_deadline_s=180)
names = [p.name for p in c.list_metrics()]
payload = {
    "reports": [r.to_json() for r in reports],
    "fidelity": [f.to_json() for f in c.last_fidelity],
    "mine": sorted({scenario_marker(r.dataset, r.max_range)
                    for r in reports
                    if any(n.startswith(f"{r.dataset}_max{r.max_range}_")
                           for n in names)}),
    "n_hosts": jax.process_count(),
}
with open("@OUT@", "w") as f:
    json.dump(payload, f)
print("WORKER @PID@ OK", flush=True)
'''
