"""Unit tests for the paper's pipeline (POSD / NSA / PSDA).

Property-based (hypothesis) tests live in ``test_streamsim_properties.py``
behind ``pytest.importorskip`` so this module runs without hypothesis.
"""

import numpy as np
import pytest

from repro.streamsim import (
    Producer,
    StreamQueue,
    StreamStore,
    VirtualClock,
    make_stream,
    nsa,
    nsa_batched,
    nsa_paper,
    preprocess,
    volatility,
)
from repro.streamsim.nsa import compression_factor, scale_stamps


# ------------------------------------------------------------------- POSD
class TestPreprocess:
    @pytest.mark.parametrize("name", ["sogouq", "traffic", "userbehavior"])
    def test_identifies_time_and_sorts(self, name):
        raw = make_stream(name, scale=0.002, seed=1)
        s = preprocess(raw)
        assert len(s) == len(raw)
        assert np.all(np.diff(s.t) >= 0), "chronological order (Def. 1)"

    def test_timezone_unified(self):
        # userbehavior is stored shifted +8h; POSD must bring it back so all
        # datasets share the same day window
        ub = preprocess(make_stream("userbehavior", scale=0.002, seed=1))
        tr = preprocess(make_stream("traffic", scale=0.002, seed=1))
        # both spans must be ~1 day and start at a day boundary modulo tz
        assert abs(ub.time_range - tr.time_range) < 3600
        assert ub.time_range < 90_000

    def test_accurate_time_strings_parsed(self):
        raw = make_stream("sogouq", scale=0.002, seed=2)
        assert raw.columns["access_time"].dtype.kind in "US"
        s = preprocess(raw)
        assert s.t.dtype == np.float64

    def test_no_time_column_rejected(self):
        from repro.streamsim.datasets import RawStream
        raw = RawStream("x", {"a": np.arange(10), "b": np.arange(10.0)})
        with pytest.raises(ValueError):
            preprocess(raw)


# -------------------------------------------------------------------- NSA
class TestNSA:
    @pytest.mark.parametrize("max_range", [60, 600])
    def test_vectorized_equals_paper(self, small_stream, max_range):
        a = nsa(small_stream, max_range)
        b = nsa_paper(small_stream, max_range)
        assert np.array_equal(a.t, b.t)
        assert np.array_equal(a.scale_stamp, b.scale_stamp)
        for k in a.payload:
            assert np.array_equal(a.payload[k], b.payload[k])

    def test_volatility_preserved(self):
        s = preprocess(make_stream("userbehavior", scale=0.25, seed=3))
        v0 = volatility(s)
        for mr in (600, 3600):
            v = volatility(nsa(s, mr), mr)
            assert abs(v.average - v0.average) / v0.average < 0.05, \
                "per-second average must match the original (Tables 1-3)"
            assert v.variance <= v0.variance * 1.25
            assert v.variance >= v0.variance * 0.5

    def test_simulated_volatility_shrinks_with_scale(self):
        # paper §5.2: larger stream -> simulated volatility (relatively)
        # smaller than original
        s = preprocess(make_stream("sogouq", scale=0.5, seed=4))
        v0, v1 = volatility(s), volatility(nsa(s, 600), 600)
        assert v1.variance < v0.variance

    def test_compression_factor(self, small_stream):
        assert compression_factor(small_stream, 3600) >= 23.9, \
            "one day into one hour must be >= ~24x (paper §6)"

    def test_scale_stamp_bounds_and_order(self, small_stream):
        ss = scale_stamps(small_stream.t, 600)
        assert ss.min() >= 0 and ss.max() <= 599
        assert np.all(np.diff(ss) >= 0), "Min-Max preserves order"

    def test_multiple_modes(self):
        # at realistic rates the literal 'records' reading keeps far fewer
        # records than the Tables-1-3-consistent 'time' reading
        s = preprocess(make_stream("traffic", scale=0.2, seed=5))
        d_time = nsa(s, 600, multiple_mode="time")
        d_rec = nsa(s, 600, multiple_mode="records")
        assert len(d_rec) < len(d_time)

    def test_keep_first_vs_systematic(self):
        # needs k >= 2 kept per bucket for the orders to differ
        s = preprocess(make_stream("traffic", scale=0.2, seed=6))
        d_sys = nsa(s, 120, keep="systematic")
        d_first = nsa(s, 120, keep="first")
        assert len(d_sys) == len(d_first), "same per-bucket budget"
        assert not np.array_equal(d_sys.t, d_first.t)


# ---------------------------------------------------- device-resident path
def _streams_equal(a, b):
    assert np.array_equal(a.t, b.t)
    assert np.array_equal(a.scale_stamp, b.scale_stamp)
    assert set(a.payload) == set(b.payload)
    for k in a.payload:
        assert np.array_equal(a.payload[k], b.payload[k])


class TestNSABackends:
    @pytest.mark.parametrize("name", ["sogouq", "traffic", "userbehavior"])
    @pytest.mark.parametrize("max_range", [600, 3600])
    def test_pallas_bit_identical_on_paper_config(self, name, max_range):
        # the paper_stream config datasets x time-range endpoints: the
        # device path must reproduce the numpy output bit-for-bit
        s = preprocess(make_stream(name, scale=0.02, seed=11))
        _streams_equal(nsa(s, max_range, backend="pallas"),
                       nsa(s, max_range, backend="numpy"))

    def test_pallas_small_and_unaligned(self, small_stream):
        # record counts that are not TILE multiples exercise the padding
        for mr in (7, 60, 601):
            _streams_equal(nsa(small_stream, mr, backend="pallas"),
                           nsa(small_stream, mr))

    def test_auto_backend_matches(self, small_stream):
        _streams_equal(nsa(small_stream, 600, backend="auto"),
                       nsa(small_stream, 600))

    def test_bad_backend_rejected(self, small_stream):
        with pytest.raises(ValueError):
            nsa(small_stream, 600, backend="cuda")

    def test_giant_bucket_falls_back_to_numpy(self):
        # 100k identical timestamps -> one bucket whose (c-1)*k product is
        # outside the int32 kernel domain; the pallas backend must fall
        # back to numpy and still be bit-identical
        from repro.streamsim.preprocess import Stream
        s = Stream("burst", np.full(100_000, 5.0),
                   {"x": np.arange(100_000)})
        _streams_equal(nsa(s, 600, backend="pallas"),
                       nsa(s, 600, backend="numpy"))
        out = nsa_batched({"burst": s}, 600, backend="pallas")
        _streams_equal(out["burst"], nsa(s, 600))

    @pytest.mark.parametrize("backend", ["numpy", "pallas"])
    def test_batched_equals_per_stream(self, backend):
        streams = {
            name: preprocess(make_stream(name, scale=0.005, seed=13))
            for name in ("sogouq", "traffic", "userbehavior")
        }
        out = nsa_batched(streams, 300, backend=backend)
        assert set(out) == set(streams)
        for name, s in streams.items():
            _streams_equal(out[name], nsa(s, 300))


# ----------------------------------------------------------- PSDA producer
class TestProducer:
    def _sim(self, max_range=40):
        s = preprocess(make_stream("traffic", scale=0.003, seed=5))
        return nsa(s, max_range)

    def test_ordered_complete_delivery(self):
        sim = self._sim()
        q = StreamQueue(maxsize=1000)
        p = Producer(sim, q, clock=VirtualClock())
        assert p.run() == 0, "paper status success:0"
        buckets = list(q)
        stamps = [b.scale_stamp for b in buckets]
        assert stamps == sorted(stamps), "chronological emission"
        total = sum(len(b) for b in buckets)
        assert total == len(sim), "at-least-once, exactly-all delivery"

    def test_threaded_producer_matches_virtual(self):
        sim = self._sim(10)
        q1, q2 = StreamQueue(1000), StreamQueue(1000)
        assert Producer(sim, q1, clock=VirtualClock()).run() == 0
        p2 = Producer(sim, q2, clock=VirtualClock(), tick_s=0.001)
        assert p2.run_threaded() == 0
        b1, b2 = list(q1), list(q2)
        assert [b.scale_stamp for b in b1] == [b.scale_stamp for b in b2]
        assert sum(len(b) for b in b1) == sum(len(b) for b in b2)

    def test_backpressure(self):
        sim = self._sim(30)
        q = StreamQueue(maxsize=2)
        import threading
        p = Producer(sim, q, clock=VirtualClock())
        th = threading.Thread(target=p.run, daemon=True)
        th.start()
        got = list(q)  # consumer drains; producer must not deadlock/drop
        th.join(timeout=10)
        assert not th.is_alive()
        assert sum(len(b) for b in got) == len(sim)

    @pytest.mark.parametrize("max_range", [40, 5000])
    def test_gap_batched_run_matches_per_tick(self, max_range):
        # the VirtualClock fast path batches sleeps across empty buckets
        # (O(#non-empty) host work); the consumer-observable behaviour must
        # be identical to the literal per-second loop — same bucket
        # sequence, same emit_time stamps, same final clock value
        sim = self._sim(max_range)
        if max_range == 5000:   # dense case covers the no-gap edge
            assert len(np.unique(sim.scale_stamp)) < max_range, \
                "sparse case needs empty gaps"
        q1, q2 = StreamQueue(100_000), StreamQueue(100_000)
        p1 = Producer(sim, q1, clock=VirtualClock())
        assert p1.run() == 0
        p2 = Producer(sim, q2, clock=VirtualClock())
        assert p2._run_per_tick() == 0
        b1, b2 = list(q1), list(q2)
        assert [b.scale_stamp for b in b1] == [b.scale_stamp for b in b2]
        assert [b.emit_time for b in b1] == [b.emit_time for b in b2]
        assert p1.clock.now == p2.clock.now
        assert p1.stats() == p2.stats()

    def test_real_clock_keeps_per_tick_semantics(self):
        # non-virtual clocks must keep the paper's one-sleep-per-second
        # loop; a counting clock stands in for RealClock
        class CountingClock:
            def __init__(self):
                self.calls, self.now = 0, 0.0

            def sleep(self, s):
                self.calls += 1
                self.now += s

            def time(self):
                return self.now

        sim = self._sim(40)
        clock = CountingClock()
        q = StreamQueue(100_000)
        assert Producer(sim, q, clock=clock).run() == 0
        assert clock.calls == 40, "one sleep per simulated second"


# ------------------------------------------------------------------- store
class TestStore:
    def test_roundtrip_and_atomicity(self, tmp_path, small_stream):
        store = StreamStore(tmp_path)
        sim = nsa(small_stream, 60)
        store.put("traffic__sim60", sim)
        back = store.get("traffic__sim60")
        assert np.array_equal(back.t, sim.t)
        assert np.array_equal(back.scale_stamp, sim.scale_stamp)
        assert store.list() == ["traffic__sim60"]
        # no temp litter after writes (atomicity)
        litter = [p for p in (tmp_path / "traffic__sim60").iterdir()
                  if p.suffix == ".tmp"]
        assert litter == []

    def test_controller_end_to_end(self, tmp_path):
        from repro.streamsim import Controller

        def consumer(queue):
            n = sum(len(b) for b in queue)
            return {"records_seen": n}

        c = Controller(str(tmp_path / "store"))
        rep = c.run("traffic", 40, consumer, scale=0.002, seed=9)
        assert rep.consumer_metrics["records_seen"] == rep.simulated_rows
        assert rep.compression > 2000  # 86400/40
        assert len(c.list_metrics()) == 1
        # second run reuses stored streams (one-time preprocessing, §3.1)
        rep2 = c.run("traffic", 40, consumer, scale=0.002, seed=9)
        assert rep2.simulated_rows == rep.simulated_rows

    def test_cache_hit_reports_zero_nsa_time(self, tmp_path):
        # regression: a store-cache hit used to report the PREVIOUS run's
        # NSA wall time in the SimulationReport
        from repro.streamsim import Controller

        def consumer(queue):
            return {"records_seen": sum(len(b) for b in queue)}

        c = Controller(str(tmp_path / "store"))
        rep1 = c.run("traffic", 40, consumer, scale=0.002, seed=9)
        assert rep1.nsa_s > 0.0, "first run actually performs NSA"
        rep2 = c.run("traffic", 40, consumer, scale=0.002, seed=9)
        assert rep2.nsa_s == 0.0, "cache hit performs no NSA"

    def test_save_metrics_no_same_millisecond_collision(self, tmp_path):
        # regression: filenames were ms-resolution time.time() only, so two
        # reports in the same millisecond (routine under run_many)
        # overwrote each other
        from repro.streamsim import Controller, SimulationReport
        from repro.streamsim.metrics import Volatility

        c = Controller(str(tmp_path / "store"))
        v = Volatility(1.0, 0.5, 0.7, 40)
        rep = SimulationReport("traffic", 40, 100, 10, 2160.0, v, v, 0.9,
                               0.0, 0.0, 0.0, {})
        for _ in range(20):
            c.save_metrics(rep)
        assert len(c.list_metrics()) == 20


class TestRunMany:
    @staticmethod
    def _consumer(queue):
        return {"records_seen": sum(len(b) for b in queue)}

    def test_sweep_matches_per_scenario_run(self, tmp_path):
        # the batched scenario sweep must report exactly what sequential
        # per-scenario Controller.run reports
        from repro.streamsim import Controller

        datasets, max_ranges = ["traffic", "sogouq"], [40, 80]
        c = Controller(str(tmp_path / "batched"))
        reports = c.run_many(datasets, max_ranges, self._consumer,
                             scale=0.002, seed=9)
        assert [(r.dataset, r.max_range) for r in reports] == \
            [(d, mr) for d in datasets for mr in max_ranges]
        assert len(c.list_metrics()) == len(reports)

        ref_c = Controller(str(tmp_path / "sequential"))
        for r in reports:
            ref = ref_c.run(r.dataset, r.max_range, self._consumer,
                            scale=0.002, seed=9)
            assert r.original_rows == ref.original_rows
            assert r.simulated_rows == ref.simulated_rows
            assert r.compression == ref.compression
            assert r.trend_corr == pytest.approx(ref.trend_corr, rel=1e-9)
            for f in ("average", "variance", "std_variance", "time_range"):
                assert getattr(r.simulated_volatility, f) == pytest.approx(
                    getattr(ref.simulated_volatility, f), rel=1e-6)
                assert getattr(r.original_volatility, f) == pytest.approx(
                    getattr(ref.original_volatility, f), rel=1e-6)
            assert r.consumer_metrics["records_seen"] == \
                ref.consumer_metrics["records_seen"]

    def test_sweep_reuses_store_cache(self, tmp_path):
        from repro.streamsim import Controller

        c = Controller(str(tmp_path / "store"))
        c.run("traffic", 40, self._consumer, scale=0.002, seed=9)
        reports = c.run_many(["traffic"], [40], self._consumer,
                             scale=0.002, seed=9)
        assert reports[0].nsa_s == 0.0, "cached scenario performs no NSA"
