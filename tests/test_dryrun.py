"""Dry-run machinery tests: HLO cost analyzer validation + a reduced-mesh
lower/compile in a subprocess (the 512-device flag must not leak into this
process)."""

import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo

REPO = pathlib.Path(__file__).parent.parent

# the single cost_analysis() list-vs-dict compat shim lives in dryrun
from repro.launch.dryrun import xla_cost_analysis as _xla_cost  # noqa: E402


class TestHloAnalyzer:
    def test_loop_free_matches_xla(self):
        def f(x, w1, w2):
            return ((x @ w1) @ w2).sum()

        args = [jax.ShapeDtypeStruct(s, jnp.float32)
                for s in [(64, 128), (128, 256), (256, 512)]]
        c = jax.jit(f).lower(*args).compile()
        xla = _xla_cost(c)
        mine = analyze_hlo(c.as_text())
        exact = 2 * 64 * 128 * 256 + 2 * 64 * 256 * 512
        assert abs(mine["flops"] - exact) / exact < 0.01
        # bytes: ours models TPU dot-epilogue fusion (single-use dot outputs
        # stay on-chip), so it must be <= XLA's count and within ~2x
        assert mine["bytes"] <= xla["bytes accessed"] * 1.05
        assert mine["bytes"] >= xla["bytes accessed"] * 0.3

    def test_scan_trip_count_applied(self):
        def layer(x, w):
            return jax.nn.gelu(x @ w), None

        def g(x, ws):
            y, _ = jax.lax.scan(layer, x, ws)
            return y.sum()

        args = [jax.ShapeDtypeStruct((64, 128), jnp.float32),
                jax.ShapeDtypeStruct((32, 128, 128), jnp.float32)]
        c = jax.jit(g).lower(*args).compile()
        mine = analyze_hlo(c.as_text())
        exact = 32 * 2 * 64 * 128 * 128
        assert abs(mine["flops"] - exact) / exact < 0.01, \
            "while bodies must be multiplied by trip count"
        # XLA's own count misses the loop: stays far below exact
        assert _xla_cost(c)["flops"] < exact / 4

    def test_scan_bytes_not_inflated_by_stacked_params(self):
        # a scan reading one (128,128) slice per step must not count the
        # whole (32,128,128) stack per iteration
        def layer(x, w):
            return jax.nn.gelu(x @ w), None

        def g(x, ws):
            y, _ = jax.lax.scan(layer, x, ws)
            return y.sum()

        args = [jax.ShapeDtypeStruct((64, 128), jnp.float32),
                jax.ShapeDtypeStruct((32, 128, 128), jnp.float32)]
        c = jax.jit(g).lower(*args).compile()
        mine = analyze_hlo(c.as_text())
        # slice traffic: 32 iters * [x(64,128)*3-ish + w(128,128)*2] * 4B
        upper = 32 * (6 * 64 * 128 + 3 * 128 * 128) * 4
        assert mine["bytes"] < upper

    def test_grad_flops_ratio(self):
        # grad of matmul chain should cost ~3x forward
        def f(w, x):
            return ((x @ w) ** 2).sum()

        wspec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        xspec = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        fwd = analyze_hlo(jax.jit(f).lower(wspec, xspec).compile().as_text())
        bwd = analyze_hlo(jax.jit(jax.grad(f)).lower(
            wspec, xspec).compile().as_text())
        assert 1.5 <= bwd["flops"] / fwd["flops"] <= 3.5


SMOKE_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, json
import repro.launch.dryrun as D
import repro.launch.mesh as M
# shrink the production mesh for a CPU-sized smoke of the same code path
M.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 4) if multi_pod else (4, 4),
    ("pod", "data", "model") if multi_pod else ("data", "model"),
    **M._axis_types_kwargs(3 if multi_pod else 2))
D.make_production_mesh = M.make_production_mesh
import repro.configs as C
# reduced shapes so a smoke config lowers in seconds
C.SHAPES = {
    "train_4k": C.ShapeSpec("train_4k", 64, 8, "train"),
    "decode_32k": C.ShapeSpec("decode_32k", 64, 8, "decode"),
}
D.SHAPES = C.SHAPES
import repro.configs.llama3_8b as L
cfgs = {"llama3-8b": L.smoke().replace(loss_chunk=16)}
D.get_config = lambda a: cfgs[a]
for shape in ("train_4k", "decode_32k"):
    for mesh in ("single", "multi"):
        r = D.run_cell("llama3-8b", shape, mesh, verbose=False)
        assert r["ok"], r.get("error")
        assert r["hlo_flops"] > 0
        assert r["roofline"]["dominant"] in ("compute_s", "memory_s",
                                             "collective_s")
print("DRYRUN_SMOKE_OK")
"""


class TestDryRunSmoke:
    def test_reduced_mesh_cells_compile(self):
        r = subprocess.run(
            [sys.executable, "-c", SMOKE_DRYRUN], capture_output=True,
            text=True, timeout=900,
            env={**os.environ, "PYTHONPATH": "src"}, cwd=REPO)
        assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
        assert "DRYRUN_SMOKE_OK" in r.stdout
