"""Stream-task tier: determinism + paper-claim equivalence suite.

The task tier (repro.streamsim.tasks / taskbench) is the SPS side of the
paper's headline claim: simulated replay accelerates a stream task while
the task's own output keeps the original's volatility and trends. These
tests pin:

- task semantics (ETL cleaning, windowed aggregates, threshold/CUSUM
  detection, the watermark reorder buffer) against hand oracles;
- determinism: identical seeds -> bit-identical task output over
  VirtualClock replay (latency bins are wall-time and explicitly exempt);
- equivalence: simulated-vs-original task output trend correlation >= the
  documented FIDELITY_FLOOR, and simulated replay faster for every task;
- the device latency-histogram path (one fused dispatch per sweep);
- engine integration: tasks as Controller.run_many consumers (monolithic
  and chunked), QueueGroup drain order, and the wedged-task deadline
  error naming the task, not just the scenario.
"""

import threading

import numpy as np
import pytest

from repro.streamsim import (
    Controller,
    ETLTask,
    EventDetectTask,
    Producer,
    StreamQueue,
    VirtualClock,
    WindowedStatsTask,
    consumer_label,
    make_stream,
    nsa,
    preprocess,
)
from repro.streamsim.engine import replay_many
from repro.streamsim.queue import Bucket
from repro.streamsim.taskbench import (
    FIDELITY_FLOOR,
    TaskBenchRunner,
    original_replay_stream,
    slice_stream,
    summarize_latencies,
)
from repro.streamsim.tasks import output_series


def bucket(stamp, count, value=1.0):
    return Bucket(scale_stamp=stamp, t=np.full(count, float(stamp)),
                  payload={"v": np.full(count, value)}, emit_time=0.0)


def feed(buckets, maxsize=None):
    """A closed queue preloaded with the given buckets."""
    q = StreamQueue(maxsize=maxsize or max(len(buckets), 1))
    for b in buckets:
        q.put(b)
    q.close()
    return q


@pytest.fixture(scope="module")
def source():
    """A 2-hour slice of sogouq at a realistic rate plus its simulation."""
    orig = slice_stream(preprocess(make_stream("sogouq", scale=0.3, seed=0)),
                        7200)
    return orig, nsa(orig, 100)


@pytest.fixture(scope="module")
def bench_reports(source):
    """One TaskBenchRunner pass shared by the equivalence assertions."""
    runner = TaskBenchRunner(["sogouq"], [100], scale=0.3, seed=0,
                             span_s=7200, backend="numpy")
    tasks = [ETLTask(), WindowedStatsTask(window_s=30),
             EventDetectTask(mode="threshold", threshold=4.0)]
    return {r.task: r for r in runner.run(tasks)}


# ------------------------------------------------------------ output series
class TestOutputSeries:
    def test_accumulates_duplicate_stamps(self):
        out = output_series([2, 0, 2], [3, 1, 4])
        assert out.tolist() == [1, 0, 7]

    def test_empty(self):
        assert len(output_series([], [])) == 0

    def test_negative_stamp_raises(self):
        with pytest.raises(ValueError):
            output_series([-1], [1])


# ----------------------------------------------------------------- ETL task
class TestETLTask:
    def test_all_clean_without_bounds(self):
        m = ETLTask()(feed([bucket(0, 3), bucket(2, 2)]))
        assert m["etl_clean"] == 5 and m["etl_dirty"] == 0
        assert m["task_output_counts"].tolist() == [3, 0, 2]

    def test_bounds_filter_drops(self):
        q = feed([bucket(0, 4, value=10.0), bucket(1, 2, value=1.0)])
        m = ETLTask(bounds={"v": (0.0, 5.0)})(q)
        assert m["etl_clean"] == 2 and m["etl_dirty"] == 4
        assert m["task_output_counts"].tolist() == [0, 2]

    def test_nonfinite_records_dropped(self):
        b = bucket(0, 3)
        b.payload["v"][1] = np.nan
        m = ETLTask()(feed([b]))
        assert m["etl_clean"] == 2 and m["etl_dirty"] == 1

    def test_checksum_deterministic(self, source):
        _, sim = source
        runs = [replay_many({("s", 100): sim}, ETLTask(), 64)[0][("s", 100)]
                for _ in range(2)]
        assert runs[0]["etl_checksum"] == runs[1]["etl_checksum"]

    def test_common_metric_keys(self):
        m = ETLTask()(feed([bucket(0, 1)]))
        for key in ("task", "task_buckets", "task_records", "task_wall_s",
                    "task_throughput_rps", "task_latency_bins",
                    "task_output_counts"):
            assert key in m
        assert m["task"] == "etl"
        assert m["task_latency_bins"].dtype == np.int32


# --------------------------------------------------------------- STATS task
class TestWindowedStatsTask:
    def test_sliding_matches_convolve_oracle(self):
        rng = np.random.default_rng(0)
        q = rng.integers(0, 20, 257).astype(np.float64)
        task = WindowedStatsTask(window_s=16)
        oracle = np.convolve(q, np.ones(16) / 16, mode="same")
        np.testing.assert_allclose(task.aggregate(q), oracle, atol=1e-9)

    def test_tumbling_partial_window_uses_true_length(self):
        task = WindowedStatsTask(window_s=4, mode="tumbling")
        agg = task.aggregate(np.array([2.0, 2, 2, 2, 6, 6]))
        assert agg.tolist() == [2.0, 6.0]   # trailing pair means over 2

    def test_window_clamped_to_series(self):
        """A window wider than the series clamps to its length and keeps
        the convolve mode=\"same\" zero-padded-edge convention."""
        task = WindowedStatsTask(window_s=100)
        agg = task.aggregate(np.array([1.0, 3.0]))
        oracle = np.convolve([1.0, 3.0], np.ones(2) / 2, mode="same")
        np.testing.assert_allclose(agg, oracle)

    def test_bad_mode_and_window_raise(self):
        with pytest.raises(ValueError):
            WindowedStatsTask(mode="hopping")
        with pytest.raises(ValueError):
            WindowedStatsTask(window_s=0)

    def test_consumer_metrics_carry_aggregate(self):
        m = WindowedStatsTask(window_s=2)(feed([bucket(0, 2), bucket(1, 4)]))
        assert m["stats_mode"] == "sliding"
        assert m["stats_peak"] >= m["stats_mean"] > 0
        assert len(m["stats_aggregate"]) == 2


# ----------------------------------------------------------- detection task
class TestEventDetectTask:
    def test_threshold_event_stamps_exact(self):
        task = EventDetectTask(mode="threshold", threshold=2.5)
        m = task(feed([bucket(0, 1), bucket(1, 3), bucket(2, 2),
                       bucket(3, 5)]))
        assert m["task_events"].tolist() == [1, 3]
        assert m["detect_events"] == 2

    def test_threshold_requires_threshold(self):
        with pytest.raises(ValueError):
            EventDetectTask(mode="threshold")

    def test_bad_mode_raises(self):
        with pytest.raises(ValueError):
            EventDetectTask(mode="zscore", threshold=1.0)

    def test_cusum_fires_on_burst(self):
        quiet = [bucket(i, 2) for i in range(30)]
        burst = [bucket(30 + i, 12) for i in range(10)]
        m = EventDetectTask(mode="cusum", drift=0.5, h=5.0)(
            feed(quiet + burst))
        assert m["detect_events"] >= 1
        assert m["task_events"].min() >= 30   # only inside the burst

    def test_cusum_quiet_on_flat(self):
        m = EventDetectTask(mode="cusum", drift=0.5, h=5.0)(
            feed([bucket(i, 3) for i in range(50)]))
        assert m["detect_events"] == 0

    def test_watermark_restores_order(self):
        """A w-displaced arrival order with tolerance w detects EXACTLY
        like the in-order replay (the invariance the chaos layer leans
        on)."""
        rng = np.random.default_rng(3)
        counts = rng.integers(0, 10, 120)
        buckets = [bucket(i, int(c)) for i, c in enumerate(counts)]
        w = 8
        shuffled = []
        for i in range(0, len(buckets), w):   # block shuffle: displacement < w
            block = buckets[i:i + w]
            rng.shuffle(block)
            shuffled.extend(block)
        kw = dict(mode="cusum", drift=0.5, h=4.0)
        ordered = EventDetectTask(reorder_tolerance=w, **kw)(feed(buckets))
        reordered = EventDetectTask(reorder_tolerance=w, **kw)(feed(shuffled))
        assert ordered["task_events"].tolist() == \
            reordered["task_events"].tolist()

    def test_threshold_invariant_under_any_order(self):
        buckets = [bucket(i, int(c)) for i, c in
                   enumerate([1, 7, 2, 9, 0, 8, 3])]
        task = EventDetectTask(mode="threshold", threshold=5.0)
        a = task(feed(buckets))
        b = task(feed(list(reversed(buckets))))
        assert sorted(a["task_events"]) == sorted(b["task_events"])

    def test_negative_tolerance_raises(self):
        with pytest.raises(ValueError):
            EventDetectTask(mode="cusum", reorder_tolerance=-1)


# ------------------------------------------------------- latency histograms
class TestLatencySummary:
    def test_quantiles_match_nearest_rank(self):
        rng = np.random.default_rng(1)
        bins = rng.integers(0, 400, 5000).astype(np.int32)
        s = summarize_latencies([bins], bin_us=5.0, backend="numpy")[0]
        for p, got in ((0.50, s.p50_us), (0.99, s.p99_us),
                       (0.999, s.p999_us)):
            rank = int(np.ceil(p * len(bins)))
            expect = (np.sort(bins)[rank - 1] + 0.5) * 5.0
            assert got == pytest.approx(expect)

    def test_mean_and_jitter_from_histogram(self):
        bins = np.array([10, 10, 20, 20], np.int32)
        s = summarize_latencies([bins], bin_us=2.0, backend="numpy")[0]
        centers = (bins + 0.5) * 2.0
        assert s.mean_us == pytest.approx(centers.mean())
        assert s.jitter_us == pytest.approx(centers.std())

    def test_constant_bins_zero_jitter(self):
        s = summarize_latencies([np.full(64, 7, np.int32)],
                                backend="numpy")[0]
        assert s.jitter_us == pytest.approx(0.0)
        assert s.p50_us == s.p999_us

    def test_empty_scenario_is_nan(self):
        s = summarize_latencies([np.zeros(0, np.int32)], backend="numpy")[0]
        assert s.samples == 0 and np.isnan(s.p50_us)

    def test_no_scenarios(self):
        assert summarize_latencies([]) == []

    def test_one_fused_dispatch_per_sweep(self, monkeypatch):
        """S scenarios' latency bins must cost ONE stream_metrics_batched
        call (the device histogram path), not S."""
        from repro.kernels import ops
        calls = []
        real = ops.stream_metrics_batched

        def counting(ss_seq, max_range):
            calls.append(len(list(ss_seq)))
            return real(ss_seq, max_range)

        monkeypatch.setattr(ops, "stream_metrics_batched", counting)
        rng = np.random.default_rng(2)
        arrays = [rng.integers(0, 50, 100).astype(np.int32)
                  for _ in range(5)]
        out = summarize_latencies(arrays, n_bins=64, backend="auto")
        assert calls == [5]
        assert len(out) == 5 and all(o.samples == 100 for o in out)

    def test_device_path_matches_numpy(self):
        rng = np.random.default_rng(4)
        arrays = [rng.integers(0, 30, n).astype(np.int32)
                  for n in (0, 17, 256)]
        a = summarize_latencies(arrays, n_bins=32, backend="auto")
        b = summarize_latencies(arrays, n_bins=32, backend="numpy")
        for x, y in zip(a, b):
            assert x.samples == y.samples
            if x.samples:
                assert x.to_dict() == pytest.approx(y.to_dict())


# ------------------------------------------------- determinism + equivalence
class TestDeterminismAndEquivalence:
    def test_identical_seeds_identical_output(self):
        """Two independent end-to-end pipelines from the same seed agree
        bit-for-bit on every deterministic task output."""
        runs = []
        for _ in range(2):
            orig = slice_stream(
                preprocess(make_stream("sogouq", scale=0.2, seed=7)), 3600)
            sim = nsa(orig, 60)
            m, _ = replay_many({("sogouq", 60): sim}, ETLTask(), 64)
            runs.append(m[("sogouq", 60)])
        a, b = runs
        np.testing.assert_array_equal(a["task_output_counts"],
                                      b["task_output_counts"])
        assert a["etl_checksum"] == b["etl_checksum"]
        assert a["task_records"] == b["task_records"]

    def test_different_seed_differs(self):
        outs = []
        for seed in (0, 1):
            orig = slice_stream(
                preprocess(make_stream("sogouq", scale=0.2, seed=seed)),
                3600)
            m, _ = replay_many({("s", 0): nsa(orig, 60)}, ETLTask(), 64)
            outs.append(m[("s", 0)]["task_output_counts"])
        assert not np.array_equal(*outs)

    def test_replay_matches_direct_feed(self, source):
        """The engine transport adds nothing: replaying through
        replay_many equals feeding the same buckets straight in."""
        _, sim = source
        task = EventDetectTask(mode="threshold", threshold=4.0)
        via_engine, _ = replay_many({("s", 100): sim}, task, 64)
        q = StreamQueue(maxsize=256)
        th = threading.Thread(
            target=Producer(sim, q, clock=VirtualClock()).run, daemon=True)
        th.start()
        direct = task(q)
        th.join()
        np.testing.assert_array_equal(
            via_engine[("s", 100)]["task_output_counts"],
            direct["task_output_counts"])
        np.testing.assert_array_equal(
            via_engine[("s", 100)]["task_events"], direct["task_events"])

    @pytest.mark.parametrize("task_name", ["etl", "windowed-stats",
                                           "event-detect"])
    def test_fidelity_above_documented_floor(self, bench_reports, task_name):
        rep = bench_reports[task_name]
        assert rep.trend_fidelity >= FIDELITY_FLOOR, (
            f"{task_name}: simulated-replay output trend diverged "
            f"({rep.trend_fidelity:.3f} < floor {FIDELITY_FLOOR})")

    @pytest.mark.parametrize("task_name", ["etl", "windowed-stats",
                                           "event-detect"])
    def test_simulated_replay_accelerates(self, bench_reports, task_name):
        rep = bench_reports[task_name]
        assert rep.speedup > 1.0
        assert rep.t_simulated_s < rep.t_original_s

    def test_volatility_digest_present(self, bench_reports):
        rep = bench_reports["etl"]
        assert rep.cv_original > 0 and rep.cv_simulated > 0

    def test_report_to_dict(self, bench_reports):
        d = bench_reports["etl"].to_dict()
        for key in ("task", "dataset", "max_range", "speedup",
                    "paper_ratio", "trend_fidelity", "latency"):
            assert key in d
        assert d["paper_ratio"] == 24.0
        assert d["latency"]["samples"] > 0

    def test_original_replay_stream_stamps(self, source):
        orig, _ = source
        stamped = original_replay_stream(orig)
        assert stamped.scale_stamp.min() == 0
        assert stamped.scale_stamp.max() <= 7200
        assert len(stamped.scale_stamp) == len(orig.t)

    def test_runner_validates_inputs(self):
        with pytest.raises(ValueError):
            TaskBenchRunner([], [100])
        with pytest.raises(ValueError):
            slice_stream(preprocess(make_stream("sogouq", scale=0.01,
                                                seed=0)), 0)


# -------------------------------------------------------- engine integration
class TestEngineIntegration:
    def test_task_through_controller_run_many(self, tmp_path):
        ctrl = Controller(tmp_path / "store")
        reports = ctrl.run_many(["sogouq"], [60, 120], ETLTask(),
                                scale=0.02, seed=3, backend="numpy")
        assert len(reports) == 2
        for r in reports:
            cm = r.consumer_metrics
            assert cm["task"] == "etl"
            assert cm["etl_clean"] == cm["task_records"]
            assert len(cm["task_output_counts"]) <= r.max_range

    def test_task_through_chunked_path(self, tmp_path):
        """Tasks consume the PR 7 chunked pipeline unchanged, and the
        chunked replay feeds the same buckets as the monolithic one."""
        a = Controller(tmp_path / "a").run_many(
            ["sogouq"], [60], EventDetectTask(mode="threshold",
                                              threshold=3.0),
            scale=0.02, seed=3, backend="numpy", chunk_s=17)
        b = Controller(tmp_path / "b").run_many(
            ["sogouq"], [60], EventDetectTask(mode="threshold",
                                              threshold=3.0),
            scale=0.02, seed=3, backend="numpy")
        ca, cb = a[0].consumer_metrics, b[0].consumer_metrics
        np.testing.assert_array_equal(ca["task_output_counts"],
                                      cb["task_output_counts"])
        np.testing.assert_array_equal(ca["task_events"], cb["task_events"])

    def test_queuegroup_drain_order(self, source):
        """Drain-order regression: each scenario's queue must deliver its
        buckets in the producer's stamp order even with sibling scenarios
        interleaved in one merged walk."""
        _, sim = source
        sims = {("sogouq", 100): sim, ("sogouq-b", 100): sim}

        class OrderProbe(ETLTask):
            name = "order-probe"

            def _start(self):
                state = super()._start()
                state["order"] = []
                return state

            def _process(self, state, bucket):
                state["order"].append(int(bucket.scale_stamp))
                return super()._process(state, bucket)

            def _finalize(self, state, out):
                return {**super()._finalize(state, out),
                        "order": list(state["order"])}

        metrics, _ = replay_many(sims, OrderProbe(), 16)
        expect = sorted(np.unique(sim.scale_stamp).tolist())
        for key, m in metrics.items():
            assert m["order"] == expect, f"{key} drained out of order"

    def test_wedged_deadline_names_task(self, source):
        """Satellite fix: the consumer_deadline_s classification must name
        the wedged TASK, not just its scenario."""
        _, sim = source

        class WedgedTask:
            name = "wedge-probe"

            def __call__(self, queue):
                for _ in queue:
                    import time
                    time.sleep(3600)
                return {}

        with pytest.raises(RuntimeError) as exc_info:
            replay_many({("sogouq", 100): sim}, WedgedTask(), 16,
                        consumer_deadline_s=0.3)
        msg = str(exc_info.value)
        assert "wedge-probe" in msg
        assert "('sogouq', 100)" in msg
        cause = exc_info.value.__cause__
        assert isinstance(cause, TimeoutError)
        assert "running task 'wedge-probe'" in str(cause)

    def test_wedged_deadline_names_plain_function(self, source):
        _, sim = source

        def slowpoke(queue):
            import time
            for _ in queue:
                time.sleep(3600)
            return {}

        with pytest.raises(RuntimeError) as exc_info:
            replay_many({("sogouq", 100): sim}, slowpoke, 16,
                        consumer_deadline_s=0.3)
        assert "slowpoke" in str(exc_info.value)

    def test_consumer_label(self):
        assert consumer_label(ETLTask()) == "etl"

        def plain(queue):
            return {}

        assert consumer_label(plain) == "plain"

        class Named:
            task_name = "custom"

        assert consumer_label(Named()) == "custom"
        assert consumer_label(object()) is None


# --------------------------------------------------------------- serving task
class TestServingTask:
    @pytest.fixture(scope="class")
    def engine_setup(self):
        jax = pytest.importorskip("jax")
        from repro.configs.paper_stream import consumer_lm
        from repro.models import transformer as T
        cfg = consumer_lm().replace(n_layers=2, d_model=64, n_heads=4,
                                    n_kv_heads=2, head_dim=16, d_ff=128,
                                    vocab_size=512, loss_chunk=16)
        return cfg, T.init_params(cfg, jax.random.PRNGKey(0))

    def test_serving_smoke_on_cpu(self, engine_setup):
        """ServingTask drains a simulated replay on the CPU backend:
        every admitted request finishes and the latency digest is sane."""
        from repro.streamsim import ServingTask
        cfg, params = engine_setup
        orig = preprocess(make_stream("sogouq", scale=0.005, seed=4))
        sim = nsa(orig, 30)
        task = ServingTask(cfg, params, slots=4, max_len=48, prompt_len=4,
                           max_new_tokens=3, max_requests_per_bucket=2)
        metrics, _ = replay_many({("sogouq", 30): sim}, task, 64)
        m = metrics[("sogouq", 30)]
        assert m["task"] == "serving"
        assert m["task_records"] > 5
        assert m["serving_finished"] == m["task_records"]
        assert len(m["task_latency_bins"]) == m["task_records"]
        # regression: arrivals must be restamped onto the engine's wall
        # clock — the virtual emit_time stamp puts EVERY latency in the
        # overflow bin (latency ~= process uptime)
        assert m["task_latency_bins"].max() < task.n_bins - 1
        s = summarize_latencies([m["task_latency_bins"]],
                                bin_us=task.bin_us, n_bins=task.n_bins,
                                backend="numpy")[0]
        assert s.p50_us > 0 and s.p999_us >= s.p99_us >= s.p50_us

    def test_reuse_engine_resets_state(self, engine_setup):
        from repro.streamsim import ServingTask
        cfg, params = engine_setup
        orig = preprocess(make_stream("sogouq", scale=0.003, seed=5))
        sim = nsa(orig, 20)
        task = ServingTask(cfg, params, slots=2, max_len=48, prompt_len=4,
                           max_new_tokens=2, max_requests_per_bucket=1,
                           reuse_engine=True)
        runs = [replay_many({("s", 20): sim}, task, 64)[0][("s", 20)]
                for _ in range(2)]
        assert runs[0]["task_records"] == runs[1]["task_records"]
        assert runs[0]["serving_finished"] == runs[1]["serving_finished"]
        np.testing.assert_array_equal(runs[0]["task_output_counts"],
                                      runs[1]["task_output_counts"])
