"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.stream_sample import TILE


def _sorted_times(n, span, seed, dtype=np.float64):
    rng = np.random.default_rng(seed)
    t = np.sort(rng.uniform(0, span, n)).astype(dtype)
    t[0], t[-1] = 0.0, span
    return t


class TestStreamSample:
    @pytest.mark.parametrize("n", [64, 1024, 4096, 10_000])
    @pytest.mark.parametrize("max_range", [16, 128, 600])
    def test_matches_oracle(self, n, max_range):
        t = _sorted_times(n, 86_400.0, seed=n + max_range)
        mult = 86_400.0 / max_range
        ss_k, keep_k = ops.stream_sample(t, max_range, mult)
        ss_o, keep_o = ops.stream_sample_ref(t, max_range, mult)
        np.testing.assert_array_equal(np.asarray(ss_k), np.asarray(ss_o))
        np.testing.assert_array_equal(np.asarray(keep_k), np.asarray(keep_o))

    def test_matches_host_nsa(self):
        from repro.streamsim.nsa import scale_stamps, systematic_keep_mask
        t = _sorted_times(20_000, 86_400.0, seed=1)
        mr, mult = 300, 86_400.0 / 300
        ss_np = scale_stamps(t, mr)
        keep_np = systematic_keep_mask(ss_np, mr, mult)
        ss_k, keep_k = ops.stream_sample(t, mr, mult)
        assert np.mean(np.asarray(ss_k) == ss_np) > 0.999
        assert np.mean(np.asarray(keep_k) == keep_np) > 0.999

    @pytest.mark.parametrize("dtype", [np.float64, np.float32])
    def test_dtypes(self, dtype):
        t = _sorted_times(TILE, 1000.0, seed=3, dtype=dtype)
        ss, keep = ops.stream_sample(t, 50, 20.0)
        assert ss.dtype == jnp.int32
        assert int(keep.sum()) >= 50 // 2


class TestBucketHist:
    @pytest.mark.parametrize("n,max_range", [(512, 16), (4096, 128),
                                             (20_000, 600), (1024, 3600)])
    def test_matches_oracle(self, n, max_range):
        rng = np.random.default_rng(n)
        ss = np.sort(rng.integers(0, max_range, n)).astype(np.int32)
        h_k = ops.bucket_hist(ss, max_range)
        h_o = ref.bucket_hist_ref(jnp.asarray(ss), max_range)
        np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_o))
        assert int(h_k.sum()) == n


class TestVolatility:
    @pytest.mark.parametrize("n", [60, 600, 3600, 86_400])
    def test_moments(self, n):
        rng = np.random.default_rng(n)
        q = rng.poisson(25.0, n).astype(np.float32)
        avg, var, std = ops.volatility_stats(q)
        assert np.isclose(float(avg), q.mean(), rtol=1e-5)
        assert np.isclose(float(var), q.var(), rtol=1e-4)
        assert np.isclose(float(std), q.std(), rtol=1e-4)

    def test_against_ref(self):
        q = np.arange(1024, dtype=np.float32)
        s, s2 = ops.volatility_moments(q)
        exp = ref.volatility_ref(jnp.asarray(q))
        assert np.isclose(float(s), float(exp[0]))
        assert np.isclose(float(s2), float(exp[1]), rtol=1e-6)


class TestFlashDecode:
    @pytest.mark.parametrize("b,h,kh,d,s", [
        (1, 4, 4, 32, 256),     # MHA
        (2, 8, 2, 64, 512),     # GQA 4:1
        (4, 16, 1, 64, 1024),   # MQA
        (2, 12, 4, 128, 384),   # uneven block tail
    ])
    def test_matches_oracle(self, b, h, kh, d, s):
        key = jax.random.PRNGKey(b * 100 + s)
        q = jax.random.normal(key, (b, h, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, d))
        lens = jax.random.randint(jax.random.fold_in(key, 3), (b,), 1, s + 1)
        out = ops.flash_decode(q, k, v, lens, block_s=128)
        exp = ref.flash_decode_ref(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        key = jax.random.PRNGKey(0)
        b, h, kh, d, s = 2, 8, 4, 64, 256
        q = jax.random.normal(key, (b, h, d), jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, d),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, d),
                              jnp.bfloat16)
        lens = jnp.full((b,), s, jnp.int32)
        out = ops.flash_decode(q, k, v, lens, block_s=128)
        exp = ref.flash_decode_ref(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=5e-2, atol=5e-2)

    def test_prefix_only_attention(self):
        """Tokens beyond `lengths` must not influence the output."""
        key = jax.random.PRNGKey(7)
        b, h, kh, d, s = 2, 4, 2, 32, 256
        q = jax.random.normal(key, (b, h, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, d))
        lens = jnp.array([100, 40], jnp.int32)
        out1 = ops.flash_decode(q, k, v, lens, block_s=64)
        k2 = k.at[:, 150:].set(999.0)
        v2 = v.at[:, 150:].set(-999.0)
        out2 = ops.flash_decode(q, k2, v2, lens, block_s=64)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)
