"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.stream_sample import TILE


def _sorted_times(n, span, seed, dtype=np.float64):
    rng = np.random.default_rng(seed)
    t = np.sort(rng.uniform(0, span, n)).astype(dtype)
    t[0], t[-1] = 0.0, span
    return t


class TestStreamSample:
    @pytest.mark.parametrize("n", [64, 1024, 4096, 10_000])
    @pytest.mark.parametrize("max_range", [16, 128, 600])
    def test_matches_oracle(self, n, max_range):
        t = _sorted_times(n, 86_400.0, seed=n + max_range)
        mult = 86_400.0 / max_range
        ss_k, keep_k = ops.stream_sample(t, max_range, mult)
        ss_o, keep_o = ops.stream_sample_ref(t, max_range, mult)
        np.testing.assert_array_equal(np.asarray(ss_k), np.asarray(ss_o))
        np.testing.assert_array_equal(np.asarray(keep_k), np.asarray(keep_o))

    def test_matches_host_nsa_exactly(self):
        # the +-1 bucket snap against exact f64 tables makes the kernel
        # bit-identical to the host path, not merely close
        from repro.streamsim.nsa import scale_stamps, systematic_keep_mask
        t = _sorted_times(20_000, 86_400.0, seed=1)
        mr, mult = 300, 86_400.0 / 300
        ss_np = scale_stamps(t, mr)
        keep_np = systematic_keep_mask(ss_np, mr, mult)
        ss_k, keep_k = ops.stream_sample(t, mr, mult)
        np.testing.assert_array_equal(np.asarray(ss_k), ss_np)
        np.testing.assert_array_equal(np.asarray(keep_k), keep_np)

    @pytest.mark.parametrize("dtype", [np.float64, np.float32])
    def test_dtypes(self, dtype):
        t = _sorted_times(TILE, 1000.0, seed=3, dtype=dtype)
        ss, keep = ops.stream_sample(t, 50, 20.0)
        assert ss.dtype == jnp.int32
        assert int(keep.sum()) >= 50 // 2

    def test_keep_rule_overflow_refused(self):
        # (c-1)*k >= 2**31 would wrap the int32 Bresenham product and
        # silently diverge from the int64 numpy path — must raise instead
        t = np.full(100_000, 5.0)
        with pytest.raises(ops.KeepRuleOverflow):
            ops.stream_sample(t, 600, 3.0)
        with pytest.raises(ops.KeepRuleOverflow):
            ops.stream_sample_batched([t], 600, 3.0)

    def test_max_range_beyond_snap_limit_refused(self):
        # beyond the +-1 snap guarantee the wrapper must refuse (not assert)
        from repro.kernels.stream_sample import MAX_RANGE_LIMIT
        t = np.arange(100, dtype=np.float64)
        with pytest.raises(ops.PallasDomainError):
            ops.stream_sample(t, MAX_RANGE_LIMIT + 1, 2.0)
        # ...and nsa() falls back to numpy instead of surfacing the error
        from repro.streamsim.nsa import nsa as nsa_fn
        from repro.streamsim.preprocess import Stream
        s = Stream("x", t, {"v": np.arange(100)})
        a = nsa_fn(s, MAX_RANGE_LIMIT + 1, backend="pallas")
        b = nsa_fn(s, MAX_RANGE_LIMIT + 1, backend="numpy")
        np.testing.assert_array_equal(a.t, b.t)

    @pytest.mark.parametrize("n", [1, 7, 500])
    def test_zero_span_stream(self, n):
        # all-equal timestamps: host path puts everything in bucket 0; the
        # degenerate table branch must agree (regression: records used to
        # land in bucket 1 via the snap)
        from repro.streamsim.nsa import scale_stamps, systematic_keep_mask
        t = np.full(n, 1234.5)
        ss, keep = ops.stream_sample(t, 600, 144.0)
        np.testing.assert_array_equal(np.asarray(ss), scale_stamps(t, 600))
        np.testing.assert_array_equal(
            np.asarray(keep), systematic_keep_mask(np.zeros(n, np.int64),
                                                   600, 144.0))


class TestStreamSampleBatched:
    @pytest.mark.parametrize("lengths", [
        (256, 256, 256),          # uniform
        (100, 5000, 1237),        # ragged + unaligned tails
        (TILE, 1, 3 * TILE + 7),  # single-record stream + exact tile
    ])
    def test_batched_equals_looped(self, lengths):
        # one 2-D-grid dispatch == S sequential single-stream dispatches
        mr = 60
        ts = [_sorted_times(n, 86_400.0, seed=90 + i) if n > 1
              else np.array([float(i)]) for i, n in enumerate(lengths)]
        mults = [86_400.0 / mr * (1 + 0.5 * i) for i in range(len(ts))]
        ss_b, keep_b, lens = ops.stream_sample_batched(ts, mr, mults)
        for s, t in enumerate(ts):
            ss_1, keep_1 = ops.stream_sample(t, mr, mults[s])
            n = lens[s]
            np.testing.assert_array_equal(np.asarray(ss_b[s, :n]),
                                          np.asarray(ss_1))
            np.testing.assert_array_equal(np.asarray(keep_b[s, :n]),
                                          np.asarray(keep_1))
            assert not np.asarray(keep_b[s, n:]).any(), "padded tail kept"

    def test_scalar_multiple_broadcasts(self):
        ts = [_sorted_times(500, 3600.0, seed=5) for _ in range(2)]
        ss_b, keep_b, _ = ops.stream_sample_batched(ts, 30, 120.0)
        assert ss_b.shape == keep_b.shape == (2, TILE)

    def test_empty_stream_rejected(self):
        with pytest.raises(ValueError):
            ops.stream_sample_batched([np.zeros(0)], 10, 1.0)


class TestCompact:
    @pytest.mark.parametrize("n", [1, 100, TILE, 4 * TILE, 10_000])
    @pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
    def test_matches_oracle_and_nonzero(self, n, density):
        rng = np.random.default_rng(n + int(density * 7))
        mask = (rng.random(n) < density)
        if density == 1.0:
            mask[:] = True          # all kept
        idx, total = ops.compact_mask(mask)
        exp = np.flatnonzero(mask)
        assert total == len(exp)
        np.testing.assert_array_equal(np.asarray(idx[:total]), exp)
        assert np.all(np.asarray(idx[total:]) == n), "sentinel tail"
        # positions agree with the pure-jnp oracle
        from repro.kernels.compact import compact_positions_pallas
        pad = (-n) % TILE
        mp = jnp.asarray(np.concatenate([mask, np.zeros(pad, bool)]),
                         jnp.int32)
        pos_k, tot_k = compact_positions_pallas(mp, interpret=True)
        pos_o, tot_o = ref.compact_ref(mp)
        np.testing.assert_array_equal(np.asarray(pos_k), np.asarray(pos_o))
        assert int(tot_k[0]) == int(tot_o[0]) == total

    def test_bool_and_int_masks(self):
        m = np.array([1, 0, 1, 1, 0], np.int64)
        idx_i, tot_i = ops.compact_mask(m)
        idx_b, tot_b = ops.compact_mask(m.astype(bool))
        assert tot_i == tot_b == 3
        np.testing.assert_array_equal(np.asarray(idx_i), np.asarray(idx_b))

    def test_empty(self):
        idx, total = ops.compact_mask(np.zeros(0, bool))
        assert total == 0 and idx.shape == (0,)


class TestCompactBatched:
    """R rows' mask compactions in ONE 2-D-grid dispatch (per-row SMEM
    carry reset) must be bit-identical to R sequential compactions."""

    @pytest.mark.parametrize("shape,densities", [
        ((1, 100), (0.3,)),
        ((3, TILE), (0.0, 0.5, 1.0)),          # empty / mixed / all-kept rows
        ((4, 10_000), (0.1, 0.9, 0.0, 0.5)),   # unaligned record tail
    ])
    def test_batched_equals_looped(self, shape, densities):
        R, n = shape
        rng = np.random.default_rng(R * n)
        mask = np.stack([rng.random(n) < d for d in densities])
        idx_b, totals = ops.compact_mask_batched(mask)
        assert idx_b.shape == (R, n) and totals.shape == (R,)
        for r in range(R):
            idx_1, total_1 = ops.compact_mask(mask[r])
            assert totals[r] == total_1
            np.testing.assert_array_equal(np.asarray(idx_b[r]),
                                          np.asarray(idx_1))
            exp = np.flatnonzero(mask[r])
            np.testing.assert_array_equal(np.asarray(idx_b[r, :totals[r]]),
                                          exp)
            assert np.all(np.asarray(idx_b[r, totals[r]:]) == n), \
                "sentinel tail"

    def test_carry_resets_between_rows(self):
        # identical all-kept rows: a leaking carry would shift row 1's
        # positions by row 0's total
        mask = np.ones((2, 2 * TILE), bool)
        idx_b, totals = ops.compact_mask_batched(mask)
        np.testing.assert_array_equal(totals, [2 * TILE, 2 * TILE])
        np.testing.assert_array_equal(np.asarray(idx_b[0]),
                                      np.asarray(idx_b[1]))

    def test_empty_and_bad_shapes(self):
        idx, totals = ops.compact_mask_batched(np.zeros((2, 0), bool))
        assert idx.shape == (2, 0) and list(totals) == [0, 0]
        with pytest.raises(ValueError):
            ops.compact_mask_batched(np.zeros(5, bool))


class TestStreamMetrics:
    """The fused metrics engine: histogram + moments in one record pass."""

    @pytest.mark.parametrize("n,max_range", [(1, 16), (512, 16), (4096, 128),
                                             (20_000, 600), (1024, 3600),
                                             (4096, 86_400)])
    def test_matches_oracle(self, n, max_range):
        rng = np.random.default_rng(n + max_range)
        ss = np.sort(rng.integers(0, max_range, n)).astype(np.int32)
        h_k, m_k = ops.stream_metrics(ss, max_range)
        h_o = ref.bucket_hist_ref(jnp.asarray(ss), max_range)
        np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_o))
        assert h_k.dtype == jnp.int32, "int32 counts — no f32 rounding"
        assert int(h_k.sum()) == n
        q = np.asarray(h_o, np.float64)
        np.testing.assert_allclose(np.asarray(m_k),
                                   [q.sum(), (q * q).sum()], rtol=1e-5)

    def test_unsorted_input_still_exact(self):
        # sortedness only narrows the kernel's data-adaptive bucket-block
        # loop; correctness must not depend on it
        rng = np.random.default_rng(7)
        ss = rng.integers(0, 600, 5000).astype(np.int32)
        h_k, _ = ops.stream_metrics(ss, 600)
        np.testing.assert_array_equal(np.asarray(h_k),
                                      np.bincount(ss, minlength=600))

    @pytest.mark.parametrize("lengths", [(256, 256), (0, 1, 3000, 1024),
                                         (1, 8192)])
    def test_batched_equals_looped(self, lengths):
        rng = np.random.default_rng(sum(lengths))
        mr = 300
        sss = [np.sort(rng.integers(0, mr, n)).astype(np.int32)
               for n in lengths]
        h_b, m_b, lens = ops.stream_metrics_batched(sss, mr)
        np.testing.assert_array_equal(lens, lengths)
        for s, ss in enumerate(sss):
            np.testing.assert_array_equal(
                np.asarray(h_b[s]), np.bincount(ss, minlength=mr))
            if len(ss) == 0:
                assert float(m_b[s, 0]) == float(m_b[s, 1]) == 0.0
            else:
                h_1, m_1 = ops.stream_metrics(ss, mr)
                np.testing.assert_array_equal(np.asarray(h_b[s]),
                                              np.asarray(h_1))
                np.testing.assert_allclose(np.asarray(m_b[s]),
                                           np.asarray(m_1), rtol=1e-6)

    def test_out_of_range_stamps_rejected(self):
        with pytest.raises(ValueError):
            ops.stream_metrics(np.array([0, 600]), 600)
        with pytest.raises(ValueError):
            ops.stream_metrics(np.array([-1, 5]), 600)

    def test_moments_tight_on_day_scale(self):
        # pairwise-block + Kahan summation in the kernel: the [Σq, Σq²]
        # pair must agree with exact f64 within 1e-5 relative on the
        # day-scale fixture (86 400 buckets) — an order tighter than the
        # 1e-3 the naive running f32 sum guaranteed
        rng = np.random.default_rng(42)
        ss = np.sort(rng.integers(0, 86_400, 1_000_000)).astype(np.int32)
        hist, mom = ops.stream_metrics(ss, 86_400)
        q = np.asarray(hist, np.float64)
        np.testing.assert_allclose(np.asarray(mom, np.float64),
                                   [q.sum(), (q * q).sum()], rtol=1e-5)

    def test_int32_overflow_domain_guarded(self):
        # counts accumulate in int32: exact up to 2**31 per bucket (the
        # seed's f32 one-hot kernel silently rounded past 2**24); beyond
        # the int32 domain the wrapper must raise, not wrap
        ops._check_metrics_domain(2 ** 31 - 1)  # in-domain: no raise
        with pytest.raises(ops.PallasDomainError):
            ops._check_metrics_domain(2 ** 31)

    def test_empty_batch_rejected(self):
        with pytest.raises(ValueError):
            ops.stream_metrics_batched([], 10)


class TestBucketHist:
    @pytest.mark.parametrize("n,max_range", [(512, 16), (4096, 128),
                                             (20_000, 600), (1024, 3600)])
    def test_matches_oracle(self, n, max_range):
        rng = np.random.default_rng(n)
        ss = np.sort(rng.integers(0, max_range, n)).astype(np.int32)
        h_k = ops.bucket_hist(ss, max_range)
        h_o = ref.bucket_hist_ref(jnp.asarray(ss), max_range)
        np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_o))
        assert int(h_k.sum()) == n


class TestVolatility:
    @pytest.mark.parametrize("n", [60, 600, 3600, 86_400])
    def test_moments(self, n):
        rng = np.random.default_rng(n)
        q = rng.poisson(25.0, n).astype(np.float32)
        avg, var, std = ops.volatility_stats(q)
        assert np.isclose(float(avg), q.mean(), rtol=1e-5)
        assert np.isclose(float(var), q.var(), rtol=1e-4)
        assert np.isclose(float(std), q.std(), rtol=1e-4)

    def test_against_ref(self):
        q = np.arange(1024, dtype=np.float32)
        s, s2 = ops.volatility_moments(q)
        exp = ref.volatility_ref(jnp.asarray(q))
        assert np.isclose(float(s), float(exp[0]))
        assert np.isclose(float(s2), float(exp[1]), rtol=1e-6)


class TestFlashDecode:
    @pytest.mark.parametrize("b,h,kh,d,s", [
        (1, 4, 4, 32, 256),     # MHA
        (2, 8, 2, 64, 512),     # GQA 4:1
        (4, 16, 1, 64, 1024),   # MQA
        (2, 12, 4, 128, 384),   # uneven block tail
    ])
    def test_matches_oracle(self, b, h, kh, d, s):
        key = jax.random.PRNGKey(b * 100 + s)
        q = jax.random.normal(key, (b, h, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, d))
        lens = jax.random.randint(jax.random.fold_in(key, 3), (b,), 1, s + 1)
        out = ops.flash_decode(q, k, v, lens, block_s=128)
        exp = ref.flash_decode_ref(q, k, v, lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        key = jax.random.PRNGKey(0)
        b, h, kh, d, s = 2, 8, 4, 64, 256
        q = jax.random.normal(key, (b, h, d), jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, d),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, d),
                              jnp.bfloat16)
        lens = jnp.full((b,), s, jnp.int32)
        out = ops.flash_decode(q, k, v, lens, block_s=128)
        exp = ref.flash_decode_ref(q, k, v, lens)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            rtol=5e-2, atol=5e-2)

    def test_prefix_only_attention(self):
        """Tokens beyond `lengths` must not influence the output."""
        key = jax.random.PRNGKey(7)
        b, h, kh, d, s = 2, 4, 2, 32, 256
        q = jax.random.normal(key, (b, h, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, d))
        lens = jnp.array([100, 40], jnp.int32)
        out1 = ops.flash_decode(q, k, v, lens, block_s=64)
        k2 = k.at[:, 150:].set(999.0)
        v2 = v.at[:, 150:].set(-999.0)
        out2 = ops.flash_decode(q, k2, v2, lens, block_s=64)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)
