"""Serving engine + stream-driven load tests."""

import threading

import jax
import numpy as np
import pytest

from repro.configs.paper_stream import consumer_lm
from repro.models import transformer as T
from repro.serving.engine import Request, ServingEngine
from repro.serving.load import stream_arrivals
from repro.streamsim import (
    Producer,
    StreamQueue,
    VirtualClock,
    make_stream,
    nsa,
    preprocess,
)


def tiny_cfg():
    return consumer_lm().replace(n_layers=2, d_model=64, n_heads=4,
                                 n_kv_heads=2, head_dim=16, d_ff=128,
                                 vocab_size=512, loss_chunk=16)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = tiny_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestEngine:
    def test_single_request_completes(self, engine_setup):
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, slots=2, max_len=48, eos_id=-1)
        rng = np.random.default_rng(0)
        eng.submit(Request(rid=0, prompt=rng.integers(1, 512, 6,
                                                      dtype=np.int32),
                           max_new_tokens=5))
        eng.drain()
        assert eng.metrics.finished == 1
        assert eng.metrics.tokens_out >= 5

    def test_batched_requests_all_finish(self, engine_setup):
        cfg, params = engine_setup
        eng = ServingEngine(cfg, params, slots=4, max_len=48, eos_id=-1)
        rng = np.random.default_rng(1)
        for i in range(10):
            eng.submit(Request(rid=i,
                               prompt=rng.integers(1, 512, 4 + i % 5,
                                                   dtype=np.int32),
                               max_new_tokens=4))
        eng.drain()
        assert eng.metrics.finished == 10
        s = eng.metrics.summary()
        assert s["queue_peak"] >= 6  # more requests than slots => queueing

    def test_greedy_matches_unbatched_reference(self, engine_setup):
        """Continuous batching must not change a sequence's outputs."""
        cfg, params = engine_setup
        rng = np.random.default_rng(2)
        prompt = rng.integers(1, 512, 8, dtype=np.int32)
        # reference: dedicated engine with one slot
        ref_eng = ServingEngine(cfg, params, slots=1, max_len=48, eos_id=-1)
        ref_eng.submit(Request(rid=0, prompt=prompt.copy(),
                               max_new_tokens=6))
        ref_eng.drain()
        ref_tokens = ref_eng.metrics  # via request record below
        # batched: same request + noise requests
        eng = ServingEngine(cfg, params, slots=4, max_len=48, eos_id=-1)
        target = Request(rid=0, prompt=prompt.copy(), max_new_tokens=6)
        eng.submit(target)
        for i in range(3):
            eng.submit(Request(rid=i + 1,
                               prompt=rng.integers(1, 512, 5,
                                                   dtype=np.int32),
                               max_new_tokens=6))
        eng.drain()
        # re-run reference to capture generated ids
        ref = Request(rid=9, prompt=prompt.copy(), max_new_tokens=6)
        ref_eng2 = ServingEngine(cfg, params, slots=1, max_len=48, eos_id=-1)
        ref_eng2.submit(ref)
        ref_eng2.drain()
        assert target.generated == ref.generated

    def test_stream_driven_load(self, engine_setup):
        cfg, params = engine_setup
        sim = nsa(preprocess(make_stream("sogouq", scale=0.005, seed=4)), 30)
        q = StreamQueue(maxsize=64)
        threading.Thread(target=Producer(sim, q, clock=VirtualClock()).run,
                         daemon=True).start()
        eng = ServingEngine(cfg, params, slots=4, max_len=48, eos_id=-1)
        n = 0
        for ss, reqs in stream_arrivals(q, cfg.vocab_size, prompt_len=4,
                                        max_new_tokens=3,
                                        max_requests_per_bucket=2):
            for r in reqs:
                eng.submit(r)
                n += 1
            eng.tick()
        eng.drain()
        assert n > 5
        assert eng.metrics.finished == n
        assert eng.metrics.summary()["p50_latency_s"] >= 0.0
