"""Tile-tuning registry tests: default bit-identity, the measured-sweep
cache (hit skips the sweep, keyed per device kind, corrupt file falls
back), concurrent-writer atomicity, and non-default-config equivalence.

Everything runs in Pallas interpret mode on CPU; the measured sweeps here
tune the interpreter (a valid, self-consistent target — see
``tuning.device_kind``), so the tests assert cache *mechanics*, never
which candidate wins.
"""

import json
import threading

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ops, ref, tuning  # noqa: E402
from repro.kernels.tuning import (  # noqa: E402
    DEFAULT_CONFIG, TuneKey, KernelTuner, TileConfig)
from repro.streamsim.store import StreamStore  # noqa: E402


@pytest.fixture()
def store(tmp_path):
    return StreamStore(tmp_path / "store")


def _cache_file(store, kind):
    return store.root / "_markers" / tuning.TUNE_NAMESPACE / f"{kind}.json"


# ------------------------------------------------------------ default path
def test_default_config_is_the_shipped_constants():
    assert DEFAULT_CONFIG.record_tile == ops.TILE == 1024
    assert DEFAULT_CONFIG.bucket_block == ops.BUCKET_BLOCK == 512
    assert DEFAULT_CONFIG.grid_split == 1
    assert DEFAULT_CONFIG.sublane == 8


@pytest.mark.parametrize("kind", ["cpu-interpret", "tpu-v4", "tpu-v5e"])
def test_heuristic_reproduces_constants_off_gpu(kind):
    # autotune="off" on TPU / interpret must be bit-for-bit the pre-tuner
    # kernels, i.e. the chooser returns exactly the shipped constants
    for kernel in tuning.KERNELS:
        key = TuneKey.from_shape(kernel, s=8, n=90000, r=86400)
        assert tuning.heuristic_config(key, kind) == DEFAULT_CONFIG


def test_tune_key_pow2_snaps_and_round_trips():
    key = TuneKey.from_shape("metrics_fused", s=5, n=90000, r=86400)
    assert (key.s, key.n, key.r) == (8, 1 << 17, 1 << 17)
    assert TuneKey.decode(key.encode()) == key


def test_off_mode_does_no_io(store):
    tuner = KernelTuner("off", store=store, kind="cpu-interpret")
    cfg = tuner.config_for("metrics_fused", s=4, n=4096, r=1024)
    assert cfg == DEFAULT_CONFIG
    assert not _cache_file(store, "cpu-interpret").exists()


# ----------------------------------------------- non-default config outputs
def test_non_default_config_outputs_match_default():
    rng = np.random.default_rng(11)
    ss = np.sort(rng.integers(0, 3000, (3, 4096)), axis=1).astype(np.int32)
    wide = TileConfig(record_tile=2048, bucket_block=256)
    from repro.kernels.metrics_fused import stream_metrics_pallas
    buckets = 3072   # multiple of both 512 and 256
    h0, m0 = stream_metrics_pallas(jnp.asarray(ss), buckets, interpret=True)
    h1, m1 = stream_metrics_pallas(jnp.asarray(ss), buckets, interpret=True,
                                   config=wide)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
    np.testing.assert_allclose(np.asarray(m0), np.asarray(m1),
                               rtol=1e-5, atol=1e-5)

    from repro.kernels.compact import compact_positions_batched_pallas
    mask = (rng.random((3, 4096)) < 0.4).astype(np.int32)
    p0, t0 = compact_positions_batched_pallas(jnp.asarray(mask),
                                              interpret=True)
    p1, t1 = compact_positions_batched_pallas(jnp.asarray(mask),
                                              interpret=True, config=wide)
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))

    from repro.kernels.trend_scan import trend_scan_pallas
    q = rng.integers(0, 5, (3, 4096)).astype(np.int32)
    s0 = trend_scan_pallas(jnp.asarray(q), interpret=True)
    s1 = trend_scan_pallas(jnp.asarray(q), interpret=True, config=wide)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_grid_split_matches_single_launch():
    # the batch-axis relief valve must be a pure partition of the rows
    rng = np.random.default_rng(3)
    streams = [np.sort(rng.uniform(0, 600.0, 700)) for _ in range(5)]

    class _Tuner(KernelTuner):
        def config_for(self, kernel, **kw):
            return TileConfig(grid_split=3)

    ranges = [100, 200, 300, 400, 500]
    ss0, keep0, len0 = ops.stream_sample_batched(streams, ranges, 1.0)
    with tuning.use(_Tuner("off")):
        ss1, keep1, len1 = ops.stream_sample_batched(streams, ranges, 1.0)
    np.testing.assert_array_equal(np.asarray(ss0), np.asarray(ss1))
    np.testing.assert_array_equal(np.asarray(keep0), np.asarray(keep1))
    np.testing.assert_array_equal(np.asarray(len0), np.asarray(len1))


# --------------------------------------------------------------- sweep/cache
def _counting_timer(tuner):
    calls = [0]
    real = tuner._timer

    def timer():
        calls[0] += 1
        return real()

    tuner._timer = timer
    return calls


def test_force_sweep_persists_and_cached_hit_skips_sweep(store):
    kind = "cpu-interpret"
    t1 = KernelTuner("force", store=store, kind=kind, reps=1)
    c1 = _counting_timer(t1)
    cfg = t1.config_for("trend_scan", s=2, n=2048)
    assert c1[0] > 0, "force mode must actually time candidates"
    assert isinstance(cfg, TileConfig)
    blob = json.loads(_cache_file(store, kind).read_text())
    assert blob["version"] == 1 and blob["device_kind"] == kind
    keystr = TuneKey.from_shape("trend_scan", s=2, n=2048).encode()
    assert blob["entries"][keystr] == cfg.as_dict()

    # a fresh tuner (fresh process, conceptually) hits the disk cache and
    # never calls the timer
    t2 = KernelTuner("cached", store=store, kind=kind, reps=1)
    c2 = _counting_timer(t2)
    assert t2.config_for("trend_scan", s=2, n=2048) == cfg
    assert c2[0] == 0, "cache hit must skip the measured sweep"


def test_cache_is_keyed_per_device_kind(store):
    ka, kb = "tpu-v4", "gpu-a100"
    ta = KernelTuner("force", store=store, kind=ka, reps=1)
    ta._sweep = lambda key: TileConfig(record_tile=2048)
    ta.config_for("compact", s=4, n=4096)
    assert _cache_file(store, ka).exists()
    assert not _cache_file(store, kb).exists()

    # the other kind sees nothing cached: its sweep runs
    tb = KernelTuner("cached", store=store, kind=kb, reps=1)
    swept = []
    tb._sweep = lambda key: swept.append(key) or TileConfig()
    tb.config_for("compact", s=4, n=4096)
    assert len(swept) == 1


def test_corrupt_cache_falls_back_to_heuristic(store):
    kind = "cpu-interpret"
    f = _cache_file(store, kind)
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text('{"version": 1, "entries": {"trunca')   # torn write
    tuner = KernelTuner("cached", store=store, kind=kind, reps=1)
    assert tuner._load_cache() == {}
    tuner._sweep = lambda key: tuning.heuristic_config(key, kind)
    cfg = tuner.config_for("metrics_fused", s=2, n=2048, r=512)
    assert cfg == DEFAULT_CONFIG   # no raise, heuristic fallback

    # entries with a bogus payload are skipped entry-wise, not wholesale
    f.write_text(json.dumps({
        "version": 1, "device_kind": kind,
        "entries": {"trend_scan/s2/n2048/r0/int32":
                    {"record_tile": 2048, "bucket_block": 512,
                     "grid_split": 1},
                    "not-a-key": {"record_tile": "wat"}}}))
    cache = tuner._load_cache()
    assert cache == {TuneKey.from_shape("trend_scan", s=2, n=2048):
                     TileConfig(record_tile=2048)}


def test_concurrent_force_writers_leave_valid_json(store):
    kind = "cpu-interpret"
    keys = [("trend_scan", 2, 2048), ("compact", 4, 4096)]
    cfgs = {0: TileConfig(record_tile=2048), 1: TileConfig(bucket_block=256)}
    errs = []

    def write(i):
        try:
            t = KernelTuner("force", store=store, kind=kind, reps=1)
            t._sweep = lambda key: cfgs[i]
            kernel, s, n = keys[i]
            for _ in range(20):      # hammer the read-merge-write path
                t._mem.clear()
                t.config_for(kernel, s=s, n=n)
        except Exception as e:       # pragma: no cover - failure detail
            errs.append(e)

    threads = [threading.Thread(target=write, args=(i,)) for i in (0, 1)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errs
    blob = json.loads(_cache_file(store, kind).read_text())   # valid JSON
    entries = blob["entries"]
    for i, (kernel, s, n) in enumerate(keys):
        assert entries[TuneKey.from_shape(kernel, s=s, n=n).encode()] == \
            cfgs[i].as_dict()


def test_sweep_failure_degrades_to_heuristic(store):
    tuner = KernelTuner("force", store=store, kind="cpu-interpret", reps=1)

    def boom():
        raise RuntimeError("device fell over")

    tuner._timer = boom
    cfg = tuner.config_for("trend_scan", s=2, n=2048)
    assert cfg == tuning.heuristic_config(
        TuneKey.from_shape("trend_scan", s=2, n=2048), "cpu-interpret")


# ------------------------------------------------------------- ambient knob
def test_tuner_context_off_installs_nothing(store):
    with tuning.tuner_context(None, store=store):
        assert tuning.current() is tuning._DEFAULT_TUNER
    with tuning.tuner_context("off", store=store):
        assert tuning.current() is tuning._DEFAULT_TUNER
    with pytest.raises(ValueError):
        with tuning.tuner_context("fastest", store=store):
            pass  # pragma: no cover


def test_shared_tuner_registry_reuses_instances(store):
    a = tuning.shared_tuner("cached", store=store, kind="tpu-v4")
    b = tuning.shared_tuner("cached", store=store, kind="tpu-v4")
    c = tuning.shared_tuner("cached", store=store, kind="tpu-v5e")
    assert a is b and a is not c


def test_nsa_autotune_off_is_bit_identical():
    from repro.streamsim import make_stream, nsa, preprocess
    st = preprocess(make_stream("traffic", scale=0.01, seed=2))
    base = nsa(st, 600, backend="pallas")
    tuned_off = nsa(st, 600, backend="pallas", autotune="off")
    np.testing.assert_array_equal(base.t, tuned_off.t)


def test_controller_run_accepts_autotune(tmp_path):
    from repro.streamsim.controller import Controller

    def consumer(q):
        n = 0
        while True:
            item = q.get()
            if item is None:
                break
            n += 1
        return {"consumed": n}

    ctl = Controller(store_dir=tmp_path / "s1")
    r0 = ctl.run("traffic", 600, consumer, scale=0.01, seed=3)
    ctl2 = Controller(store_dir=tmp_path / "s2")
    r1 = ctl2.run("traffic", 600, consumer, scale=0.01, seed=3,
                  autotune="cached")
    assert r0.simulated_rows == r1.simulated_rows
    assert r0.consumer_metrics == r1.consumer_metrics
