"""RWKV-6 "Finch" time-mixing + channel-mixing (arXiv:2404.05892).

Attention-free: per head-of-64 the time-mix keeps a (D, D) state matrix
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    o_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)
with *data-dependent* decay w_t (the Finch novelty) produced by a LoRA on
the token-shifted input. Training runs the recurrence with ``lax.scan``
over time chunks (state is O(1) in sequence length — why rwkv6 runs the
long_500k cell); decode is a single state update.

This is the TPU adaptation of the CUDA wkv kernel: the recurrence is kept
in f32, the per-chunk inner contraction is an MXU-batched matmul, and the
chunk size trades scan length against VMEM-resident state reuse.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, matmul, rmsnorm

_LORA = 64


def rwkv_init(cfg: ModelConfig, key) -> Dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 12)
    nh = d // cfg.rwkv_head_dim
    return {
        # token-shift lerp coefficients for r,k,v,w,g
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dt),
        "wr": dense_init(ks[1], d, d, dt),
        "wk": dense_init(ks[2], d, d, dt),
        "wv": dense_init(ks[3], d, d, dt),
        "wg": dense_init(ks[4], d, d, dt),
        "wo": dense_init(ks[5], d, d, dt),
        # data-dependent decay LoRA: d -> 64 -> d
        "w_lora_a": dense_init(ks[6], d, _LORA, dt),
        "w_lora_b": dense_init(ks[7], _LORA, d, dt),
        "w_base": jnp.full((d,), -6.0, jnp.float32),
        "u": (jax.random.normal(ks[8], (nh, cfg.rwkv_head_dim), jnp.float32)
              * 0.1),
        "ln_x": jnp.zeros((d,), jnp.float32),  # per-head group-norm weight
        # channel mix
        "cm_mu": (jax.random.uniform(ks[9], (2, d), jnp.float32)).astype(dt),
        "cm_r": dense_init(ks[10], d, d, dt),
        "cm_k": dense_init(ks[11], d, cfg.d_ff, dt),
        "cm_v": dense_init(jax.random.fold_in(key, 99), cfg.d_ff, d, dt),
    }


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray) -> jnp.ndarray:
    """x_{t-1} sequence: prev token feeds position 0. x: (B,S,d), prev (B,d)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0, unroll: int = 1):
    """The wkv recurrence over time. r,k,v,w: (B,S,H,D) f32; u: (H,D);
    s0: (B,H,D,D). Returns (o (B,S,H,D), s_last).

    ``unroll`` > 1 unrolls the scan body: the (B,H,D,D) state stays in
    registers/VMEM across ``unroll`` consecutive tokens instead of
    round-tripping HBM every step — the recurrence itself is unchanged
    (bit-identical outputs), only state traffic drops ~unroll-fold. This is
    the TPU analogue of the fused CUDA wkv kernel's state residency."""

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                     # (B,H,D)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)   # (B,H,D,D)
        o = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_last, o = jax.lax.scan(step, s0, xs, unroll=unroll)
    return jnp.moveaxis(o, 0, 1), s_last


def time_mix(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
             prev_tok: jnp.ndarray, s0: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (y, last_token, s_last)."""
    b, s, d = x.shape
    nh, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xs = _token_shift(x, prev_tok)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i] * (xs - x) for i in range(5))
    r = matmul(xr, p["wr"]).reshape(b, s, nh, hd).astype(jnp.float32)
    k = matmul(xk, p["wk"]).reshape(b, s, nh, hd).astype(jnp.float32)
    v = matmul(xv, p["wv"]).reshape(b, s, nh, hd).astype(jnp.float32)
    g = jax.nn.silu(matmul(xg, p["wg"]).astype(jnp.float32))
    # data-dependent decay (Finch): w = exp(-exp(base + lora(xw)))
    dw = matmul(jnp.tanh(matmul(xw, p["w_lora_a"]).astype(jnp.float32)
                         ).astype(x.dtype), p["w_lora_b"])
    w = jnp.exp(-jnp.exp(p["w_base"] + dw.astype(jnp.float32)))
    w = w.reshape(b, s, nh, hd)
    o, s_last = _wkv_scan(r, k, v, w, p["u"], s0,
                          unroll=max(cfg.wkv_unroll, 1))
    o = o.reshape(b, s, d)
    # per-head group norm
    o = o.reshape(b, s, nh, hd)
    o = (o - o.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        o.var(-1, keepdims=True) + 64e-5)
    o = o.reshape(b, s, d) * (1.0 + p["ln_x"])
    y = matmul((o * g).astype(x.dtype), p["wo"])
    return y, x[:, -1], s_last


def channel_mix(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                prev_tok: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xs = _token_shift(x, prev_tok)
    mu = p["cm_mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(matmul(xk, p["cm_k"]).astype(jnp.float32)))
    kv = matmul(k.astype(x.dtype), p["cm_v"])
    return jax.nn.sigmoid(matmul(xr, p["cm_r"]).astype(jnp.float32)
                          ).astype(x.dtype) * kv, x[:, -1]


def rwkv_block(cfg: ModelConfig, p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence RWKV-6 time-mix (zero initial state). The channel-mix
    replaces the MLP slot (transformer.py wires it as the block's 'mlp')."""
    b, d = x.shape[0], x.shape[2]
    nh, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    s0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    prev = jnp.zeros((b, d), x.dtype)
    y, _, _ = time_mix(cfg, p, x, prev, s0)
    return y


def rwkv_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray, state: Dict
                ) -> Tuple[jnp.ndarray, Dict]:
    """One-step decode. state: {'s': (B,H,D,D) f32, 'tm_prev': (B,d),
    'cm_prev': (B,d)} — O(1) in context length."""
    y, tm_prev, s_last = time_mix(cfg, p, x, state["tm_prev"], state["s"])
    return y, {"s": s_last, "tm_prev": tm_prev, "cm_prev": state["cm_prev"]}


def rwkv_state_init(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d = cfg.d_model
    nh, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {
        "s": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "tm_prev": jnp.zeros((batch, d), dtype),
        "cm_prev": jnp.zeros((batch, d), dtype),
    }
