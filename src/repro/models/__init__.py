"""Model zoo: the 10 assigned architectures as composable pure-JAX modules.

Everything is functional: ``init_params(cfg, key) -> pytree`` and
``apply``-style functions taking the pytree explicitly. No flax/optax —
params are plain nested dicts, distribution is applied from the outside via
PartitionSpec trees (:mod:`repro.distributed.sharding`).
"""

from repro.models.config import ModelConfig  # noqa: F401
from repro.models import transformer  # noqa: F401
