"""Shared neural building blocks (pure JAX, no flax).

Conventions:
- params are nested dicts of jnp arrays;
- compute dtype is cfg.dtype (bf16 target), norms/softmax/accumulation f32;
- every matmul passes ``preferred_element_type=float32`` so the MXU
  accumulates in f32 regardless of operand dtype.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x @ w with f32 accumulation, result cast back to x.dtype."""
    out = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def einsum(spec: str, *xs: jnp.ndarray) -> jnp.ndarray:
    out = jnp.einsum(spec, *xs, preferred_element_type=jnp.float32)
    return out.astype(xs[0].dtype)


# ------------------------------------------------------------------- init
def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------- RMSNorm
def rmsnorm_init(d: int) -> jnp.ndarray:
    return jnp.zeros((d,), jnp.float32)  # gemma-style (1 + w) parameterization


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            f32: bool = True) -> jnp.ndarray:
    """RMSNorm. ``f32=True`` upcasts activations (paper-faithful numerics);
    ``f32=False`` squares in bf16 with f32 mean accumulation — avoids the
    f32 residual-stack materialization XLA hoists into the layer scan (see
    EXPERIMENTS.md §Perf llama3 iteration 1)."""
    if f32:
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
        return y.astype(x.dtype)
    var = jnp.mean(x * x, axis=-1, keepdims=True, dtype=jnp.float32)
    scale = jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return (x * scale.astype(x.dtype)).astype(x.dtype)


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) rotary over last dim; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- SwiGLU
def swiglu_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d, d_ff, dtype),
        "up": dense_init(k2, d, d_ff, dtype),
        "down": dense_init(k3, d_ff, d, dtype),
    }


def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = matmul(x, p["gate"])
    u = matmul(x, p["up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return matmul(h, p["down"])


# -------------------------------------------------------------- embedding
def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 scale: bool = True) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0)
    if scale:
        out = out * jnp.asarray(math.sqrt(table.shape[1]), out.dtype)
    return out


def unembed(x: jnp.ndarray, table: jnp.ndarray,
            softcap: float = 0.0) -> jnp.ndarray:
    """Logits head. table: (V, d) (tied) -> x @ table.T in f32."""
    logits = jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
