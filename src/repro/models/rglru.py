"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The temporal-mixing block is:  x -> [branch A: linear -> GeLU] ⊙
[branch B: linear -> causal conv1d(width 4) -> RG-LRU] -> linear out.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t)          recurrence gate
    i_t = sigmoid(W_x x_t)          input gate
    a_t = exp(c * softplus(Λ) * (-r_t))          ∈ (0,1), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t²) * (i_t ⊙ x_t)

It is a *linear* recurrence in h, so training uses
``jax.lax.associative_scan`` (log-depth on TPU) — the hardware-adapted
replacement for the paper-series' custom GPU scan kernel. Decode is a single
O(1) state update, which is why recurrentgemma runs the long_500k cell.

Gates use block-diagonal projections (8 blocks) as in the Griffin reference.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, matmul

_C = 8.0
_BLOCKS = 8


def rglru_init(cfg: ModelConfig, key) -> Dict:
    d, w = cfg.d_model, cfg.lru_width_
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    bw = w // _BLOCKS
    # Λ init so a^c ~ U[0.9, 0.999] per Griffin appendix
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.exp(-jnp.log(u) / _C) - 1.0)  # softplus^-1(-ln u / c)
    return {
        "in_gelu": dense_init(ks[1], d, w, dt),
        "in_rnn": dense_init(ks[2], d, w, dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "gate_a": dense_init(ks[4], bw, _BLOCKS * bw, jnp.float32
                             ).reshape(bw, _BLOCKS, bw).swapaxes(0, 1),
        "gate_x": dense_init(ks[5], bw, _BLOCKS * bw, jnp.float32
                             ).reshape(bw, _BLOCKS, bw).swapaxes(0, 1),
        "lambda": lam,
        "out": dense_init(ks[6], w, d, dt),
    }


def _gates(p: Dict, x: jnp.ndarray):
    """Block-diagonal gate projections. x: (..., W) f32."""
    shp = x.shape[:-1]
    w = x.shape[-1]
    xb = x.reshape(shp + (_BLOCKS, w // _BLOCKS))
    r = jax.nn.sigmoid(jnp.einsum("...bi,bij->...bj", xb, p["gate_a"])
                       ).reshape(shp + (w,))
    i = jax.nn.sigmoid(jnp.einsum("...bi,bij->...bj", xb, p["gate_x"])
                       ).reshape(shp + (w,))
    return r, i


def _conv1d(p: Dict, x: jnp.ndarray, state: jnp.ndarray = None):
    """Causal depthwise conv, width K. x: (B,S,W). state: (B,K-1,W) or None."""
    k = p["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return out + p["conv_b"], new_state


def _rglru_coeffs(p: Dict, x: jnp.ndarray):
    """a_t, b_t = gated decay and input for the linear recurrence (f32)."""
    xf = x.astype(jnp.float32)
    r, i = _gates(p, xf)
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r        # (B,S,W)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in a numerically safe form
    gate = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = gate * (i * xf)
    return a, b


def rglru_scan(p: Dict, x: jnp.ndarray, h0: jnp.ndarray = None):
    """Associative-scan linear recurrence. x: (B,S,W) -> (y, h_last)."""
    a, b = _rglru_coeffs(p, x)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block(cfg: ModelConfig, p: Dict, x: jnp.ndarray
                ) -> jnp.ndarray:
    """Full-sequence Griffin recurrent block. x: (B,S,d) -> (B,S,d)."""
    g = jax.nn.gelu(matmul(x, p["in_gelu"]).astype(jnp.float32))
    u = matmul(x, p["in_rnn"])
    u, _ = _conv1d(p, u)
    h, _ = rglru_scan(p, u)
    y = (g * h.astype(jnp.float32)).astype(x.dtype)
    return matmul(y, p["out"])


def rglru_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                 state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """O(1) decode step. x: (B,1,d); state: {'h': (B,W), 'conv': (B,K-1,W)}."""
    g = jax.nn.gelu(matmul(x, p["in_gelu"]).astype(jnp.float32))
    u = matmul(x, p["in_rnn"])
    u, conv_state = _conv1d(p, u, state["conv"])
    a, b = _rglru_coeffs(p, u)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]    # (B,W)
    y = (g[:, 0] * h).astype(x.dtype)[:, None]
    out = matmul(y, p["out"])
    return out, {"h": h, "conv": conv_state}


def rglru_state_init(cfg: ModelConfig, batch: int, dtype) -> Dict:
    w = cfg.lru_width_
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }
