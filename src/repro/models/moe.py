"""Mixture-of-Experts with sort-free capacity dispatch (EP-shardable).

Dispatch is the MaxText-style "dropping" scheme: each token's top-k expert
assignments scatter into a per-expert capacity buffer (E, C, d); expert FFNs
run as one E-batched einsum (experts shard over the 'model'/EP mesh axis, so
the scatter/gather lower to all-to-alls under SPMD); results gather back and
combine weighted by the router gate. Tokens beyond capacity drop (residual
passes them through) — the standard trade for static shapes on TPU.

Router variants: softmax top-k renormalized (Switch/Mixtral style) and
sigmoid scoring (DeepSeek-V3 / Llama-4). Aux losses: load-balance (Switch)
+ router z-loss, returned for the train loop to weigh in.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, matmul, swiglu, swiglu_init


def moe_init(cfg: ModelConfig, key) -> Dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "experts": {
            "gate": dense_init(ks[1], d, e * f, dt).reshape(d, e, f
                                                            ).swapaxes(0, 1),
            "up": dense_init(ks[2], d, e * f, dt).reshape(d, e, f
                                                          ).swapaxes(0, 1),
            "down": dense_init(ks[3], f, e * d, dt).reshape(f, e, d
                                                            ).swapaxes(0, 1),
        },
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = swiglu_init(ks[4], d,
                                  cfg.n_shared_experts * f, dt)
    return p


def _router(cfg: ModelConfig, p: Dict, x2: jnp.ndarray):
    """x2: (T, d) -> (gates (T,k), ids (T,k), aux losses)."""
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), p["router"])
    if cfg.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        gates, ids = jax.lax.top_k(scores, cfg.top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, cfg.top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + z-loss
    e = cfg.n_experts
    me = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    pe = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(me * pe)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, ids, {"moe_lb": lb_loss, "moe_z": z_loss}


def moe_block(cfg: ModelConfig, p: Dict, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, d) -> (y, aux_losses)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    x2 = x.reshape(t, d)
    gates, ids, aux = _router(cfg, p, x2)

    # capacity per expert (static). At serving scale (small token counts —
    # decode ticks, short prefills) use dropless exact routing (cap = T);
    # at training scale use Switch-style capacity dropping for static,
    # balanced buffers.
    if t * k <= 4096:
        cap = t
    else:
        cap = max(int(t * k / e * cfg.capacity_factor), 1)

    # in-expert slot of each assignment: rank among same-expert assignments
    flat_ids = ids.reshape(-1)                               # (T*k,)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)    # (T*k, E)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot)            # exclusive
    rank = jnp.take_along_axis(ranks, flat_ids[:, None], axis=1)[:, 0]
    dropped = rank >= cap
    slot = jnp.where(dropped, cap, rank)                     # cap = trash row

    # dispatch: build the (E, C, d) buffer by GATHERING tokens through an
    # int32 slot->token map. Scattering (T*k, d) activations into the
    # expert-sharded buffer makes XLA replicate the scatter source
    # (measured: 13 TB of f32 all-gather on deepseek prefill_32k); the
    # gather form moves only the (T, d) bf16 token array + an int map
    # (§Perf deepseek iterations 1-3).
    tok_of_assign = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)  # (T*k,)
    tok_map = jnp.full((e, cap + 1), t, jnp.int32)           # t = trash row
    tok_map = tok_map.at[flat_ids, slot].set(tok_of_assign, mode="drop")
    x_pad = jnp.concatenate([x2, jnp.zeros((1, d), x.dtype)])
    buf = x_pad[tok_map[:, :cap]]                            # (E, C, d)
    buf = constrain(buf, "expert", None, None)

    # E-batched expert SwiGLU (EP: E shards over 'model')
    w = p["experts"]
    g = jnp.einsum("ecd,edf->ecf", buf, w["gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, w["up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w["down"],
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out_buf = constrain(out_buf, "expert", None, None)

    # combine: scatter-ADD each buffer row back to its source token.
    # A gather (out_buf[flat_ids, slot]) materializes a replicated
    # (T*k, d) — the scatter-add form keeps the accumulation token-sharded
    # and lowers to (T, d) all-reduces over the EP axis (§Perf deepseek).
    gate_map = jnp.zeros((e, cap + 1), x.dtype)
    gate_map = gate_map.at[flat_ids, slot].set(
        gates.reshape(-1).astype(x.dtype), mode="drop")
    contrib = out_buf * gate_map[:, :cap, None]              # (E, C, d)
    y = jnp.zeros((t + 1, d), x.dtype)
    y = y.at[tok_map[:, :cap].reshape(-1)].add(
        contrib.reshape(-1, d), mode="drop")[:t]
    y = constrain(y, "batch", None)

    if "shared" in p:
        y = y + swiglu(p["shared"], x2)
    return y.reshape(b, s, d), aux
