"""Attention mixers: GQA (global & sliding-window), chunked-causal
(flash-style, memory-bounded), MLA (DeepSeek), and their decode paths.

Memory discipline (this is what makes prefill_32k lowerable):
- ``attn_impl='naive'``   materializes (B, H, Sq, Skv) scores — fine for
  short sequences and smoke tests.
- ``attn_impl='chunked'`` processes query chunks against only their causal
  KV prefix (static Python triangle over chunks, online-softmax inner scan),
  so peak live memory is (B, H, cq, ckv) and FLOPs are the exact causal
  triangle — no masked-half waste.

Decode reads the KV cache with plain jnp ops so XLA SPMD can distribute the
softmax over a sequence-sharded cache (the distributed flash-decode
pattern); the Pallas kernel (repro.kernels.flash_decode) is the
single-device fast path used by the serving engine.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, matmul, rmsnorm

NEG_INF = -1e30


# ================================================================== params
def attn_init(cfg: ModelConfig, key) -> Dict:
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], d, h * dh, dt).reshape(d, h, dh),
        "wk": dense_init(ks[1], d, kh * dh, dt).reshape(d, kh, dh),
        "wv": dense_init(ks[2], d, kh * dh, dt).reshape(d, kh, dh),
        "wo": dense_init(ks[3], h * dh, d, dt).reshape(h, dh, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dt)
        p["bk"] = jnp.zeros((kh, dh), dt)
        p["bv"] = jnp.zeros((kh, dh), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def mla_init(cfg: ModelConfig, key) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    qh = cfg.qk_nope_dim + cfg.qk_rope_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_dq": dense_init(ks[0], d, cfg.q_lora_rank, dt),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), jnp.float32),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank, h * qh, dt
                           ).reshape(cfg.q_lora_rank, h, qh),
        "w_dkv": dense_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dt),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), jnp.float32),
        "w_ukv": dense_init(
            ks[3], cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim), dt
        ).reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim + cfg.v_head_dim),
        "wo": dense_init(ks[4], h * cfg.v_head_dim, d, dt
                         ).reshape(h, cfg.v_head_dim, d),
    }


# ============================================================ QKV plumbing
def _qkv(cfg: ModelConfig, p: Dict, x: jnp.ndarray, positions: jnp.ndarray):
    """x: (B, S, d) -> q (B,S,H,Dh), k/v (B,S,Kh,Dh), rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(x.dtype)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    b, s, kh, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, groups, dh)
                            ).reshape(b, s, kh * groups, dh)


# ========================================================== full-seq paths
def _naive_attention(q, k, v, positions, window: int) -> jnp.ndarray:
    """(B,S,H,D) x (B,S,H,D) -> (B,S,H,D); causal (+optional window)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    pq = positions[:, :, None]   # (B,Sq,1)
    pk = positions[:, None, :]   # (B,1,Sk)
    mask = pq >= pk
    if window > 0:
        mask &= (pq - pk) < window
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _chunked_attention(q, k, v, positions, window: int,
                       cq: int, ckv: int) -> jnp.ndarray:
    """Flash-style causal attention, exact-triangle FLOPs.

    Static Python loop over query chunks; each chunk scans only the KV chunks
    its causal (and window) footprint reaches, carrying online-softmax
    (m, l, acc). Peak live scores: (B, H, cq, ckv) f32.
    """
    b, s, h, dh = q.shape
    dv = v.shape[-1]       # may differ from dh (MLA: qk 192, v 128)
    scale = 1.0 / math.sqrt(dh)
    cq = min(cq, s)
    ckv = min(ckv, s)
    assert s % cq == 0 and s % ckv == 0, (s, cq, ckv)
    outs = []
    for i in range(s // cq):
        q_i = q[:, i * cq:(i + 1) * cq]                       # (B,cq,H,D)
        pq = positions[:, i * cq:(i + 1) * cq]                # (B,cq)
        hi = (i + 1) * cq                                     # causal bound
        lo = max(0, (i * cq - window) // ckv * ckv) if window > 0 else 0
        n_kv = -(-(hi - lo) // ckv)                           # chunks to scan
        k_sl = jax.lax.dynamic_slice_in_dim(k, lo, n_kv * ckv, axis=1)
        v_sl = jax.lax.dynamic_slice_in_dim(v, lo, n_kv * ckv, axis=1)
        p_sl = jax.lax.dynamic_slice_in_dim(positions, lo, n_kv * ckv, axis=1)
        k_ch = k_sl.reshape(b, n_kv, ckv, h, dh).swapaxes(0, 1)
        v_ch = v_sl.reshape(b, n_kv, ckv, h, dv).swapaxes(0, 1)
        p_ch = p_sl.reshape(b, n_kv, ckv).swapaxes(0, 1)

        def body(carry, inp):
            m, l, acc = carry
            k_j, v_j, p_j = inp
            sc = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j,
                            preferred_element_type=jnp.float32) * scale
            msk = pq[:, :, None] >= p_j[:, None, :]
            if window > 0:
                msk &= (pq[:, :, None] - p_j[:, None, :]) < window
            sc = jnp.where(msk[:, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p_ = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p_.astype(q.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_ch, v_ch, p_ch))
        out_i = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        outs.append(out_i.swapaxes(1, 2))                     # (B,cq,H,D)
    return jnp.concatenate(outs, axis=1)


def attention_block(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                    positions: jnp.ndarray, *, window: int = 0) -> jnp.ndarray:
    """Full-sequence GQA attention (train / prefill)."""
    q, k, v = _qkv(cfg, p, x, positions)
    groups = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, groups), _repeat_kv(v, groups)
    s = x.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "naive" if s <= max(cfg.attn_chunk_q, 512) else "chunked"
    if impl == "naive":
        out = _naive_attention(q, k, v, positions, window)
    else:
        out = _chunked_attention(q, k, v, positions, window,
                                 cfg.attn_chunk_q, cfg.attn_chunk_kv)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)


# ============================================================== decode path
def attn_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
                cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                pos: jnp.ndarray, *, window: int = 0
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (B, 1, d); cache_{k,v}: (B, S, Kh, Dh) (ring
    buffer of size `window` when window > 0); pos: (B,) absolute position of
    the new token. Returns (y (B,1,d), new_k, new_v)."""
    b = x.shape[0]
    s_cache = cache_k.shape[1]
    q, k_new, v_new = _qkv(cfg, p, x, pos[:, None])
    slot = pos % s_cache if window > 0 else pos
    cache_k = _scatter_cache(cache_k, k_new, slot)
    cache_v = _scatter_cache(cache_v, v_new, slot)

    groups = cfg.n_heads // cfg.n_kv_heads
    scale = 1.0 / math.sqrt(cfg.head_dim_)
    qg = q.reshape(b, cfg.n_kv_heads, groups, cfg.head_dim_)
    # scores over the whole cache; SPMD distributes when cache is seq-sharded
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    cache_pos = _cache_positions(pos, s_cache, window)          # (B, S)
    valid = cache_pos <= pos[:, None]
    if window > 0:
        valid &= (pos[:, None] - cache_pos) < window
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(x.dtype), cache_v,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim_)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return y, cache_k, cache_v


def _scatter_cache(cache: jnp.ndarray, new: jnp.ndarray,
                   slot: jnp.ndarray) -> jnp.ndarray:
    """cache (B,S,Kh,D), new (B,1,Kh,D), slot (B,) -> per-batch dynamic set."""
    b = cache.shape[0]
    oh = jax.nn.one_hot(slot, cache.shape[1], dtype=cache.dtype)  # (B,S)
    return cache * (1 - oh[:, :, None, None]) + new * oh[:, :, None, None]


def _cache_positions(pos: jnp.ndarray, s_cache: int, window: int):
    """Absolute position stored at each cache slot (ring-aware)."""
    idx = jnp.arange(s_cache)[None, :]
    if window <= 0:
        return jnp.broadcast_to(idx, (pos.shape[0], s_cache))
    # ring buffer: slot holds the latest absolute position p with
    # p % s_cache == idx and p <= pos
    cur = pos[:, None]
    cand = cur - ((cur - idx) % s_cache)
    return cand


# ==================================================================== MLA
def mla_block(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
              positions: jnp.ndarray) -> jnp.ndarray:
    """DeepSeek MLA, full-sequence (train / prefill): reconstruct per-head
    K/V from the latent, then chunked/naive attention with qk dim
    (nope+rope) and v dim v_head_dim."""
    b, s, d = x.shape
    h = cfg.n_heads
    cq = rmsnorm(matmul(x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"]).astype(x.dtype)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = matmul(x, p["w_dkv"])
    c_kv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_ukv"]).astype(x.dtype)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)

    impl = cfg.attn_impl
    if impl == "auto":
        impl = "naive" if s <= max(cfg.attn_chunk_q, 512) else "chunked"
    if impl == "naive":
        out = _naive_attention(qq, k, v, positions, 0)
    else:
        out = _chunked_attention(qq, k, v, positions, 0,
                                 cfg.attn_chunk_q, cfg.attn_chunk_kv)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)


def mla_decode(cfg: ModelConfig, p: Dict, x: jnp.ndarray,
               cache_ckv: jnp.ndarray, cache_kr: jnp.ndarray,
               pos: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Absorbed-matrix MLA decode: attention runs directly in the
    kv_lora_rank latent space — the cache stores (c_kv, k_rope) only
    (576 dims/token instead of H*(nope+v)=32k), which is MLA's point.

    x: (B,1,d); cache_ckv: (B,S,R); cache_kr: (B,S,rope); pos: (B,)."""
    b = x.shape[0]
    h, r = cfg.n_heads, cfg.kv_lora_rank
    cq = rmsnorm(matmul(x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"]).astype(x.dtype)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)

    dkv = matmul(x, p["w_dkv"])
    c_new, kr_new = jnp.split(dkv, [r], axis=-1)
    c_new = rmsnorm(c_new, p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kr_new[:, :, None, :], pos[:, None], cfg.rope_theta)

    s_cache = cache_ckv.shape[1]
    oh = jax.nn.one_hot(pos, s_cache, dtype=cache_ckv.dtype)     # (B,S)
    cache_ckv = cache_ckv * (1 - oh[:, :, None]) + c_new * oh[:, :, None]
    cache_kr = cache_kr * (1 - oh[:, :, None]) + kr_new[:, :, 0, :] * oh[:, :, None]

    w_uk = p["w_ukv"][:, :, :cfg.qk_nope_dim]                    # (R,H,nope)
    w_uv = p["w_ukv"][:, :, cfg.qk_nope_dim:]                    # (R,H,v)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_uk).astype(x.dtype)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    sc = (jnp.einsum("bshr,btr->bhst", q_lat, cache_ckv,
                     preferred_element_type=jnp.float32)
          + jnp.einsum("bshk,btk->bhst", q_rope, cache_kr,
                       preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(s_cache)[None, :] <= pos[:, None]
    sc = jnp.where(valid[:, None, None], sc, NEG_INF)
    wgt = jax.nn.softmax(sc, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", wgt.astype(x.dtype), cache_ckv,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshr,rhk->bshk", o_lat, w_uv).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return y, cache_ckv, cache_kr
