"""Transformer assembly: init / forward / loss / prefill / decode for every
assigned architecture, driven entirely by :class:`ModelConfig`.

Layer-stacking strategy: consecutive layers of the same block kind form a
*run*; each run's params are stacked on a leading axis and applied with one
``lax.scan`` (HLO stays O(#runs), not O(#layers) — an 80-layer dense model
compiles as a single scan; RecurrentGemma's (rglru, rglru, local)×8+2
pattern becomes 26 runs of tiny bodies; DeepSeek is dense-prefix + MoE-run).

Memory discipline:
- per-block remat (``cfg.remat``) wraps the scan body;
- the LM loss never materializes (B, S, V) logits: it scans over sequence
  chunks (``cfg.loss_chunk``) with a remat'd chunk body, so peak live loss
  memory is (B, C, V/shards).

Decode: the KV/state cache is a pytree mirroring the run structure; caches
are donated by the serve step so XLA updates them in place.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    dense_init,
    embed_init,
    embed_lookup,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
    unembed,
)


# ================================================================= structure
def _runs(blocks: List[str]) -> List[Tuple[str, int]]:
    """Group consecutive equal block kinds: ['a','a','b'] -> [('a',2),('b',1)]."""
    out: List[Tuple[str, int]] = []
    for b in blocks:
        if out and out[-1][0] == b:
            out[-1] = (b, out[-1][1] + 1)
        else:
            out.append((b, 1))
    return out


def _stack(trees: List[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ==================================================================== params
def _block_init(cfg: ModelConfig, kind: str, key) -> Dict:
    mixer, mlp = kind.split(":")
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {
        "norm1": rmsnorm_init(cfg.d_model),
        "norm2": rmsnorm_init(cfg.d_model),
    }
    if mixer in ("attn", "local"):
        p["mix"] = (attn.mla_init(cfg, k1) if cfg.mla
                    else attn.attn_init(cfg, k1))
    elif mixer == "rglru":
        p["mix"] = rglru_mod.rglru_init(cfg, k1)
    elif mixer == "rwkv":
        p["mix"] = rwkv_mod.rwkv_init(cfg, k1)  # includes channel-mix
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if mixer != "rwkv":
        if mlp == "moe":
            p["mlp"] = moe_mod.moe_init(cfg, k2)
        else:
            p["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff,
                                   jnp.dtype(cfg.dtype))
    return p


def init_params(cfg: ModelConfig, key) -> Dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = cfg.blocks()
    runs = _runs(blocks)
    run_params, i = [], 0
    for kind, count in runs:
        run_params.append(_stack([_block_init(cfg, kind, keys[i + j])
                                  for j in range(count)]))
        i += count
    p: Dict[str, Any] = {
        "embed": embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dt),
        "runs": run_params,
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.vocab_size, dt)
    if cfg.mtp:
        k = keys[-3]
        p["mtp"] = {
            "proj": dense_init(k, 2 * cfg.d_model, cfg.d_model, dt),
            "block": _block_init(cfg, "attn:dense", jax.random.fold_in(k, 1)),
            "norm": rmsnorm_init(cfg.d_model),
        }
    return p


def param_specs(cfg: ModelConfig, key=None):
    """ShapeDtypeStruct pytree of the params (no allocation) for AOT."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# =================================================================== forward
def _block_apply(cfg: ModelConfig, kind: str, p: Dict, x: jnp.ndarray,
                 positions: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    mixer, mlp = kind.split(":")
    aux: Dict[str, jnp.ndarray] = {}
    h = rmsnorm(x, p["norm1"], cfg.norm_eps, cfg.norm_f32)
    if mixer == "attn":
        y = (attn.mla_block(cfg, p["mix"], h, positions) if cfg.mla
             else attn.attention_block(cfg, p["mix"], h, positions))
    elif mixer == "local":
        y = attn.attention_block(cfg, p["mix"], h, positions,
                                 window=cfg.window)
    elif mixer == "rglru":
        y = rglru_mod.rglru_block(cfg, p["mix"], h)
    elif mixer == "rwkv":
        y = rwkv_mod.rwkv_block(cfg, p["mix"], h)
    x = x + y
    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps, cfg.norm_f32)
    if mixer == "rwkv":
        prev = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)
        y2, _ = rwkv_mod.channel_mix(cfg, p["mix"], h2, prev)
    elif mlp == "moe":
        y2, aux = moe_mod.moe_block(cfg, p["mlp"], h2)
    else:
        y2 = swiglu(p["mlp"], h2)
    x = x + y2
    x = constrain(x, "batch", "seq", "embed")
    return x, aux


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full"


def forward(cfg: ModelConfig, params: Dict, inputs: jnp.ndarray,
            positions: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, Dict]:
    """inputs: (B, S) int tokens, or (B, S, d) embeddings (stub frontends).
    Returns (hidden (B,S,d), aux losses)."""
    if inputs.ndim == 2:
        x = embed_lookup(params["embed"], inputs)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(x, "batch", "seq", "embed")

    aux_all: List[Dict] = []
    for (kind, count), stacked in zip(_runs(cfg.blocks()), params["runs"]):
        body = _remat(cfg, functools.partial(_block_apply, cfg, kind))

        def scan_body(carry, layer_p):
            y, aux = body(layer_p, carry, positions)
            return y, aux

        def scan_fn(x, stacked=stacked, scan_body=scan_body):
            return jax.lax.scan(scan_body, x, stacked)

        x, aux = scan_fn(x)
        aux_all.append(jax.tree.map(jnp.sum, aux))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.norm_f32)
    aux = {}
    for a in aux_all:
        for k, v in a.items():
            aux[k] = aux.get(k, 0.0) + v
    return x, aux


# ====================================================================== loss
def _head_table(cfg: ModelConfig, params: Dict) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"]
    return params["lm_head"].T  # (V, d) view for unembed


def lm_loss(cfg: ModelConfig, params: Dict, hidden: jnp.ndarray,
            labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
            ) -> jnp.ndarray:
    """Mean next-token CE without materializing (B, S, V): scan over
    ``cfg.loss_chunk``-sized sequence chunks with a remat'd body."""
    b, s, d = hidden.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0, (s, c)
    n = s // c
    table = _head_table(cfg, params)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    h_ch = jnp.moveaxis(hidden.reshape(b, n, c, d), 1, 0)
    y_ch = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
    m_ch = jnp.moveaxis(mask.reshape(b, n, c).astype(jnp.float32), 1, 0)

    def chunk(carry, inp):
        h, y, m = inp
        logits = unembed(h, table, cfg.logit_softcap)        # (B,C,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    chunk = _remat(cfg, chunk) if cfg.remat != "none" else chunk
    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.float32(0)),
                                 (h_ch, y_ch, m_ch))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict,
            aux_weights: Tuple[float, float] = (0.01, 1e-3)) -> Tuple[jnp.ndarray, Dict]:
    """batch: {'inputs': tokens (B,S) or embeds (B,S,d), 'labels': (B,S),
    optional 'mask': (B,S)} -> (scalar loss, metrics)."""
    hidden, aux = forward(cfg, params, batch["inputs"])
    loss = lm_loss(cfg, params, hidden, batch["labels"], batch.get("mask"))
    metrics = {"ce": loss}
    if "moe_lb" in aux:
        n_moe = max(sum(1 for k in cfg.blocks() if k.endswith(":moe")), 1)
        lb = aux["moe_lb"] / n_moe
        z = aux["moe_z"] / n_moe
        loss = loss + aux_weights[0] * lb + aux_weights[1] * z
        metrics.update(moe_lb=lb, moe_z=z)
    if cfg.mtp:
        mtp_loss = _mtp_loss(cfg, params, hidden, batch)
        loss = loss + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(cfg: ModelConfig, params: Dict, hidden: jnp.ndarray,
              batch: Dict) -> jnp.ndarray:
    """DeepSeek-V3 multi-token prediction: one extra block predicts t+2
    from [h_t ; emb(tok_{t+1})]."""
    p = params["mtp"]
    tokens = batch["inputs"]
    if tokens.ndim != 2:  # embedding-input archs: MTP needs token ids
        return jnp.float32(0.0)
    b, s = tokens.shape
    # keep full length S (chunked attention & loss need S % chunk == 0):
    # position t sees [h_t ; emb(tok_{t+1})] and predicts tok_{t+2};
    # the final position is masked (no t+1 token).
    emb_next = embed_lookup(
        params["embed"],
        jnp.concatenate([tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], 1))
    h_in = jnp.concatenate([hidden, emb_next], axis=-1)
    h_in = jnp.einsum("bsd,de->bse", h_in, p["proj"]).astype(hidden.dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h2, _ = _block_apply(cfg, "attn:dense", p["block"], h_in, pos)
    h2 = rmsnorm(h2, p["norm"], cfg.norm_eps)
    # labels2[t] = labels[t+1] (= tok_{t+2}); last position invalid
    labels2 = jnp.concatenate(
        [batch["labels"][:, 1:], jnp.zeros((b, 1), batch["labels"].dtype)], 1)
    mask = jnp.concatenate(
        [jnp.ones((b, s - 1), jnp.float32), jnp.zeros((b, 1), jnp.float32)],
        axis=1)
    return lm_loss(cfg, params, h2, labels2, mask)


# ===================================================================== cache
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Decode cache pytree mirroring the run structure.

    attn caches are (R, B, S, ...); 'local' runs bound S by the window
    (ring buffer); recurrent runs carry O(1) state."""
    dt = jnp.dtype(cfg.dtype)
    caches = []
    for kind, count in _runs(cfg.blocks()):
        mixer = kind.split(":")[0]
        if mixer in ("attn", "local"):
            s = min(max_len, cfg.window) if (mixer == "local" and cfg.window
                                             ) else max_len
            if cfg.mla:
                c = {"ckv": jnp.zeros((count, batch, s, cfg.kv_lora_rank), dt),
                     "kr": jnp.zeros((count, batch, s, cfg.qk_rope_dim), dt)}
            else:
                kh, dh = cfg.n_kv_heads, cfg.head_dim_
                c = {"k": jnp.zeros((count, batch, s, kh, dh), dt),
                     "v": jnp.zeros((count, batch, s, kh, dh), dt)}
        elif mixer == "rglru":
            st = rglru_mod.rglru_state_init(cfg, batch, dt)
            c = jax.tree.map(lambda x: jnp.broadcast_to(
                x, (count,) + x.shape).copy(), st)
        elif mixer == "rwkv":
            st = rwkv_mod.rwkv_state_init(cfg, batch, dt)
            c = jax.tree.map(lambda x: jnp.broadcast_to(
                x, (count,) + x.shape).copy(), st)
        caches.append(c)
    return {"runs": caches, "pos": jnp.zeros((batch,), jnp.int32)}


def _block_decode(cfg: ModelConfig, kind: str, p: Dict, cache: Dict,
                  x: jnp.ndarray, pos: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, Dict]:
    mixer, mlp = kind.split(":")
    h = rmsnorm(x, p["norm1"], cfg.norm_eps, cfg.norm_f32)
    if mixer in ("attn", "local"):
        if cfg.mla:
            y, ckv, kr = attn.mla_decode(cfg, p["mix"], h, cache["ckv"],
                                         cache["kr"], pos)
            cache = {"ckv": ckv, "kr": kr}
        else:
            window = cfg.window if mixer == "local" else 0
            y, ck, cv = attn.attn_decode(cfg, p["mix"], h, cache["k"],
                                         cache["v"], pos, window=window)
            cache = {"k": ck, "v": cv}
    elif mixer == "rglru":
        y, cache = rglru_mod.rglru_decode(cfg, p["mix"], h, cache)
    elif mixer == "rwkv":
        y, cache = rwkv_mod.rwkv_decode(cfg, p["mix"], h, cache)
    x = x + y
    h2 = rmsnorm(x, p["norm2"], cfg.norm_eps, cfg.norm_f32)
    if mixer == "rwkv":
        y2, cm_prev = rwkv_mod.channel_mix(cfg, p["mix"], h2,
                                           cache["cm_prev"])
        cache = dict(cache, cm_prev=cm_prev)
    elif mlp == "moe":
        y2, _ = moe_mod.moe_block(cfg, p["mlp"], h2)
    else:
        y2 = swiglu(p["mlp"], h2)
    return x + y2, cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """One serving step: tokens (B,) or embeddings (B, d) -> (logits (B, V),
    updated cache). Cache['pos'] tracks per-sequence absolute position."""
    pos = cache["pos"]
    if tokens.ndim == 1:
        x = embed_lookup(params["embed"], tokens[:, None])
    else:
        x = tokens[:, None, :].astype(jnp.dtype(cfg.dtype))
    new_caches = []
    for (kind, count), stacked_p, stacked_c in zip(
            _runs(cfg.blocks()), params["runs"], cache["runs"]):

        def body(x, layer):
            lp, lc = layer
            y, nc = _block_decode(cfg, kind, lp, lc, x, pos)
            return y, nc

        x, nc = jax.lax.scan(body, x, (stacked_p, stacked_c))
        new_caches.append(nc)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.norm_f32)
    logits = unembed(x[:, 0], _head_table(cfg, params), cfg.logit_softcap)
    return logits, {"runs": new_caches, "pos": pos + 1}


def prefill(cfg: ModelConfig, params: Dict, inputs: jnp.ndarray,
            lengths: jnp.ndarray, max_len: int
            ) -> Tuple[jnp.ndarray, Dict]:
    """Process the prompt, build the cache. inputs: (B, S_p) tokens or
    (B, S_p, d) embeds; lengths: (B,) valid prompt lengths.
    Returns (last-position logits (B, V), cache)."""
    b = inputs.shape[0]
    s_p = inputs.shape[1]
    if inputs.ndim == 2:
        x = embed_lookup(params["embed"], inputs)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(s_p, dtype=jnp.int32), (b, s_p))
    cache = init_cache(cfg, b, max_len)
    new_caches = []
    for (kind, count), stacked_p, stacked_c in zip(
            _runs(cfg.blocks()), params["runs"], cache["runs"]):
        mixer = kind.split(":")[0]

        def body(x, layer, kind=kind, mixer=mixer):
            lp, lc = layer
            h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
            if mixer in ("attn", "local"):
                window = cfg.window if mixer == "local" else 0
                if cfg.mla:
                    y, lc = _mla_prefill(cfg, lp["mix"], h, positions, lc)
                else:
                    y, lc = _attn_prefill(cfg, lp["mix"], h, positions, lc,
                                          window)
            elif mixer == "rglru":
                y, lc = _rglru_prefill(cfg, lp["mix"], h, lc)
            elif mixer == "rwkv":
                y, lc = _rwkv_prefill(cfg, lp["mix"], h, lc)
            x = x + y
            h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
            if mixer == "rwkv":
                prev = jnp.zeros((b, x.shape[-1]), x.dtype)
                y2, cm_prev = rwkv_mod.channel_mix(cfg, lp["mix"], h2, prev)
                lc = dict(lc, cm_prev=cm_prev)
            elif kind.endswith(":moe"):
                y2, _ = moe_mod.moe_block(cfg, lp["mlp"], h2)
            else:
                y2 = swiglu(lp["mlp"], h2)
            return x + y2, lc

        x, nc = jax.lax.scan(body, x, (stacked_p, stacked_c))
        new_caches.append(nc)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps, cfg.norm_f32)
    last = jnp.take_along_axis(
        x, jnp.maximum(lengths - 1, 0)[:, None, None].astype(jnp.int32),
        axis=1)[:, 0]
    logits = unembed(last, _head_table(cfg, params), cfg.logit_softcap)
    return logits, {"runs": new_caches, "pos": lengths.astype(jnp.int32)}


def _attn_prefill(cfg, p, h, positions, lc, window):
    q, k, v = attn._qkv(cfg, p, h, positions)
    groups = cfg.n_heads // cfg.n_kv_heads
    kk, vv = attn._repeat_kv(k, groups), attn._repeat_kv(v, groups)
    s = h.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "naive" if s <= max(cfg.attn_chunk_q, 512) else "chunked"
    if impl == "naive":
        out = attn._naive_attention(q, kk, vv, positions, window)
    else:
        out = attn._chunked_attention(q, kk, vv, positions, window,
                                      cfg.attn_chunk_q, cfg.attn_chunk_kv)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(h.dtype)
    s_cache = lc["k"].shape[1]
    if s <= s_cache:
        ck = jax.lax.dynamic_update_slice_in_dim(lc["k"], k, 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(lc["v"], v, 0, axis=1)
    else:  # ring window cache: keep the last s_cache positions
        ck = k[:, -s_cache:]
        cv = v[:, -s_cache:]
        # rotate so slot (pos % s_cache) holds position pos
        shift = (s % s_cache)
        ck = jnp.roll(ck, shift, axis=1)
        cv = jnp.roll(cv, shift, axis=1)
    return y, {"k": ck, "v": cv}


def _mla_prefill(cfg, p, h, positions, lc):
    from repro.models.layers import matmul
    y = attn.mla_block(cfg, p, h, positions)
    dkv = matmul(h, p["w_dkv"])
    c_kv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = attn.apply_rope(k_rope[:, :, None, :], positions,
                             cfg.rope_theta)[:, :, 0]
    ckv = jax.lax.dynamic_update_slice_in_dim(lc["ckv"], c_kv, 0, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(lc["kr"], k_rope, 0, axis=1)
    return y, {"ckv": ckv, "kr": kr}


def _rglru_prefill(cfg, p, h, lc):
    from repro.models.layers import matmul
    g = jax.nn.gelu(matmul(h, p["in_gelu"]).astype(jnp.float32))
    u = matmul(h, p["in_rnn"])
    u, conv_state = rglru_mod._conv1d(p, u, lc["conv"])
    hh, h_last = rglru_mod.rglru_scan(p, u, lc["h"])
    y = (g * hh.astype(jnp.float32)).astype(h.dtype)
    return matmul(y, p["out"]), {"h": h_last, "conv": conv_state}


def _rwkv_prefill(cfg, p, h, lc):
    y, tm_prev, s_last = rwkv_mod.time_mix(cfg, p, h, lc["tm_prev"], lc["s"])
    return y, dict(lc, s=s_last, tm_prev=tm_prev)
