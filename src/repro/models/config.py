"""Model configuration — one dataclass covering all 10 assigned families.

``blocks()`` expands the per-layer block kinds ("<mixer>:<mlp>"); the
transformer groups consecutive equal kinds into scanned runs (see
``transformer._runs``) so an 80-layer dense stack compiles as one
``lax.scan`` while RecurrentGemma's (rglru, rglru, local) interleave and
DeepSeek's dense-prefix + MoE-suffix stay exact.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention options
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen1.5
    rope_theta: float = 10_000.0
    window: int = 0                 # sliding-window size for 'local' blocks
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # layer pattern (cycled to n_layers); kinds: attn | local | rglru | rwkv
    pattern: Tuple[str, ...] = ("attn",)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0          # deepseek: first k layers use dense MLP
    capacity_factor: float = 1.25
    router_score: str = "softmax"   # softmax | sigmoid (deepseek/llama4)

    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    mtp: bool = False               # deepseek multi-token prediction head

    # RG-LRU (recurrentgemma / griffin)
    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4

    # RWKV6
    rwkv_head_dim: int = 64
    wkv_unroll: int = 1             # scan unroll: keeps the (D,D) state in
                                    # registers across steps (see §Perf rwkv)

    # modality frontend: tokens | embeddings (audio/vlm stubs feed embeddings)
    input_mode: str = "tokens"

    # numerics / memory
    dtype: str = "bfloat16"
    norm_f32: bool = True           # False: bf16 norm math (f32 mean accum)
    remat: str = "full"             # none | full | dots
    attn_impl: str = "auto"         # auto | naive | chunked
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    loss_chunk: int = 512           # seq chunk for the vocab-safe CE

    # ---------------------------------------------------------------- utils
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    def mlp_kind(self, layer: int) -> str:
        if self.n_experts > 0 and layer >= self.first_k_dense:
            return "moe"
        return "dense"

    def blocks(self) -> List[str]:
        """Per-layer '<mixer>:<mlp>' kinds."""
        out = []
        for i in range(self.n_layers):
            mixer = self.pattern[i % len(self.pattern)]
            out.append(f"{mixer}:{self.mlp_kind(i)}")
        return out

    def supports_long_context(self) -> bool:
        """True iff decode cost is sub-quadratic in context (SSM/hybrid):
        every mixer is recurrent or window-bounded."""
        return all(m in ("rglru", "rwkv", "local")
                   for m in (self.pattern[i % len(self.pattern)]
                             for i in range(self.n_layers)))

    def n_params(self) -> int:
        """Exact parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.head_dim_
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size  # lm head
        n += d  # final norm
        for i, kind in enumerate(self.blocks()):
            mixer, mlp = kind.split(":")
            n += 2 * d  # two pre-norms
            if mixer == "attn" or mixer == "local":
                if self.mla:
                    qh = self.qk_nope_dim + self.qk_rope_dim
                    n += d * self.q_lora_rank + self.q_lora_rank  # q down + norm
                    n += self.q_lora_rank * self.n_heads * qh     # q up
                    n += d * (self.kv_lora_rank + self.qk_rope_dim)
                    n += self.kv_lora_rank                         # kv norm
                    n += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim)
                    n += self.n_heads * self.v_head_dim * d        # out
                else:
                    n += d * self.n_heads * dh          # wq
                    n += 2 * d * self.n_kv_heads * dh   # wk, wv
                    n += self.n_heads * dh * d          # wo
                    if self.qkv_bias:
                        n += (self.n_heads + 2 * self.n_kv_heads) * dh
                    if self.qk_norm:
                        n += 2 * dh
            elif mixer == "rglru":
                w = self.lru_width_
                n += 2 * d * w + w * d      # in x2 branches, out
                n += self.conv_width * w    # temporal conv
                n += 3 * w                  # lambda, input-gate, rec-gate proj diag-ish
                n += 2 * w * w // 8         # block-diag gate projections (8 blocks)
            elif mixer == "rwkv":
                n += 6 * d                  # token-shift lerp mus (r,k,v,w,g,x)
                n += 5 * d * d              # r,k,v,g,o projections
                n += 2 * d * 64 + 64 * d    # w lora (time-decay)
                n += d                      # u (bonus)
            if mlp == "dense":
                n += 3 * d * self.d_ff      # swiglu
            else:
                n += d * self.n_experts     # router
                n += self.n_experts * 3 * d * self.d_ff_expert
                n += self.n_shared_experts * 3 * d * self.d_ff_expert
        if self.mtp:
            # one extra block (attn:dense with d_ff_expert-sized MLP) + proj
            n += 2 * d * self.vocab_size // self.vocab_size  # negligible norms
            n += 4 * d * dh * self.n_heads
            n += 2 * d * d
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
