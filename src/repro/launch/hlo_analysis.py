"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while-loop *body* once, but a
scanned 80-layer transformer executes its body 80 times — the reported
FLOPs/bytes/collectives are off by orders of magnitude for scan-based
models. This module re-derives the three roofline inputs from the post-SPMD
HLO text with loop multipliers applied:

- **flops**: every ``dot``/``convolution`` contributes
  2 × numel(output) × prod(contracting dims) (operand shapes resolved
  through a per-computation symbol table), including dots inside fused
  computations;
- **bytes**: per top-level instruction at fusion granularity: output bytes
  + operand bytes — the standard "bytes accessed" HBM-traffic proxy;
- **collectives**: ring-model bytes per device:
      all-gather          out·(g-1)/g
      all-reduce          2·out·(g-1)/g
      reduce-scatter      out·(g-1)
      all-to-all          out·(g-1)/g
      collective-permute  out

Loop multipliers: each ``while`` body's cost is multiplied by the loop trip
count, read from the condition computation's comparison constant (exact for
lax.scan / fori_loop lowerings; 1 with a warning otherwise).

Validated against ``cost_analysis()`` on loop-free modules and against
closed-form counts on scanned modules — see tests/test_dryrun.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([a-z0-9\-]+)\((.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# ops that move no HBM bytes by themselves
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "partition-id", "replica-id",
             "opt-barrier"}


def _numel(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_numel(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(type_str))


def _first_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    types: Dict[str, str] = dataclasses.field(default_factory=dict)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _HDR_RE.match(stripped)
                if m:
                    cur = Computation(m.group(2))
                    if m.group(1):
                        entry = m.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.types[ins.name] = ins.out_type
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _operand_section(rest: str) -> str:
    """The operand list: everything before the matching close paren."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def tally(self, op: str, b: float) -> None:
        self.bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + b

    def add(self, other: "CompCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_detail.items():
            rec = self.coll_detail.setdefault(k, {"count": 0, "bytes": 0.0})
            rec["count"] += v["count"] * mult
            rec["bytes"] += v["bytes"] * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: Dict[str, CompCost] = {}
        self.warnings: List[str] = []

    # ------------------------------------------------------------- helpers
    def _operand_bytes(self, comp: Computation, ins: Instr) -> int:
        sec = _operand_section(ins.rest)
        total = 0
        for name in _OPERAND_NAME_RE.findall(sec):
            t = comp.types.get(name)
            if t:
                total += _type_bytes(t)
        return total

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_dims = _first_dims(ins.out_type)
        out_numel = 1
        for d in out_dims:
            out_numel *= d
        sec = _operand_section(ins.rest)
        names = _OPERAND_NAME_RE.findall(sec)
        lhs_dims = _first_dims(comp.types.get(names[0], "")) if names else []
        cm = _CONTRACT_RE.search(ins.rest)
        contract = 1
        if cm and cm.group(1):
            for d in cm.group(1).split(","):
                i = int(d)
                contract *= lhs_dims[i] if i < len(lhs_dims) else 1
        elif ins.op == "convolution":
            # rough: 2 * out_numel * (kernel spatial * in_channels)
            rhs_dims = _first_dims(comp.types.get(names[1], "")) if len(
                names) > 1 else []
            k = 1
            for d in rhs_dims[:-1]:
                k *= d
            contract = max(k, 1)
        return 2.0 * out_numel * contract

    def _collective(self, ins: Instr) -> float:
        out_b = _type_bytes(ins.out_type)
        g = 1
        gm = _GROUPS_RE.search(ins.rest)
        if gm:
            g = max(int(gm.group(2)), 1)
        else:
            gb = _GROUPS_BRACE_RE.search(ins.rest)
            if gb:
                g = max(len(gb.group(1).split(",")), 1)
        kind = ins.op.replace("-start", "")
        if kind == "all-gather":
            return out_b * (g - 1) / g
        if kind == "all-reduce":
            return 2.0 * out_b * (g - 1) / g
        if kind == "reduce-scatter":
            return float(out_b) * (g - 1)
        if kind == "all-to-all":
            return out_b * (g - 1) / g
        return float(out_b)

    def _consumer_count(self, comp: Computation, name: str) -> int:
        pat = re.compile(r"%" + re.escape(name) + r"\b")
        return sum(1 for ci in comp.instrs
                   if ci.name != name and pat.search(ci.rest))

    def _fusion_bytes(self, comp: Computation, ins: Instr,
                      called_names: List[str]) -> float:
        """HBM bytes of a fusion call site, slice- and epilogue-aware.

        Loop-carried scans fuse ``dynamic-slice(stacked_params, i)`` (reads
        one layer's slice, not the stack) and root
        ``dynamic-update-slice(big_buffer, update, i)`` (writes the update
        region in place). Counting full operand/output tensors would inflate
        bytes by the layer count — so operands consumed *only* through
        slicing ops count their slice sizes, and a DUS root (possibly under
        a root ``convert`` — the XLA-CPU convert/DUS/convert round-trip,
        which a TPU performs in place) counts its update size.

        Epilogue modeling: an operand that is a *single-use dot output*
        fuses into the producing dot's epilogue on TPU (MXU accumulators
        convert on the way out) — it never round-trips HBM, so it is not
        charged here (see also the matching discount in the dot handler)."""
        called = self.comps.get(called_names[0]) if called_names else None
        # ---- output side
        out_b = _type_bytes(ins.out_type)
        if called is not None and called.instrs:
            root = called.instrs[-1]
            if root.op == "convert":
                # root convert over a DUS == in-place DUS on TPU
                sec = _operand_section(root.rest)
                names = _OPERAND_NAME_RE.findall(sec)
                if names:
                    prod = next((ci for ci in called.instrs
                                 if ci.name == names[0]), None)
                    if prod is not None and prod.op == "dynamic-update-slice":
                        root = prod
            if root.op == "dynamic-update-slice":
                sec = _operand_section(root.rest)
                names = _OPERAND_NAME_RE.findall(sec)
                upd = called.types.get(names[1], "") if len(names) > 1 else ""
                if upd:
                    out_b = 2 * _type_bytes(upd)  # read region + write
        # ---- operand side
        in_b = 0
        if called is None:
            in_b = self._operand_bytes(comp, ins)
        else:
            param_names = {}
            for ci in called.instrs:
                if ci.op == "parameter":
                    m = re.match(r"(\d+)\)", ci.rest)
                    if m:
                        param_names[int(m.group(1))] = ci.name
            sec = _operand_section(ins.rest)
            names = _OPERAND_NAME_RE.findall(sec)
            for idx, nm in enumerate(names):
                t = comp.types.get(nm)
                if not t:
                    continue
                # single-use dot output: stays in the MXU epilogue (no HBM)
                prod = next((ci for ci in comp.instrs if ci.name == nm), None)
                if (prod is not None and prod.op == "dot"
                        and self._consumer_count(comp, nm) == 1):
                    continue
                b = _type_bytes(t)
                pname = param_names.get(idx)
                if pname is not None:
                    pat = re.compile(r"%" + re.escape(pname) + r"\b")
                    uses = [ci for ci in called.instrs
                            if ci.name != pname and pat.search(ci.rest)]
                    if uses and all(ci.op in ("dynamic-slice", "slice",
                                              "gather") for ci in uses):
                        b = sum(_type_bytes(ci.out_type) for ci in uses)
                in_b += b
        return float(out_b + in_b)

    def _trip_count(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None:
            self.warnings.append(f"missing condition {cond_name}")
            return 1
        best = 1
        for ins in cond.instrs:
            if ins.op == "constant":
                m = re.match(r"(\d+)\)", ins.rest)
                if m:
                    best = max(best, int(m.group(1)))
            for c in _CONST_RE.findall(ins.rest):
                best = max(best, int(c))
        return best

    # ----------------------------------------------------------- traversal
    def _cost_of(self, name: str) -> CompCost:
        if name in self._memo:
            return self._memo[name]
        cost = CompCost()
        self._memo[name] = cost
        comp = self.comps.get(name)
        if comp is None:
            return cost
        for ins in comp.instrs:
            op = ins.op
            if op.endswith("-done"):
                continue
            base = op.replace("-start", "")
            if op == "while":
                body = _CALLS_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trips = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    cost.add(self._cost_of(body.group(1)), trips)
                continue
            if base in COLLECTIVE_OPS:
                b = self._collective(ins)
                cost.coll_bytes += b
                rec = cost.coll_detail.setdefault(
                    base, {"count": 0, "bytes": 0.0})
                rec["count"] += 1
                rec["bytes"] += b
                cost.tally(base, _type_bytes(ins.out_type))
                continue
            if op in ("dot", "convolution"):
                cost.flops += self._dot_flops(comp, ins)
                out_b = _type_bytes(ins.out_type)
                # single-use dot: the consumer (epilogue fusion / convert)
                # writes the final result; the raw accumulator stays on-chip
                if self._consumer_count(comp, ins.name) == 1:
                    out_b = 0
                cost.tally(op, out_b + self._operand_bytes(comp, ins))
                continue
            if op in ("fusion", "call", "conditional", "custom-call", "map",
                      "reduce", "reduce-window", "sort", "scatter",
                      "select-and-scatter", "async-start"):
                called_names = _CALLS_RE.findall(ins.rest)
                for sub in called_names:
                    subc = self._cost_of(sub)
                    # called computations: count flops/collectives; bytes are
                    # accounted at this call site (fusion granularity)
                    cost.flops += subc.flops
                    cost.coll_bytes += subc.coll_bytes
                    for k, v in subc.coll_detail.items():
                        rec = cost.coll_detail.setdefault(
                            k, {"count": 0, "bytes": 0.0})
                        rec["count"] += v["count"]
                        rec["bytes"] += v["bytes"]
                cost.tally(op, self._fusion_bytes(comp, ins, called_names))
                continue
            if op in _FREE_OPS:
                continue
            if op == "dynamic-slice":
                # reads only the slice, not the (stacked) operand
                cost.tally(op, 2 * _type_bytes(ins.out_type))
                continue
            if op == "dynamic-update-slice":
                # in-place inside loops: traffic ~ the update slice
                sec = _operand_section(ins.rest)
                names = _OPERAND_NAME_RE.findall(sec)
                upd = comp.types.get(names[1], "") if len(names) > 1 else ""
                cost.tally(op, 2 * _type_bytes(upd))
                continue
            if op == "gather":
                cost.tally(op, 2 * _type_bytes(ins.out_type))
                continue
            # remaining top-level ops (copy, transpose, slice, ...)
            cost.tally(op, _type_bytes(ins.out_type)
                       + self._operand_bytes(comp, ins))
        return cost

    def entry_cost(self) -> CompCost:
        name = self.entry
        if name is None:
            for n in self.comps:
                if "main" in n:
                    name = n
                    break
        if name is None:
            raise ValueError("no entry computation found")
        # fused/called computations must not be double counted when reached
        # only via the entry walk — _memo handles sharing.
        return self._cost_of(name)


def analyze_hlo(text: str) -> Dict:
    hc = HloCost(text)
    c = hc.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.coll_bytes,
        "collectives": c.coll_detail,
        "bytes_by_op": dict(sorted(c.bytes_by_op.items(),
                                   key=lambda kv: -kv[1])),
        "warnings": hc.warnings,
    }
