"""Training driver: train an LM on a simulated IoT stream (end-to-end).

This is the SPS-as-training-job: POSD -> NSA -> PSDA producer -> StreamBatcher
-> fault-tolerant TrainLoop. On real hardware pass --arch <assigned-id>; on
CPU (this container) the default is the ~100M consumer LM from the paper
config, trainable for a few hundred steps in minutes.

Examples::

    PYTHONPATH=src python -m repro.launch.train --dataset userbehavior \
        --max-range 600 --steps 200 --inject-failure 120
"""

from __future__ import annotations

import argparse
import json
import threading
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_smoke
from repro.configs.paper_stream import consumer_lm
from repro.models import transformer
from repro.streamsim import (
    Producer,
    StreamQueue,
    VirtualClock,
    make_stream,
    nsa,
    preprocess,
)
from repro.training.checkpoint import CheckpointManager
from repro.training.data import StreamBatcher, SyntheticBatcher
from repro.training.ft import FailureInjector
from repro.training.optimizer import AdamW, adamw_init
from repro.training.steps import jit_train_step
from repro.training.train_loop import TrainLoop, TrainLoopConfig


def build_batches(args, vocab: int):
    if args.dataset == "synthetic":
        return iter(SyntheticBatcher(args.batch, args.seq, vocab)), None
    raw = make_stream(args.dataset, scale=args.scale, seed=args.seed)
    stream = nsa(preprocess(raw), args.max_range)
    queue = StreamQueue(maxsize=256)
    producer = Producer(stream, queue, clock=VirtualClock())
    th = threading.Thread(target=producer.run, daemon=True)
    th.start()
    batcher = StreamBatcher(queue, args.batch, args.seq, vocab)

    def forever():
        while True:  # re-produce the stream when exhausted (epochs)
            yield from batcher
            q2 = StreamQueue(maxsize=256)
            p2 = Producer(stream, q2, clock=VirtualClock())
            threading.Thread(target=p2.run, daemon=True).start()
            batcher.queue = q2

    return forever(), batcher


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assigned arch id (smoke config); default 100M LM")
    ap.add_argument("--dataset", default="userbehavior",
                    choices=["sogouq", "traffic", "userbehavior", "synthetic"])
    ap.add_argument("--max-range", type=int, default=600)
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a crash at this step (recovers from ckpt)")
    ap.add_argument("--out", default="results/train_metrics.json")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.arch else consumer_lm()
    cfg = cfg.replace(remat="none") if cfg.n_layers <= 12 else cfg
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model={cfg.name} params={n_params/1e6:.1f}M")

    opt = AdamW(lr=args.lr, total_steps=args.steps)
    opt_state = adamw_init(params)
    step_fn = jit_train_step(cfg, opt, mesh=None, donate=False)
    batches, batcher = build_batches(args, cfg.vocab_size)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    injector = None
    if args.inject_failure is not None:
        injector = FailureInjector({args.inject_failure: "process-death"})
    loop = TrainLoop(step_fn, params, opt_state, batches, ckpt,
                     TrainLoopConfig(total_steps=args.steps,
                                     checkpoint_every=args.ckpt_every),
                     injector=injector,
                     on_metrics=lambda s, m: (
                         print(f"step {s}: loss={m['loss']:.4f} "
                               f"wall={m['wall_s']*1e3:.0f}ms")
                         if s % 10 == 0 else None))
    summary = loop.run()
    if batcher is not None:
        summary["stream"] = {
            "buckets_consumed": batcher.buckets_consumed,
            "records_consumed": batcher.records_consumed,
        }
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"summary": summary, "history": loop.history[-50:]}, f,
                  indent=2)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
