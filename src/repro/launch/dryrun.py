import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must
succeed on the single-pod (16, 16) mesh AND the 2-pod (2, 16, 16) mesh for
every supported cell. The compiled artifact also supplies the roofline
inputs: ``cost_analysis()`` (HLO FLOPs / bytes), ``memory_analysis()``
(per-device footprint), and the post-SPMD HLO text (collective schedule).

Usage::

    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config, input_specs
from repro.launch.mesh import (
    HBM_PER_CHIP,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.launch.hlo_analysis import analyze_hlo
from repro.models import transformer
from repro.training.optimizer import AdamW
from repro.training.steps import jit_prefill_step, jit_serve_step, jit_train_step

# ---------------------------------------------------------------- HLO parse
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2, "c64": 8}


def xla_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returned a one-element list of dicts
    before jax 0.6 and a bare dict after; normalize to the dict (the single
    compat shim — tests import it too)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo: str):
    """Per-device bytes moved by collectives, from post-SPMD HLO text.

    Ring-algorithm accounting per participating device (g = group size):
      all-gather        out * (g-1)/g
      all-reduce        2 * out * (g-1)/g
      reduce-scatter    out * (g-1)          (input = out*g)
      all-to-all        out * (g-1)/g
      collective-permute out
    """
    per_op = {}
    total = 0.0
    for m in _COLL_RE.finditer(hlo):
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue  # avoid double counting start/done pairs
        out_b = _shape_bytes(type_str)
        line_end = hlo.find("\n", m.end())
        line = hlo[m.start():line_end]
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        if kind == "all-gather":
            b = out_b * (g - 1) / max(g, 1)
        elif kind == "all-reduce":
            b = 2 * out_b * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            b = out_b * (g - 1)
        elif kind == "all-to-all":
            b = out_b * (g - 1) / max(g, 1)
        else:  # collective-permute
            b = out_b
        rec = per_op.setdefault(kind, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += b
        total += b
    return per_op, total


# -------------------------------------------------------------- model flops
def model_flops(cfg, shape_name: str) -> float:
    """6·N_active·D for train; 2·N_active·B (+cache attention) for decode."""
    spec = SHAPES[shape_name]
    n_active = active_params(cfg)
    b, s = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        return 6.0 * n_active * b * s
    attn_per_tok = 0.0
    for kind in cfg.blocks():
        mixer = kind.split(":")[0]
        if mixer in ("attn", "local"):
            ctx = min(s, cfg.window) if (mixer == "local" and cfg.window) else s
            if cfg.mla:
                attn_per_tok += 2 * cfg.n_heads * ctx * (
                    2 * cfg.kv_lora_rank + cfg.qk_rope_dim)
            else:
                attn_per_tok += 4 * cfg.n_heads * cfg.head_dim_ * ctx
    if spec.kind == "prefill":
        # causal triangle: average context s/2
        return 2.0 * n_active * b * s + b * attn_per_tok * s / 2
    return b * (2.0 * n_active + attn_per_tok)


def active_params(cfg) -> float:
    n = cfg.n_params()
    if cfg.n_experts > 0:
        per_expert = 3 * cfg.d_model * cfg.d_ff_expert
        n_moe_layers = sum(1 for k in cfg.blocks() if k.endswith(":moe"))
        inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
        n -= inactive
    return float(n)


# ------------------------------------------------------------------ lowering
def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               overrides: dict | None = None):
    cfg = get_config(arch)
    shard_seq = False
    if overrides:
        overrides = dict(overrides)
        shard_seq = overrides.pop("shard_seq", False)
        if overrides:
            cfg = cfg.replace(**overrides)
    spec = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    specs = input_specs(cfg, shape_name)

    if spec.kind == "train":
        opt = AdamW()
        step = jit_train_step(cfg, opt, mesh, policy="fsdp_tp", donate=True,
                              shard_seq=shard_seq)
        pshape = transformer.param_specs(cfg)
        oshape = jax.eval_shape(lambda: {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.float32), pshape),
            "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.float32), pshape)})
        args = (pshape, oshape, specs["batch"])
    elif spec.kind == "prefill":
        step = jit_prefill_step(cfg, mesh)
        pshape = transformer.param_specs(cfg)
        args = (pshape, specs["inputs"], specs["lengths"])
    else:  # decode
        step = jit_serve_step(cfg, mesh, batch=spec.global_batch,
                              max_len=spec.seq_len, donate=True)
        pshape = transformer.param_specs(cfg)
        args = (pshape, specs["cache"], specs["tokens"])

    t0 = time.perf_counter()
    lowered = step.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    return cfg, mesh, lowered, compiled, t_lower, t_compile


def analyze(arch: str, shape_name: str, mesh_kind: str, cfg, mesh, lowered,
            compiled, t_lower, t_compile) -> dict:
    n_dev = int(np.prod(list(mesh.shape.values())))
    mem = compiled.memory_analysis()
    cost = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # loop-aware HLO analysis (cost_analysis undercounts while bodies);
    # the compiled module is the per-device SPMD program, so flops/bytes
    # are already per-device.
    hc = analyze_hlo(hlo)
    per_op, coll_bytes = hc["collectives"], hc["collective_bytes"]

    flops = float(hc["flops"])
    bytes_accessed = float(hc["bytes"])
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll_bytes / ICI_BW  # per-device bytes
    mf = model_flops(cfg, shape_name)

    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf_per_dev = model_flops(cfg, shape_name) / n_dev
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "n_devices": n_dev, "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_flops": flops, "hlo_bytes": bytes_accessed,
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "collective_bytes_per_device": coll_bytes,
        "collectives": per_op,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "hbm_fraction": per_dev_bytes / HBM_PER_CHIP,
        },
        "roofline": {
            **{k: v for k, v in terms.items()},
            "dominant": dominant,
            "step_time_lower_bound_s": max(terms.values()),
            "model_flops": mf,
            "model_flops_per_device": mf_per_dev,
            "useful_flops_ratio": mf_per_dev / flops if flops else 0.0,
            "roofline_fraction": (mf_per_dev / PEAK_FLOPS_BF16)
                                 / max(max(terms.values()), 1e-12),
        },
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None, verbose: bool = True) -> dict:
    try:
        out = analyze(arch, shape_name, mesh_kind,
                      *lower_cell(arch, shape_name, mesh_kind, overrides))
    except Exception as e:  # noqa: BLE001 — recorded, the driver decides
        out = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    if verbose:
        if out["ok"]:
            r = out["roofline"]
            print(f"[OK] {arch} × {shape_name} × {mesh_kind}: "
                  f"compile={out['compile_s']}s "
                  f"flops={out['hlo_flops']:.3e} "
                  f"mem/dev={out['memory']['per_device_bytes']/2**30:.2f}GiB "
                  f"dominant={r['dominant']} "
                  f"bound={r['step_time_lower_bound_s']:.4f}s")
        else:
            print(f"[FAIL] {arch} × {shape_name} × {mesh_kind}: "
                  f"{out['error']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf loop)")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    overrides = json.loads(args.override) if args.override else None
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            cfg = get_config(a)
            for s in SHAPES:
                if cell_supported(cfg, s):
                    cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for a, s in cells:
        for m in meshes:
            if not cell_supported(get_config(a), s):
                print(f"[SKIP] {a} × {s}: full-attention arch, long-context "
                      f"cell unsupported (DESIGN.md §Arch-applicability)")
                res = {"arch": a, "shape": s, "mesh": m, "ok": True,
                       "skipped": True,
                       "reason": "full attention: 500k decode needs "
                                 "sub-quadratic mixer"}
                fn = outdir / f"{args.tag}__{a}__{s}__{m}.json"
                with open(fn, "w") as f:
                    json.dump(res, f, indent=2)
                continue
            res = run_cell(a, s, m, overrides)
            fn = outdir / f"{args.tag}__{a}__{s}__{m}.json"
            with open(fn, "w") as f:
                json.dump(res, f, indent=2)
            n_fail += 0 if res["ok"] else 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
