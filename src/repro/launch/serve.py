"""Serving driver: batched inference under simulated IoT stream load.

The load test the paper's framework accelerates: request arrivals follow the
time-compressed real-world stream (volatility + trend preserved), so a
one-hour load test exercises a full day's arrival pattern (>=24x).

Example::

    PYTHONPATH=src python -m repro.launch.serve --dataset sogouq \
        --max-range 120 --scale 0.01 --slots 8
"""

from __future__ import annotations

import argparse
import json
import threading
from pathlib import Path

import jax

from repro.configs import get_smoke
from repro.configs.paper_stream import consumer_lm
from repro.models import transformer
from repro.serving.engine import ServingEngine
from repro.serving.load import stream_arrivals
from repro.streamsim import (
    Producer,
    StreamQueue,
    VirtualClock,
    make_stream,
    nsa,
    preprocess,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--dataset", default="sogouq",
                    choices=["sogouq", "traffic", "userbehavior"])
    ap.add_argument("--max-range", type=int, default=120)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-requests-per-bucket", type=int, default=4)
    ap.add_argument("--out", default="results/serve_metrics.json")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.arch else consumer_lm()
    if cfg.input_mode != "tokens":
        raise SystemExit("serve driver demos token archs; embedding-input "
                         "archs are exercised via the dry-run")
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_len=args.max_len)

    raw = make_stream(args.dataset, scale=args.scale, seed=args.seed)
    stream = nsa(preprocess(raw), args.max_range)
    queue = StreamQueue(maxsize=64)
    producer = Producer(stream, queue, clock=VirtualClock())
    threading.Thread(target=producer.run, daemon=True).start()

    arrivals = 0
    last_ss = 0
    for ss, reqs in stream_arrivals(
            queue, cfg.vocab_size, prompt_len=args.prompt_len,
            max_new_tokens=args.new_tokens,
            max_requests_per_bucket=args.max_requests_per_bucket):
        last_ss = ss
        for r in reqs:
            engine.submit(r)
            arrivals += 1
        # one simulated second = a few decode ticks (engine keeps batching);
        # the engine runs on the same virtual clock as the producer
        for i in range(4):  # producer clock reads ss+1 at emission
            engine.tick(now=float(ss) + 1.0 + i * 0.25)
    engine.drain(now=float(last_ss) + 2.0, tick_s=0.25)

    summary = {"arrivals": arrivals, **engine.metrics.summary()}
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=2)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
