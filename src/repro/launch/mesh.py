"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the first
jax initialization.

Production target: TPU v5e pods, 256 chips each (16x16), 2 pods = 512 chips
for the multi-pod dry-run. Axes: 'pod' (cross-pod DP), 'data' (DP + FSDP),
'model' (TP + EP + seq-sharded decode).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline terms — see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_PER_CHIP = 16 * 1024**3   # 16 GiB


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
