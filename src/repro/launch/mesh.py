"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the first
jax initialization.

Production target: TPU v5e pods, 256 chips each (16x16), 2 pods = 512 chips
for the multi-pod dry-run. Axes: 'pod' (cross-pod DP), 'data' (DP + FSDP),
'model' (TP + EP + seq-sharded decode).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline terms — see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_PER_CHIP = 16 * 1024**3   # 16 GiB


def _axis_types_kwargs(n: int) -> dict:
    """``axis_types`` only exists from jax 0.5 (explicit-sharding API); on
    older versions every mesh axis is implicitly Auto, so omitting the
    kwarg is semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_types_kwargs(2))
