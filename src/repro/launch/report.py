"""Assemble EXPERIMENTS.md tables from results/dryrun/*.json."""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ARCH_ORDER = [
    "recurrentgemma-2b", "qwen3-32b", "qwen1_5-110b", "llama3-8b",
    "command-r-plus-104b", "rwkv6-1_6b", "deepseek-v3-671b",
    "llama4-scout-17b-a16e", "musicgen-medium", "llava-next-34b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.2f}"
    return f"{x:.4f}"


def load(outdir: Path, tag: str):
    recs = {}
    for p in sorted(outdir.glob(f"{tag}__*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def roofline_table(recs, mesh="single") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
            "| bytes/dev GiB | useful FLOPs ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                continue
            if r.get("skipped"):
                rows.append(f"| {a} | {s} | — | — | — | skipped "
                            f"(full attention @500k) | — | — | — |")
                continue
            rl = r["roofline"]
            rows.append(
                f"| {a} | {s} | {fmt_s(rl['compute_s'])} "
                f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
                f"| {rl['dominant'].replace('_s','')} "
                f"| {r['memory']['per_device_bytes']/2**30:.2f} "
                f"| {rl['useful_flops_ratio']:.3f} "
                f"| {rl['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | compile_s | HLO flops/dev | bytes/dev "
            "| collective GB/dev | collective mix |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                r = recs.get((a, s, m))
                if r is None or r.get("skipped"):
                    continue
                mix = ",".join(f"{k.replace('all-','a').replace('reduce-','r')}"
                               f"×{v['count']}"
                               for k, v in sorted(r["collectives"].items()))
                rows.append(
                    f"| {a} | {s} | {m} | {r['compile_s']:.0f} "
                    f"| {r['hlo_flops']:.2e} | {r['hlo_bytes']:.2e} "
                    f"| {r['collective_bytes_per_device']/1e9:.2f} | {mix} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--table", choices=["roofline", "dryrun"],
                    default="roofline")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(Path(args.out), args.tag)
    if args.table == "roofline":
        print(roofline_table(recs, args.mesh))
    else:
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
