"""Fault tolerance: failure injection, straggler mitigation, elastic re-mesh.

On a real pod these events come from the runtime (preemptions, ICI link
flaps, slow hosts); this module provides the *control-plane logic* plus
simulators so the behaviour is testable on CPU:

- :class:`FailureInjector` raises a ``SimulatedFailure`` at chosen steps
  (process death / NaN grad / device loss);
- :class:`StragglerMonitor` watches per-step wall time against a rolling
  deadline and records mitigation decisions (the action on TPU would be to
  re-issue the step's data shard to a healthy host — here we account for it
  and continue, which is what a synchronous SPMD job does after the
  collective timeout reassigns membership);
- :func:`elastic_plan` computes the new mesh + batch sharding when the
  world shrinks/grows, and the train loop restores the latest checkpoint
  onto it (checkpoints are mesh-agnostic — see checkpoint.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


class SimulatedFailure(RuntimeError):
    def __init__(self, kind: str, step: int):
        super().__init__(f"simulated {kind} at step {step}")
        self.kind = kind
        self.step = step


@dataclasses.dataclass
class FailureInjector:
    """Raise ``SimulatedFailure`` when the loop reaches the given steps."""

    failures: Dict[int, str] = dataclasses.field(default_factory=dict)
    fired: List[int] = dataclasses.field(default_factory=list)

    def check(self, step: int) -> None:
        if step in self.failures and step not in self.fired:
            self.fired.append(step)
            raise SimulatedFailure(self.failures[step], step)


@dataclasses.dataclass
class StragglerMonitor:
    """Deadline-based straggler detection over step wall times.

    deadline = median(recent) * tolerance; a step exceeding it is recorded
    as mitigated (on hardware: reissue / drop the slow host's microbatch).
    """

    tolerance: float = 3.0
    window: int = 20
    history: List[float] = dataclasses.field(default_factory=list)
    mitigated_steps: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, wall_s: float) -> bool:
        hist = self.history[-self.window:]
        slow = False
        if len(hist) >= 5:
            med = sorted(hist)[len(hist) // 2]
            slow = wall_s > self.tolerance * med
            if slow:
                self.mitigated_steps.append(step)
        self.history.append(wall_s)
        return slow

    def summary(self) -> Dict:
        return {
            "steps": len(self.history),
            "mitigated": len(self.mitigated_steps),
            "median_s": (sorted(self.history)[len(self.history) // 2]
                         if self.history else 0.0),
        }


def elastic_plan(n_healthy: int, mesh_shape: Sequence[int],
                 axis_names: Sequence[str],
                 global_batch: int) -> Tuple[Tuple[int, ...], int]:
    """Given a shrunk/grown healthy-chip count, pick the new mesh shape.

    Policy: keep the 'model' axis intact (TP degree is set by memory), and
    shrink the data axis to the largest value that divides both the healthy
    count / model size and the global batch. Returns (new_shape,
    per_shard_batch). Raises if even data=1 doesn't fit.
    """
    names = list(axis_names)
    shape = list(mesh_shape)
    model = shape[names.index("model")] if "model" in names else 1
    if n_healthy < model:
        raise ValueError(
            f"{n_healthy} chips cannot host model axis of {model}")
    avail = n_healthy // model
    data = 1
    for cand in range(avail, 0, -1):
        if global_batch % cand == 0:
            data = cand
            break
    new_shape = []
    for n, s in zip(names, shape):
        if n == "model":
            new_shape.append(model)
        elif n == "data":
            new_shape.append(data)
        else:  # pod axis folds into data on shrink
            new_shape.append(1)
    return tuple(new_shape), global_batch // data
