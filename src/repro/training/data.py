"""Data plane: the bridge from simulated IoT streams to token batches.

This is where the paper's pipeline plugs into the SPS-as-training-job: the
PSDA producer emits per-second buckets into the StreamQueue; the
:class:`StreamBatcher` consumes buckets, tokenizes records, and yields fixed
(B, S) batches. Arrival volatility therefore directly shapes the batch
cadence — which is the load pattern the paper wants tests to see.

Tokenization of records is deliberately simple and vocabulary-stable:
column values hash into the LM vocab (a production system would plug a real
tokenizer here; the framework only needs id streams).
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.streamsim.queue import Bucket, StreamQueue


def tokenize_bucket(bucket: Bucket, vocab: int,
                    tokens_per_record: int = 8) -> np.ndarray:
    """Hash each record's fields into `tokens_per_record` ids < vocab."""
    n = len(bucket)
    cols = [np.asarray(v) for v in bucket.payload.values()]
    acc = np.zeros((n, tokens_per_record), dtype=np.uint64)
    for ci, col in enumerate(cols):
        if col.dtype.kind in "US":
            h = np.array([hash(x) & 0xFFFFFFFF for x in col], np.uint64)
        else:
            h = col.astype(np.float64).view(np.uint64) if col.dtype.kind == "f" \
                else col.astype(np.uint64)
        for j in range(tokens_per_record):
            acc[:, j] ^= (h * np.uint64(0x9E3779B97F4A7C15 + 31 * (ci + 1)
                                        + 7 * j)) >> np.uint64(17)
    ts = (bucket.t * 1000).astype(np.uint64)
    acc ^= ts[:, None]
    return (acc % np.uint64(max(vocab - 2, 1)) + np.uint64(1)).astype(np.int32)


class StreamBatcher:
    """Pull buckets from the queue, emit {'inputs','labels'} LM batches."""

    def __init__(self, queue: StreamQueue, batch: int, seq: int, vocab: int,
                 tokens_per_record: int = 8):
        self.queue = queue
        self.batch = batch
        self.seq = seq
        self.vocab = vocab
        self.tpr = tokens_per_record
        self._buf = np.zeros((0,), np.int32)
        self.buckets_consumed = 0
        self.records_consumed = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        need = self.batch * (self.seq + 1)
        for bucket in self.queue:
            ids = tokenize_bucket(bucket, self.vocab, self.tpr).reshape(-1)
            self._buf = np.concatenate([self._buf, ids])
            self.buckets_consumed += 1
            self.records_consumed += len(bucket)
            while len(self._buf) >= need:
                chunk, self._buf = self._buf[:need], self._buf[need:]
                chunk = chunk.reshape(self.batch, self.seq + 1)
                yield {"inputs": chunk[:, :-1], "labels": chunk[:, 1:]}


class SyntheticBatcher:
    """Deterministic fallback batcher (tests / benchmarks without a stream)."""

    def __init__(self, batch: int, seq: int, vocab: int, seed: int = 0):
        self.batch, self.seq, self.vocab = batch, seq, vocab
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            chunk = self.rng.integers(
                1, self.vocab, (self.batch, self.seq + 1), dtype=np.int32)
            yield {"inputs": chunk[:, :-1], "labels": chunk[:, 1:]}
