"""Training substrate: optimizer, step builders, checkpointing, fault
tolerance, and the stream-fed training loop."""

from repro.training.optimizer import AdamW, adamw_init, adamw_update  # noqa: F401
from repro.training.steps import make_train_step, make_serve_step  # noqa: F401
from repro.training.checkpoint import CheckpointManager  # noqa: F401
from repro.training.train_loop import TrainLoop, TrainLoopConfig  # noqa: F401
from repro.training import ft  # noqa: F401
