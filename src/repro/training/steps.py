"""Step builders: the jit-compiled train / prefill / serve steps with their
sharding contracts. These are what the dry-run lowers and what the train
loop / serving engine execute.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.api import sharding_rules
from repro.distributed.sharding import (
    activation_rules,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
)
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamW, adamw_update


def make_train_step(cfg: ModelConfig, opt: AdamW):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True
        )(params)
        params, opt_state, stats = adamw_update(opt, grads, opt_state, params)
        metrics = dict(metrics, **stats)
        return params, opt_state, metrics

    return train_step


def make_forward_step(cfg: ModelConfig):
    """Inference forward (prefill shape): returns last-position logits."""

    def prefill_step(params, inputs, lengths):
        logits, cache = transformer.prefill(cfg, params, inputs, lengths,
                                            max_len=inputs.shape[1])
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode tick: (params, cache, tokens) -> (logits, cache).
    The cache is donated at jit time (in-place update)."""

    def serve_step(params, cache, tokens):
        return transformer.decode_step(cfg, params, cache, tokens)

    return serve_step


# ------------------------------------------------------------------ jitted
def jit_train_step(cfg: ModelConfig, opt: AdamW, mesh: Optional[Mesh] = None,
                   policy: str = "fsdp_tp", donate: bool = True,
                   shard_seq: bool = False):
    """Sharded jit of the train step against a mesh (or plain jit if None)."""
    step = make_train_step(cfg, opt)
    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    pshape = transformer.param_specs(cfg)
    pspec = param_pspecs(cfg, mesh, pshape, policy)
    oshape = jax.eval_shape(
        lambda: {"step": jnp.zeros((), jnp.int32),
                 "m": jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                   pshape),
                 "v": jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                   pshape)})
    ospec = {"step": P(), "m": pspec, "v": pspec}
    bp = batch_pspec(mesh)
    bspec = {"inputs": bp["tokens"] if cfg.input_mode == "tokens"
             else bp["embeds"],
             "labels": bp["labels"]}
    ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    rules = activation_rules(mesh, shard_seq=shard_seq)

    def wrapped(params, opt_state, batch):
        with sharding_rules(mesh, rules):
            return step(params, opt_state, batch)

    return jax.jit(
        wrapped,
        in_shardings=(ns(pspec), ns(ospec), ns(bspec)),
        out_shardings=(ns(pspec), ns(ospec), None),
        donate_argnums=(0, 1) if donate else (),
    )


def jit_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                   batch: int = 1, max_len: int = 0,
                   shard_seq: bool = True, donate: bool = True):
    step = make_serve_step(cfg)
    if mesh is None:
        return jax.jit(step, donate_argnums=(1,) if donate else ())
    pshape = transformer.param_specs(cfg)
    pspec = param_pspecs(cfg, mesh, pshape, "tp")
    cshape = jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, max_len))
    cspec = cache_pspecs(cfg, mesh, cshape, shard_seq=shard_seq)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    dp = dp if batch % _size(mesh, dp) == 0 else None
    tspec = P(dp) if cfg.input_mode == "tokens" else P(dp, None)
    ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    rules = activation_rules(mesh)

    def wrapped(params, cache, tokens):
        with sharding_rules(mesh, rules):
            return step(params, cache, tokens)

    return jax.jit(
        wrapped,
        in_shardings=(ns(pspec), ns(cspec), ns(tspec)),
        out_shardings=(None, ns(cspec)),
        donate_argnums=(1,) if donate else (),
    )


def jit_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None):
    step = make_forward_step(cfg)
    if mesh is None:
        return jax.jit(step)
    pshape = transformer.param_specs(cfg)
    pspec = param_pspecs(cfg, mesh, pshape, "tp")
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    ispec = P(dp, None) if cfg.input_mode == "tokens" else P(dp, None, None)
    ns = lambda spec: jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                   is_leaf=lambda x: isinstance(x, P))
    rules = activation_rules(mesh)

    def wrapped(params, inputs, lengths):
        with sharding_rules(mesh, rules):
            return step(params, inputs, lengths)

    return jax.jit(wrapped,
                   in_shardings=(ns(pspec), ns(ispec), ns(P(dp))),
                   out_shardings=None)


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes]))
