"""The fault-tolerant training loop.

Responsibilities (each individually testable):
- consume batches from any iterator (StreamBatcher / SyntheticBatcher);
- run the jitted train step;
- checkpoint every N steps (async), restart from the latest checkpoint on
  failure (including injected ones), with bounded retries;
- straggler accounting via :class:`StragglerMonitor`;
- NaN-loss quarantine: a non-finite loss skips the update (batch discarded)
  rather than poisoning the run — combined with restore-on-repeat.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.training.checkpoint import CheckpointManager
from repro.training.ft import FailureInjector, SimulatedFailure, StragglerMonitor


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    max_restarts: int = 3
    async_checkpoint: bool = True
    log_every: int = 10


class TrainLoop:
    def __init__(self, step_fn: Callable, params: Any, opt_state: Any,
                 batches: Iterator[Dict], ckpt: CheckpointManager,
                 cfg: TrainLoopConfig,
                 injector: Optional[FailureInjector] = None,
                 on_metrics: Optional[Callable[[int, Dict], None]] = None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.batches = iter(batches)
        self.ckpt = ckpt
        self.cfg = cfg
        self.injector = injector
        self.on_metrics = on_metrics
        self.straggler = StragglerMonitor()
        self.history: List[Dict] = []
        self.restarts = 0
        self.step = 0
        self.skipped_nan = 0

    # ------------------------------------------------------------- running
    def run(self) -> Dict:
        while self.step < self.cfg.total_steps:
            try:
                self._run_segment()
            except SimulatedFailure as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                self._restore()
        self.ckpt.wait()
        self._save()  # final
        return self.summary()

    def _run_segment(self) -> None:
        while self.step < self.cfg.total_steps:
            if self.injector is not None:
                self.injector.check(self.step)
            batch = next(self.batches)
            t0 = time.perf_counter()
            new_params, new_opt, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            wall = time.perf_counter() - t0
            if not np.isfinite(loss):
                # quarantine: drop update, keep old state
                self.skipped_nan += 1
                del new_params, new_opt
            else:
                self.params, self.opt_state = new_params, new_opt
            self.straggler.observe(self.step, wall)
            rec = {"step": self.step, "loss": loss, "wall_s": wall}
            self.history.append(rec)
            if self.on_metrics is not None:
                self.on_metrics(self.step, {**rec, **{
                    k: float(jax.device_get(v)) for k, v in metrics.items()
                    if k != "loss"}})
            self.step += 1
            if self.step % self.cfg.checkpoint_every == 0:
                self._save()

    # ------------------------------------------------------------- ckpting
    def _state(self) -> Dict:
        return {"params": self.params, "opt": self.opt_state}

    def _save(self) -> None:
        self.ckpt.save(self.step, self._state(),
                       extra={"restarts": self.restarts},
                       blocking=not self.cfg.async_checkpoint)

    def _restore(self) -> None:
        self.ckpt.wait()
        latest = self.ckpt.latest_step()
        if latest is None:
            self.step = 0  # restart from scratch
            return
        state = self.ckpt.restore(self._state(), step=latest)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = latest

    def summary(self) -> Dict:
        return {
            "final_step": self.step,
            "restarts": self.restarts,
            "skipped_nan": self.skipped_nan,
            "final_loss": self.history[-1]["loss"] if self.history else None,
            "straggler": self.straggler.summary(),
        }
