"""AdamW from scratch (no optax): f32 moments, global-norm clipping,
decoupled weight decay, linear-warmup + cosine schedule.

Moment tensors inherit the parameter sharding (the pspec tree is reused for
them by the launcher), so FSDP shards optimizer state exactly like ZeRO.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params: Any) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def _schedule(cfg: AdamW, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamW, grads: Any, state: Dict, params: Any
                 ) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "m": new_m, "v": new_v}, stats
