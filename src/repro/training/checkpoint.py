"""Sharded checkpointing with atomic commits and async writes (orbax-free).

Layout::

    <root>/step_<N>/
        arrays.npz           flattened pytree leaves, path-keyed
        manifest.json        step, tree structure, shapes/dtypes, status

Guarantees:
- atomic: a checkpoint directory appears only after a full write
  (tmp dir + ``os.replace``); a crash mid-write leaves no partial step.
- restorable onto a *different* mesh: leaves are saved unsharded (gathered),
  restore re-shards against whatever sharding the caller supplies — this is
  what makes elastic re-scaling (ft.py) work.
- async: ``save(..., blocking=False)`` hands the gathered host arrays to a
  writer thread; training continues while the previous step serializes.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, root: str | os.PathLike, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._writer: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ io
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        flat = _flatten(tree)  # gathers to host
        meta = {
            "step": step,
            "saved_at": time.time(),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "extra": extra or {},
        }
        self.wait()
        if blocking:
            self._write(step, flat, meta)
        else:
            self._writer = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._writer.start()

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], meta: Dict):
        tmp = Path(tempfile.mkdtemp(dir=self.root, prefix=".tmp_"))
        try:
            np.savez(tmp / "arrays.npz", **flat)
            with open(tmp / "manifest.json", "w") as f:
                json.dump(meta, f, indent=2)
            final = self.root / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------------- read
    def steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir():
            if p.name.startswith("step_") and (p / "manifest.json").exists():
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: optional matching pytree of
        NamedSharding to place leaves directly (elastic re-mesh path)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        with np.load(d / "arrays.npz", allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files}
        paths = jax.tree_util.tree_flatten_with_path(like)[0]
        tdef = jax.tree.structure(like)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, leaf), sh in zip(paths, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        return jax.tree.unflatten(tdef, leaves)

    def manifest(self, step: int) -> Dict:
        with open(self.root / f"step_{step:08d}" / "manifest.json") as f:
            return json.load(f)
