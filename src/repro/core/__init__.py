"""repro.core — public API facade for the paper's contribution.

One-call entry point (:func:`simulate_stream`) plus re-exports of the
pipeline stages so applications compose them directly::

    from repro.core import simulate_stream
    sim = simulate_stream("userbehavior", max_range=600)

maps to the paper's Fig. 4: POSD -> NSSD -> (PSD -> SPS via
``repro.streamsim.Producer`` / ``repro.serving`` / ``repro.training``).
"""

from __future__ import annotations

from repro.streamsim import (  # noqa: F401
    Controller,
    Producer,
    RealClock,
    SimulationReport,
    Stream,
    StreamQueue,
    StreamStore,
    VirtualClock,
    make_stream,
    nsa,
    nsa_paper,
    per_second_counts,
    preprocess,
    volatility,
)


def simulate_stream(dataset: str, max_range: int, *, scale: float = 1.0,
                    seed: int = 0) -> Stream:
    """POSD + NSA in one call (no persistence). For the persistent,
    metrics-collecting path use :class:`repro.streamsim.Controller`."""
    raw = make_stream(dataset, scale=scale, seed=seed)
    stream = preprocess(raw)
    return nsa(stream, max_range)


__all__ = [
    "Controller", "Producer", "RealClock", "SimulationReport", "Stream",
    "StreamQueue", "StreamStore", "VirtualClock", "make_stream", "nsa",
    "nsa_paper", "per_second_counts", "preprocess", "simulate_stream",
    "volatility",
]
