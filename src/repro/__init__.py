"""repro — production-grade JAX framework reproducing and extending
"A Framework for Simulating Real-world Stream Data of the Internet of Things"
(Chu, Du, Yu — Journal of Computers, 2022).

Layers
------
- ``repro.streamsim``  : the paper's contribution — IoT stream time-compression
  (POSD preprocessing, NSA normalize+sample, PSDA producer, controller).
- ``repro.core``       : public API facade over the pipeline.
- ``repro.kernels``    : Pallas TPU kernels for the pipeline's compute hot-spots.
- ``repro.models``     : the 10 assigned transformer/SSM/MoE architectures.
- ``repro.distributed``: mesh + sharding rules (DP/FSDP/TP/EP/SP).
- ``repro.training``   : optimizer, train loop, checkpointing, fault tolerance.
- ``repro.serving``    : KV-cache engine driven by simulated stream load.
- ``repro.launch``     : production mesh, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"
