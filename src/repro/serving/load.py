"""Stream-driven load generation: the paper's pipeline as a serving load test.

Each per-second bucket emitted by the PSDA producer becomes a burst of
inference requests (one per stream record, prompts tokenized from the
record's fields). The arrival process the engine sees therefore has the
*original* stream's per-second volatility and diurnal trend, compressed
``original_range / max_range``-fold in wall time — the paper's ≥24×
load-test acceleration, applied to model serving.
"""

from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np

from repro.serving.engine import Request
from repro.streamsim.queue import StreamQueue
from repro.training.data import tokenize_bucket


def stream_arrivals(queue: StreamQueue, vocab: int, *,
                    prompt_len: int = 16, max_new_tokens: int = 8,
                    max_requests_per_bucket: int = 64
                    ) -> Iterator[Tuple[int, List[Request]]]:
    """Yield (scale_stamp, requests) per bucket from the producer queue."""
    rid = 0
    for bucket in queue:
        ids = tokenize_bucket(bucket, vocab, tokens_per_record=prompt_len)
        n = min(len(bucket), max_requests_per_bucket)
        reqs = []
        for i in range(n):
            reqs.append(Request(
                rid=rid,
                prompt=ids[i].astype(np.int32),
                max_new_tokens=max_new_tokens,
                arrive_t=float(bucket.emit_time),
            ))
            rid += 1
        yield bucket.scale_stamp, reqs
