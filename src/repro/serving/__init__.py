"""Serving substrate: batched prefill/decode engine whose request arrivals
are driven by the simulated IoT stream (the paper's load-testing scenario).
"""

from repro.serving.engine import ServingEngine, Request, ServeMetrics  # noqa: F401
from repro.serving.load import stream_arrivals  # noqa: F401
