"""Batched serving engine: continuous batching over prefill + decode.

The engine owns a fixed-capacity decode batch (slots). Each tick:
1. admit waiting requests into free slots (prefill builds their cache
   entries — batched per tick),
2. run one decode step for all active slots,
3. retire sequences that hit EOS / max tokens, recording latencies.

Under the paper's scenario the request queue is fed by
:func:`repro.serving.load.stream_arrivals`, so the engine experiences the
*compressed real-world* arrival process — volatility and trend included —
which is exactly the load test the paper accelerates.

Implementation notes: slots × (max_len) KV cache lives donated inside the
jitted serve step; prefill is per-request (padded to the slot's prompt
bucket) and merges its cache into the slot axis with a scatter.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32 token ids
    max_new_tokens: int = 16
    arrive_t: float = 0.0
    start_t: float = 0.0
    finish_t: float = 0.0
    generated: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeMetrics:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    queue_peak: int = 0
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    ttft_s: List[float] = dataclasses.field(default_factory=list)

    def summary(self) -> Dict:
        lat = sorted(self.latencies_s)
        return {
            "finished": self.finished,
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
            "p50_latency_s": lat[len(lat) // 2] if lat else 0.0,
            "p99_latency_s": lat[int(len(lat) * 0.99)] if lat else 0.0,
            "queue_peak": self.queue_peak,
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 8,
                 max_len: int = 256, eos_id: int = 0, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.cache = transformer.init_cache(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.waiting: List[Request] = []
        self.metrics = ServeMetrics()
        self._decode = jax.jit(
            lambda p, c, t: transformer.decode_step(cfg, p, c, t),
            donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, toks, lens: transformer.prefill(
                cfg, p, toks, lens, max_len=max_len),
            static_argnames=())
        self._last_tokens = np.zeros((slots,), np.int32)

    # ----------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        self.waiting.append(req)
        self.metrics.queue_peak = max(self.metrics.queue_peak,
                                      len(self.waiting))

    def _admit(self, now: float) -> None:
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free or not self.waiting:
            return
        batch = []
        while free and self.waiting:
            batch.append((free.pop(0), self.waiting.pop(0)))
        maxp = max(len(r.prompt) for _, r in batch)
        maxp = max(maxp, 1)
        toks = np.zeros((len(batch), maxp), np.int32)
        lens = np.zeros((len(batch),), np.int32)
        for j, (_, r) in enumerate(batch):
            toks[j, :len(r.prompt)] = r.prompt
            lens[j] = len(r.prompt)
        logits, pcache = self._prefill(self.params, jnp.asarray(toks),
                                       jnp.asarray(lens))
        first = np.asarray(jnp.argmax(logits, -1), np.int32)
        # merge each prefilled sequence into its slot
        self.cache = _merge_cache(self.cache, pcache,
                                  [slot for slot, _ in batch])
        for j, (slot, r) in enumerate(batch):
            r.start_t = now
            r.generated = [int(first[j])]
            self.active[slot] = r
            self._last_tokens[slot] = first[j]
            self.metrics.admitted += 1
            self.metrics.ttft_s.append(now - r.arrive_t)
            self.metrics.tokens_out += 1

    # --------------------------------------------------------------- ticks
    def tick(self, now: Optional[float] = None) -> int:
        """Admit + one decode step. Returns number of active sequences."""
        now = time.perf_counter() if now is None else now
        self._admit(now)
        if not any(r is not None for r in self.active):
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self._last_tokens))
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.metrics.decode_steps += 1
        n_active = 0
        for slot, r in enumerate(self.active):
            if r is None:
                continue
            tok = int(nxt[slot])
            r.generated.append(tok)
            self._last_tokens[slot] = tok
            self.metrics.tokens_out += 1
            done = (tok == self.eos_id
                    or len(r.generated) >= r.max_new_tokens
                    or len(r.prompt) + len(r.generated) >= self.max_len - 1)
            if done:
                r.finish_t = now
                self.metrics.latencies_s.append(now - r.arrive_t)
                self.metrics.finished += 1
                self.active[slot] = None
            else:
                n_active += 1
        return n_active

    def drain(self, max_ticks: int = 10_000, now: Optional[float] = None,
              tick_s: float = 0.0) -> None:
        """Run until idle. Pass ``now``/``tick_s`` to stay on a virtual
        clock (stream-driven load tests); default uses wall time."""
        t = 0
        while (self.waiting or any(r is not None for r in self.active)) \
                and t < max_ticks:
            self.tick(now if now is None else now + t * tick_s)
            t += 1


def _merge_cache(cache: Any, pcache: Any, slots: List[int]) -> Any:
    """Scatter prefilled cache rows (batch axis) into the engine cache slots.

    Leaves are (R, B, ...) for layer caches and (B,) for pos."""
    idx = jnp.asarray(slots, jnp.int32)

    def merge(c, p):
        if c.ndim == 1:                      # pos (B,)
            return c.at[idx].set(p.astype(c.dtype))
        # (R, B, ...): prefill cache may have shorter seq axis; pad to match
        if p.shape[2:] != c.shape[2:]:
            pads = [(0, 0)] * p.ndim
            for ax in range(2, p.ndim):
                pads[ax] = (0, c.shape[ax] - p.shape[ax])
            p = jnp.pad(p, pads)
        return c.at[:, idx].set(p.astype(c.dtype))

    return jax.tree.map(merge, cache, pcache)
