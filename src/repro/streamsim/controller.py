"""Controller — the user-side core component (paper §4).

The paper's controller has three functions, mirrored 1:1 here:
  (1) control the producer to load + simulate a user-defined time range;
  (2) collect physical/workload metrics of the stream processing system;
  (3) manage metrics of different stream data for viewing.

The paper collects metrics over the SPS's REST API into a "metrics
repository"; here the consumers (training/serving loops) expose a metrics
callback and the repository is a JSON directory.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.streamsim.datasets import make_stream
from repro.streamsim.metrics import (StreamMetrics, Volatility,
                                     metrics_batched,
                                     trend_correlation_from_counts,
                                     trend_correlation_matrix)
from repro.streamsim.nsa import compression_factor, nsa, nsa_sweep
from repro.streamsim.preprocess import Stream, preprocess
from repro.streamsim.producer import (MultiQueueProducer, Producer,
                                      VirtualClock)
from repro.streamsim.queue import QueueGroup, StreamQueue
from repro.streamsim.store import StreamStore


@dataclasses.dataclass
class SimulationReport:
    dataset: str
    max_range: int
    original_rows: int
    simulated_rows: int
    compression: float
    original_volatility: Volatility
    simulated_volatility: Volatility
    trend_corr: float
    preprocess_s: float
    nsa_s: float
    produce_s: float
    consumer_metrics: Dict

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        return d


@dataclasses.dataclass
class FidelityReport:
    """One sweep's Fig.-6 fidelity artifact from :meth:`Controller.run_many`.

    ``trend_corr`` is the full S×S trend-correlation matrix over the
    sweep's streams — every dataset's original stream followed by every
    dataset's simulated stream at ``max_range`` — computed by
    :func:`repro.streamsim.metrics.trend_correlation_matrix` from ONE
    batched dispatch (on the pallas backend the whole counts → trend →
    correlation chain stays on device). ``labels[i]`` names row/column
    ``i`` (``"<dataset>/original"`` or ``"<dataset>/sim<max_range>"``).

    Matrix entries for empty / zero-variance streams are NaN in memory and
    serialize to ``null`` in :meth:`to_json` (bare ``NaN`` tokens are not
    valid JSON and would break non-Python consumers of the artifact).
    """

    max_range: int
    window_s: int
    labels: List[str]
    trend_corr: List[List[float]]

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["trend_corr"] = [[None if v != v else v for v in row]
                           for row in self.trend_corr]
        return d


class Controller:
    def __init__(self, store_dir: str, metrics_dir: Optional[str] = None):
        self.store = StreamStore(store_dir)
        self.metrics_dir = Path(metrics_dir or (Path(store_dir) / "_metrics"))
        self.metrics_dir.mkdir(parents=True, exist_ok=True)
        self.fidelity_dir = self.metrics_dir / "fidelity"
        self._metrics_seq = itertools.count()
        #: the per-sweep S×S fidelity matrices from the latest
        #: :meth:`run_many` call (also persisted under ``fidelity_dir``)
        self.last_fidelity: List[FidelityReport] = []

    # ----------------------------------------------------- (1) simulate/run
    def prepare(self, dataset: str, *, scale: float = 1.0, seed: int = 0,
                force: bool = False) -> Stream:
        """POSD once, persist (preprocessing is a one-time job — paper §3.1)."""
        key = f"{dataset}__orig"
        if self.store.exists(key) and not force:
            return self.store.get(key)
        raw = make_stream(dataset, scale=scale, seed=seed)
        stream = preprocess(raw)
        self.store.put(key, stream, {"scale": scale, "seed": seed})
        return stream

    def simulate(self, dataset: str, max_range: int, *, scale: float = 1.0,
                 seed: int = 0, force: bool = False,
                 backend: str = "auto") -> Stream:
        """NSA once per (dataset, max_range), persist (paper §3.2: stored
        'because repeated normalizing and sampling operations are not
        performed').

        ``backend`` selects the NSA implementation ("auto" picks the
        device-resident Pallas path on TPU, numpy otherwise — see
        :mod:`repro.streamsim.nsa`); every backend is bit-identical, so the
        store cache is backend-agnostic.
        """
        # timing always reflects THIS call: 0.0 on a store-cache hit
        self._last_nsa_s = 0.0
        key = f"{dataset}__sim{max_range}"
        if self.store.exists(key) and not force:
            return self.store.get(key)
        original = self.prepare(dataset, scale=scale, seed=seed, force=force)
        t0 = time.perf_counter()
        sim = nsa(original, max_range, backend=backend)
        self._last_nsa_s = time.perf_counter() - t0
        self.store.put(key, sim, {"max_range": max_range})
        return sim

    def _produce_consume(self, sim: Stream,
                         consumer: Callable[[StreamQueue], Dict],
                         queue_size: int):
        """PSDA leg shared by :meth:`run` and :meth:`run_many`: producer
        fills, consumer drains (bounded queue means we interleave: run the
        producer in a thread to honour backpressure)."""
        queue = StreamQueue(maxsize=queue_size)
        producer = Producer(sim, queue, clock=VirtualClock())
        t0 = time.perf_counter()
        status = [None]

        def _produce():
            status[0] = producer.run()

        th = threading.Thread(target=_produce, daemon=True)
        th.start()
        consumer_metrics = consumer(queue)
        th.join()
        t_prod = time.perf_counter() - t0
        if status[0] != 0:
            raise RuntimeError("producer reported fault status")
        return ({**consumer_metrics, **queue.stats(), **producer.stats()},
                t_prod)

    def _produce_consume_many(self, sims: Dict, consumer, queue_size: int):
        """Batched PSDA leg of :meth:`run_many`: ONE
        :class:`~repro.streamsim.producer.MultiQueueProducer` virtual-time
        loop interleaves every scenario's buckets; each scenario's consumer
        drains its own bounded queue in its own thread (shared backpressure
        makes concurrent drains mandatory — a full sibling queue stalls the
        whole loop). Returns ``({scenario: merged stats}, shared wall
        time)`` with per-scenario stats equivalent to sequential
        :meth:`_produce_consume` calls."""
        group = QueueGroup(sims, maxsize=queue_size)
        producer = MultiQueueProducer(sims, group.queues,
                                      clock=VirtualClock())
        status = [None]
        results: Dict = {}
        errors: List = []

        def _produce():
            status[0] = producer.run()

        def _consume(key):
            try:
                results[key] = consumer(group[key])
            except Exception as exc:  # keep the producer loop drainable
                errors.append(exc)
                for _ in group[key]:
                    pass

        t0 = time.perf_counter()
        threads = [threading.Thread(target=_produce, daemon=True)]
        threads += [threading.Thread(target=_consume, args=(key,),
                                     daemon=True) for key in sims]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        t_prod = time.perf_counter() - t0
        if errors:
            raise errors[0]
        if status[0] != 0:
            raise RuntimeError("producer reported fault status")
        return ({key: {**results[key], **group[key].stats(),
                       **producer.stats(key)} for key in sims}, t_prod)

    def _report(self, dataset: str, max_range: int, original: Stream,
                sim: Stream, om: StreamMetrics, sm: StreamMetrics,
                timings, consumer_metrics: Dict) -> SimulationReport:
        t_pre, t_nsa, t_prod = timings
        report = SimulationReport(
            dataset=dataset,
            max_range=max_range,
            original_rows=len(original),
            simulated_rows=len(sim),
            compression=compression_factor(original, max_range),
            original_volatility=om.volatility,
            simulated_volatility=sm.volatility,
            trend_corr=trend_correlation_from_counts(om.counts, sm.counts),
            preprocess_s=t_pre,
            nsa_s=t_nsa,
            produce_s=t_prod,
            consumer_metrics=consumer_metrics,
        )
        self.save_metrics(report)
        return report

    def run(self, dataset: str, max_range: int,
            consumer: Callable[[StreamQueue], Dict], *,
            scale: float = 1.0, seed: int = 0,
            queue_size: int = 64, backend: str = "auto") -> SimulationReport:
        """Full pipeline: POSD -> NSA -> PSDA -> consumer (the SPS task).

        Parameters
        ----------
        dataset : str
            Dataset name (see :func:`repro.streamsim.datasets.make_stream`).
        max_range : int
            Simulated time range for NSA.
        consumer : callable
            Drains the queue and returns its own metrics dict (function
            (2): collecting workload metrics of the SPS).
        scale, seed :
            Synthetic-dataset shape parameters (store-cache keyed).
        queue_size : int, default 64
            Bounded-queue capacity; the producer honours backpressure.
        backend : {"auto", "numpy", "pallas"}
            Passed through to NSA and the metrics engine. NSA output is
            bit-identical across backends; metric moments agree within
            1e-3; out-of-domain inputs fall back to numpy automatically.

        Returns
        -------
        SimulationReport
            All report statistics — original and simulated volatility plus
            the trend correlation — come from ONE batched metrics-engine
            call, so each stream is read once instead of once per
            statistic. The report is also persisted as JSON (function (3):
            the metrics repository).

        Raises
        ------
        RuntimeError
            If the producer reports a non-zero fault status.
        """
        t0 = time.perf_counter()
        original = self.prepare(dataset, scale=scale, seed=seed)
        t_pre = time.perf_counter() - t0

        sim = self.simulate(dataset, max_range, scale=scale, seed=seed,
                            backend=backend)
        t_nsa = self._last_nsa_s

        consumer_metrics, t_prod = self._produce_consume(sim, consumer,
                                                         queue_size)
        om, sm = metrics_batched([original, sim], [None, max_range],
                                 backend=backend)
        return self._report(dataset, max_range, original, sim, om, sm,
                            (t_pre, t_nsa, t_prod), consumer_metrics)

    def run_many(self, datasets: Sequence[str], max_ranges: Sequence[int],
                 consumer: Callable[[StreamQueue], Dict], *,
                 scale: float = 1.0, seed: int = 0, queue_size: int = 64,
                 backend: str = "auto",
                 fidelity_window_s: int = 60) -> List[SimulationReport]:
        """The Tables 1-3 scenario sweep (datasets × time ranges) as batched
        dispatches instead of ``len(datasets) * len(max_ranges)`` sequential
        :meth:`run` calls.

        ALL store-missing scenarios — the full grid, not one batch per
        ``max_range`` — go through ONE range-padded :func:`nsa_sweep`
        dispatch; every scenario's statistics (original + simulated
        volatility, trend correlation) then come from ONE batched
        metrics-engine call covering all original and simulated streams;
        and every scenario replays through ONE
        :class:`~repro.streamsim.producer.MultiQueueProducer` virtual-time
        loop feeding per-scenario bounded queues (each scenario's consumer
        drains its queue in its own thread). The 3×6 sweep therefore costs
        1 NSA dispatch + 1 replay loop instead of 6 + 18.

        Parameters
        ----------
        datasets : sequence of str
            Dataset names (see :func:`repro.streamsim.datasets.make_stream`).
        max_ranges : sequence of int
            Simulated time ranges — the sweep grid is their cross product
            with ``datasets``.
        consumer : callable
            Drains the queue per scenario and returns its metrics dict (the
            SPS-side workload). Scenario consumers run CONCURRENTLY (one
            thread per scenario — the batched replay's shared backpressure
            requires it), so a consumer shared across scenarios must be
            thread-safe.
        scale, seed, queue_size :
            As in :meth:`run`.
        backend : {"auto", "numpy", "pallas"}
            Passed through to NSA, the metrics engine, and the fidelity
            matrix; every backend yields equivalent reports.
        fidelity_window_s : int, default 60
            Sliding-mean window for the per-sweep fidelity matrices.

        Returns
        -------
        list of SimulationReport
            One per (dataset, max_range) scenario, in ``for dataset: for
            max_range`` order, each equivalent to the per-scenario
            :meth:`run` report (``nsa_s`` holds the sweep's shared NSA wall
            time for scenarios simulated together and ``produce_s`` the
            shared replay-loop wall time; ``nsa_s`` is 0.0 for store cache
            hits).

        Notes
        -----
        As a side product, each sweep's full S×S trend-correlation matrix
        over [originals..., sims@max_range...] — the Fig.-6 fidelity
        check — is computed by ONE batched
        :func:`~repro.streamsim.metrics.trend_correlation_matrix` dispatch
        per ``max_range`` (device-resident on the pallas backend), saved as
        JSON under ``fidelity_dir``, and exposed on :attr:`last_fidelity`.
        """
        datasets = list(datasets)
        max_ranges = list(max_ranges)
        originals, t_pre = {}, {}
        for d in datasets:  # per-dataset timing, matching run()'s reports
            t0 = time.perf_counter()
            originals[d] = self.prepare(d, scale=scale, seed=seed)
            t_pre[d] = time.perf_counter() - t0

        scenarios = [(d, mr) for d in datasets for mr in max_ranges]
        missing = [(d, mr) for d, mr in scenarios
                   if not self.store.exists(f"{d}__sim{mr}")]
        sims: Dict[tuple, Stream] = {}
        nsa_s: Dict[tuple, float] = {}
        t0 = time.perf_counter()
        if missing:
            # the whole store-missing grid in ONE range-padded dispatch
            batch = nsa_sweep(originals, max_ranges, pairs=missing,
                              backend=backend)
            t_sweep = time.perf_counter() - t0
            for (d, mr), sim in batch.items():
                self.store.put(f"{d}__sim{mr}", sim, {"max_range": mr})
        else:
            batch, t_sweep = {}, 0.0
        for sc in scenarios:
            sims[sc] = batch[sc] if sc in batch else \
                self.store.get(f"{sc[0]}__sim{sc[1]}")
            nsa_s[sc] = t_sweep if sc in batch else 0.0
        all_streams = [originals[d] for d in datasets] + \
            [sims[s] for s in scenarios]
        all_ranges: List[Optional[int]] = [None] * len(datasets) + \
            [mr for _, mr in scenarios]
        ms = metrics_batched(all_streams, all_ranges, backend=backend)
        om = dict(zip(datasets, ms[:len(datasets)]))
        sm = dict(zip(scenarios, ms[len(datasets):]))

        # Fig.-6 fidelity: per sweep (max_range), the S×S trend-correlation
        # matrix over [originals..., sims@mr...] from ONE batched dispatch
        # (device-resident on the pallas backend — no per-pair host loop)
        self.last_fidelity = []
        for mr in max_ranges:
            labels = [f"{d}/original" for d in datasets] + \
                [f"{d}/sim{mr}" for d in datasets]
            matrix = trend_correlation_matrix(
                [om[d].counts for d in datasets] +
                [sm[(d, mr)].counts for d in datasets],
                window_s=fidelity_window_s, backend=backend)
            fr = FidelityReport(mr, fidelity_window_s, labels,
                                matrix.tolist())
            self.save_fidelity(fr)
            self.last_fidelity.append(fr)

        # ONE virtual-time replay loop for the whole grid (per-scenario
        # bounded queues; each scenario's consumer drains concurrently)
        all_metrics, t_prod = self._produce_consume_many(
            sims, consumer, queue_size)
        reports = []
        for d, mr in scenarios:
            reports.append(self._report(
                d, mr, originals[d], sims[(d, mr)], om[d], sm[(d, mr)],
                (t_pre[d], nsa_s[(d, mr)], t_prod),
                all_metrics[(d, mr)]))
        return reports

    # -------------------------------------------------- (3) metrics manager
    def save_metrics(self, report: SimulationReport) -> Path:
        # ms stamp + a monotonic per-controller sequence number: two reports
        # landing in the same millisecond (routine under run_many) must not
        # overwrite each other
        stem = (f"{report.dataset}_max{report.max_range}_"
                f"{int(time.time() * 1e3)}")
        path = self.metrics_dir / f"{stem}_{next(self._metrics_seq):06d}.json"
        while path.exists():  # other controllers writing the same directory
            path = self.metrics_dir / \
                f"{stem}_{next(self._metrics_seq):06d}.json"
        with open(path, "w") as f:
            json.dump(report.to_json(), f, indent=2, default=_np_default)
        return path

    def save_fidelity(self, report: FidelityReport) -> Path:
        """Persist one sweep's S×S fidelity matrix under ``fidelity_dir``
        (kept out of ``metrics_dir`` proper so :meth:`list_metrics` keeps
        its one-file-per-scenario contract)."""
        self.fidelity_dir.mkdir(parents=True, exist_ok=True)
        stem = f"fidelity_max{report.max_range}_{int(time.time() * 1e3)}"
        path = self.fidelity_dir / \
            f"{stem}_{next(self._metrics_seq):06d}.json"
        while path.exists():
            path = self.fidelity_dir / \
                f"{stem}_{next(self._metrics_seq):06d}.json"
        with open(path, "w") as f:
            json.dump(report.to_json(), f, indent=2, default=_np_default)
        return path

    def list_fidelity(self) -> List[Path]:
        return sorted(self.fidelity_dir.glob("*.json"))

    def load_fidelity(self) -> List[Dict]:
        out = []
        for p in self.list_fidelity():
            with open(p) as f:
                out.append(json.load(f))
        return out

    def list_metrics(self) -> List[Path]:
        return sorted(self.metrics_dir.glob("*.json"))

    def load_metrics(self) -> List[Dict]:
        out = []
        for p in self.list_metrics():
            with open(p) as f:
                out.append(json.load(f))
        return out


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON serializable: {type(o)}")
