"""Controller — the user-side core component (paper §4).

The paper's controller has three functions, mirrored 1:1 here:
  (1) control the producer to load + simulate a user-defined time range;
  (2) collect physical/workload metrics of the stream processing system;
  (3) manage metrics of different stream data for viewing.

The paper collects metrics over the SPS's REST API into a "metrics
repository"; here the consumers (training/serving loops) expose a metrics
callback and the repository is a JSON directory.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.streamsim.datasets import make_stream
from repro.streamsim.metrics import Volatility, trend_correlation, volatility
from repro.streamsim.nsa import compression_factor, nsa
from repro.streamsim.preprocess import Stream, preprocess
from repro.streamsim.producer import Producer, VirtualClock
from repro.streamsim.queue import StreamQueue
from repro.streamsim.store import StreamStore


@dataclasses.dataclass
class SimulationReport:
    dataset: str
    max_range: int
    original_rows: int
    simulated_rows: int
    compression: float
    original_volatility: Volatility
    simulated_volatility: Volatility
    trend_corr: float
    preprocess_s: float
    nsa_s: float
    produce_s: float
    consumer_metrics: Dict

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        return d


class Controller:
    def __init__(self, store_dir: str, metrics_dir: Optional[str] = None):
        self.store = StreamStore(store_dir)
        self.metrics_dir = Path(metrics_dir or (Path(store_dir) / "_metrics"))
        self.metrics_dir.mkdir(parents=True, exist_ok=True)

    # ----------------------------------------------------- (1) simulate/run
    def prepare(self, dataset: str, *, scale: float = 1.0, seed: int = 0,
                force: bool = False) -> Stream:
        """POSD once, persist (preprocessing is a one-time job — paper §3.1)."""
        key = f"{dataset}__orig"
        if self.store.exists(key) and not force:
            return self.store.get(key)
        raw = make_stream(dataset, scale=scale, seed=seed)
        stream = preprocess(raw)
        self.store.put(key, stream, {"scale": scale, "seed": seed})
        return stream

    def simulate(self, dataset: str, max_range: int, *, scale: float = 1.0,
                 seed: int = 0, force: bool = False,
                 backend: str = "auto") -> Stream:
        """NSA once per (dataset, max_range), persist (paper §3.2: stored
        'because repeated normalizing and sampling operations are not
        performed').

        ``backend`` selects the NSA implementation ("auto" picks the
        device-resident Pallas path on TPU, numpy otherwise — see
        :mod:`repro.streamsim.nsa`); every backend is bit-identical, so the
        store cache is backend-agnostic.
        """
        # timing always reflects THIS call: 0.0 on a store-cache hit
        self._last_nsa_s = 0.0
        key = f"{dataset}__sim{max_range}"
        if self.store.exists(key) and not force:
            return self.store.get(key)
        original = self.prepare(dataset, scale=scale, seed=seed, force=force)
        t0 = time.perf_counter()
        sim = nsa(original, max_range, backend=backend)
        self._last_nsa_s = time.perf_counter() - t0
        self.store.put(key, sim, {"max_range": max_range})
        return sim

    def run(self, dataset: str, max_range: int,
            consumer: Callable[[StreamQueue], Dict], *,
            scale: float = 1.0, seed: int = 0,
            queue_size: int = 64, backend: str = "auto") -> SimulationReport:
        """Full pipeline: POSD -> NSA -> PSDA -> consumer (the SPS task).

        ``consumer`` drains the queue and returns its own metrics dict
        (function (2): collecting workload metrics of the SPS)."""
        t0 = time.perf_counter()
        original = self.prepare(dataset, scale=scale, seed=seed)
        t_pre = time.perf_counter() - t0

        sim = self.simulate(dataset, max_range, scale=scale, seed=seed,
                            backend=backend)
        t_nsa = self._last_nsa_s

        queue = StreamQueue(maxsize=queue_size)
        producer = Producer(sim, queue, clock=VirtualClock())
        t0 = time.perf_counter()
        # virtual-time: producer fills, consumer drains (bounded queue means
        # we interleave: run producer in a thread to honour backpressure)
        import threading
        status = [None]

        def _produce():
            status[0] = producer.run()

        th = threading.Thread(target=_produce, daemon=True)
        th.start()
        consumer_metrics = consumer(queue)
        th.join()
        t_prod = time.perf_counter() - t0
        if status[0] != 0:
            raise RuntimeError("producer reported fault status")

        report = SimulationReport(
            dataset=dataset,
            max_range=max_range,
            original_rows=len(original),
            simulated_rows=len(sim),
            compression=compression_factor(original, max_range),
            original_volatility=volatility(original),
            simulated_volatility=volatility(sim, max_range),
            trend_corr=trend_correlation(original, sim),
            preprocess_s=t_pre,
            nsa_s=t_nsa,
            produce_s=t_prod,
            consumer_metrics={**consumer_metrics, **queue.stats(),
                              **producer.stats()},
        )
        self.save_metrics(report)
        return report

    # -------------------------------------------------- (3) metrics manager
    def save_metrics(self, report: SimulationReport) -> Path:
        path = self.metrics_dir / (
            f"{report.dataset}_max{report.max_range}_{int(time.time()*1e3)}.json")
        with open(path, "w") as f:
            json.dump(report.to_json(), f, indent=2, default=_np_default)
        return path

    def list_metrics(self) -> List[Path]:
        return sorted(self.metrics_dir.glob("*.json"))

    def load_metrics(self) -> List[Dict]:
        out = []
        for p in self.list_metrics():
            with open(p) as f:
                out.append(json.load(f))
        return out


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON serializable: {type(o)}")
