"""Controller — the user-side core component (paper §4).

The paper's controller has three functions, mirrored 1:1 here:
  (1) control the producer to load + simulate a user-defined time range;
  (2) collect physical/workload metrics of the stream processing system;
  (3) manage metrics of different stream data for viewing.

The paper collects metrics over the SPS's REST API into a "metrics
repository"; here the consumers (training/serving loops) expose a metrics
callback and the repository is a JSON directory.

Since the plan/engine split, the controller is a THIN driver: ``run`` and
``run_many`` build a :class:`~repro.streamsim.plan.SweepPlan` and hand it
to the sweep engine (:mod:`repro.streamsim.engine`), which owns all NSA /
metrics / fidelity / replay orchestration. What remains here is the
paper-side surface: the store, the metrics repository, and the
per-dataset preprocessing timer.
"""

from __future__ import annotations

import itertools
import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.streamsim import engine
from repro.streamsim.datasets import make_stream
# Report dataclasses live in the engine's report layer now; re-exported
# here because the controller is their historical import location.
from repro.streamsim.engine import FidelityReport, SimulationReport  # noqa: F401
from repro.streamsim.faults import FaultPlan
from repro.streamsim.nsa import _resolve_backend, nsa
from repro.streamsim.plan import DAY_S, plan_sweep
from repro.streamsim.preprocess import Stream, preprocess
from repro.streamsim.queue import StreamQueue
from repro.streamsim.resilience import RetryPolicy, SweepCheckpoint
from repro.streamsim.store import StreamStore


class Controller:
    def __init__(self, store_dir: str, metrics_dir: Optional[str] = None):
        self.store = StreamStore(store_dir)
        self.metrics_dir = Path(metrics_dir or (Path(store_dir) / "_metrics"))
        self.metrics_dir.mkdir(parents=True, exist_ok=True)
        self.fidelity_dir = self.metrics_dir / "fidelity"
        self._metrics_seq = itertools.count()
        #: the per-sweep S×S fidelity matrices from the latest
        #: :meth:`run_many` call (also persisted under ``fidelity_dir``)
        self.last_fidelity: List[FidelityReport] = []

    # ----------------------------------------------------- (1) simulate/run
    def prepare(self, dataset: str, *, scale: float = 1.0, seed: int = 0,
                force: bool = False) -> Stream:
        """POSD once, persist (preprocessing is a one-time job — paper §3.1)."""
        key = f"{dataset}__orig"
        if self.store.exists(key) and not force:
            return self.store.get(key)
        raw = make_stream(dataset, scale=scale, seed=seed)
        stream = preprocess(raw)
        self.store.put(key, stream, {"scale": scale, "seed": seed})
        return stream

    def simulate(self, dataset: str, max_range: int, *, scale: float = 1.0,
                 seed: int = 0, force: bool = False,
                 backend: str = "auto") -> Stream:
        """NSA once per (dataset, max_range), persist (paper §3.2: stored
        'because repeated normalizing and sampling operations are not
        performed').

        ``backend`` selects the NSA implementation ("auto" picks the
        device-resident Pallas path on TPU, numpy otherwise — see
        :mod:`repro.streamsim.nsa`); every backend is bit-identical, so the
        store cache is backend-agnostic.
        """
        key = f"{dataset}__sim{max_range}"
        if self.store.exists(key) and not force:
            return self.store.get(key)
        original = self.prepare(dataset, scale=scale, seed=seed, force=force)
        sim = nsa(original, max_range, backend=backend)
        self.store.put(key, sim, {"max_range": max_range})
        return sim

    def _prepare_all(self, datasets: Sequence[str], scale: float,
                     seed: int, duration_s: int = 0) -> tuple:
        """POSD every dataset, timing each (matching ``run``'s reports).

        ``duration_s > 0`` prepares the MULTI-DAY original instead: one
        preprocessed day per 86 400 s of duration (day ``d`` generated
        with ``seed + d``, so days carry distinct traffic), each day
        rebased onto ``[d*86400, (d+1)*86400)`` so the diurnal cycle
        stays aligned across days, concatenated and trimmed to
        ``duration_s``. Cached under ``<dataset>__orig__d<duration>``.
        """
        originals, t_pre = {}, {}
        for d in datasets:
            t0 = time.perf_counter()
            if duration_s > 0:
                originals[d] = self._prepare_multiday(d, scale, seed,
                                                      duration_s)
            else:
                originals[d] = self.prepare(d, scale=scale, seed=seed)
            t_pre[d] = time.perf_counter() - t0
        return originals, t_pre

    def _prepare_multiday(self, dataset: str, scale: float, seed: int,
                          duration_s: int) -> Stream:
        key = f"{dataset}__orig__d{duration_s}"
        if self.store.exists(key):
            return self.store.get(key)
        n_days = -(-int(duration_s) // DAY_S)
        ts, payloads = [], []
        for day in range(n_days):
            raw = make_stream(dataset, scale=scale, seed=seed + day)
            st = preprocess(raw)
            # rebase the day onto its slot; clip a (pathological) day
            # running past 86 400 s to the slot boundary so the
            # concatenation stays chronological
            t_day = np.minimum(st.t - st.t[0], float(DAY_S))
            ts.append(t_day + day * float(DAY_S))
            payloads.append(st.payload)
        t = np.concatenate(ts)
        cols = payloads[0].keys()
        payload = {c: np.concatenate([p[c] for p in payloads])
                   for c in cols}
        keep = t < float(duration_s)     # trim the partial last day
        stream = Stream(name=dataset, t=t[keep],
                        payload={c: v[keep] for c, v in payload.items()},
                        scale_stamp=None)
        self.store.put(key, stream, {"scale": scale, "seed": seed,
                                     "duration_s": int(duration_s)})
        return stream

    def run(self, dataset: str, max_range: int,
            consumer: Callable[[StreamQueue], Dict], *,
            scale: float = 1.0, seed: int = 0,
            queue_size: int = 64, backend: str = "auto",
            autotune: Optional[str] = None) -> SimulationReport:
        """Full pipeline: POSD -> NSA -> PSDA -> consumer (the SPS task).

        A thin driver: the scenario becomes a one-cell
        :class:`~repro.streamsim.plan.SweepPlan` executed by the sweep
        engine; the consumer drains the queue on the CALLING thread (no
        thread-safety requirement, unlike :meth:`run_many`).

        Parameters
        ----------
        dataset : str
            Dataset name (see :func:`repro.streamsim.datasets.make_stream`).
        max_range : int
            Simulated time range for NSA.
        consumer : callable
            Drains the queue and returns its own metrics dict (function
            (2): collecting workload metrics of the SPS).
        scale, seed :
            Synthetic-dataset shape parameters (store-cache keyed).
        queue_size : int, default 64
            Bounded-queue capacity; the producer honours backpressure.
        backend : {"auto", "numpy", "pallas"}
            Passed through to NSA and the metrics engine. NSA output is
            bit-identical across backends; metric statistics agree within
            the documented 1e-3 tolerance; out-of-domain inputs fall back
            to numpy automatically.
        autotune : {None, "off", "cached", "force"}, optional
            Kernel tile-tuning mode for every device leg (see
            :mod:`repro.kernels.tuning`). ``None``/``"off"`` keep the
            fixed default tiles (bit-identical to prior releases);
            ``"cached"`` reuses measured winners persisted under the
            store; ``"force"`` re-sweeps the candidate lattice on-device.

        Returns
        -------
        SimulationReport
            All report statistics come from the engine's batched metrics
            pass, so each stream is read once instead of once per
            statistic. The report is also persisted as JSON (function (3):
            the metrics repository).

        Raises
        ------
        RuntimeError
            If the producer reports a non-zero fault status.
        """
        originals, t_pre = self._prepare_all([dataset], scale, seed)
        plan = plan_sweep(self.store, [dataset], [max_range],
                          {dataset: len(originals[dataset])},
                          scale=scale, seed=seed, n_hosts=1, host_index=0,
                          n_devices=1)
        result = engine.execute_sweep(plan, originals, self.store,
                                      backend=backend, autotune=autotune)
        sim = result.materialize()[(dataset, max_range)]
        consumer_metrics, t_prod = engine.replay_one(sim, consumer,
                                                     queue_size)
        report = engine.build_report(result, (dataset, max_range),
                                     t_pre[dataset], t_prod,
                                     consumer_metrics)
        self.save_metrics(report)
        return report

    def run_many(self, datasets: Sequence[str], max_ranges: Sequence[int],
                 consumer: Callable[[StreamQueue], Dict], *,
                 scale: float = 1.0, seed: int = 0, queue_size: int = 64,
                 backend: str = "auto", fidelity_window_s: int = 60,
                 n_devices: Optional[int] = None,
                 host_index: Optional[int] = None,
                 n_hosts: Optional[int] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 breaker_threshold: int = 3,
                 consumer_deadline_s: Optional[float] = None,
                 on_failure: str = "raise",
                 max_bytes: Optional[int] = None,
                 retention_policy: str = "block",
                 checkpoint: bool = False,
                 chunk_s: int = 0,
                 duration_s: int = 0,
                 service: bool = False,
                 lease_ttl_s: float = 60.0,
                 service_poll_s: float = 0.2,
                 lease_batch: int = 1,
                 worker_id: Optional[str] = None,
                 service_deadline_s: Optional[float] = None,
                 autotune: Optional[str] = None
                 ) -> List[SimulationReport]:
        """The Tables 1-3 scenario sweep (datasets × time ranges), planned
        and executed by the sweep engine.

        A thin driver over :func:`repro.streamsim.plan.plan_sweep` +
        :func:`repro.streamsim.engine.execute_sweep` +
        :func:`repro.streamsim.engine.run_sweep`: the plan resolves
        store-cache hits and partitions the store-missing scenarios into
        per-device (and, under ``jax.distributed``, per-host) shards with
        range-padded row counts balanced across shards; the engine then
        runs each shard's normalize→sample→compact→metrics chain as ONE
        dispatch per kernel stage on that shard's device, keeps kept-index
        sets and per-second counts device-resident until a single
        ``materialize()`` host pass, and replays every scenario through
        ONE multi-queue virtual-time loop.

        Parameters
        ----------
        datasets : sequence of str
            Dataset names (see :func:`repro.streamsim.datasets.make_stream`).
        max_ranges : sequence of int
            Simulated time ranges — the sweep grid is their cross product
            with ``datasets``.
        consumer : callable
            Drains the queue per scenario and returns its metrics dict (the
            SPS-side workload). Scenario consumers run CONCURRENTLY (one
            thread per scenario — the batched replay's shared backpressure
            requires it), so a consumer shared across scenarios must be
            thread-safe.
        scale, seed, queue_size :
            As in :meth:`run`.
        backend : {"auto", "numpy", "pallas"}
            Passed through to the engine; ``"numpy"`` (and ``"auto"`` off
            TPU) reproduces the sequential per-scenario reports bit-equal,
            ``"pallas"`` keeps the whole reporting chain device-resident
            (statistics within the documented 1e-3 tolerance).
        fidelity_window_s : int, default 60
            Sliding-mean window for the per-sweep fidelity matrices.
        n_devices, host_index, n_hosts : int, optional
            Plan-partition overrides (default: this process's jax
            topology — see :func:`repro.streamsim.plan.plan_sweep`). In a
            multi-host run every host builds the same plan and reports
            only its own scenario slice into the shared repository.
        fault_plan : FaultPlan, optional
            Seeded per-scenario chaos schedule (drops / duplicates /
            reorders / jitter / stalls / consumer crashes) injected into
            the replay — see :mod:`repro.streamsim.faults`.
        retry_policy, breaker_threshold, consumer_deadline_s, on_failure :
            The replay resilience knobs, passed through to
            :func:`repro.streamsim.engine.replay_many`: solo retries with
            capped exponential backoff, a per-scenario circuit breaker,
            a consumer deadline that surfaces a wedged consumer as a
            named scenario failure instead of hanging ``join()`` forever,
            and ``on_failure="degrade"`` to turn terminal failures into
            ``status="partial"`` reports instead of raising.
        max_bytes, retention_policy :
            Optional shared byte budget across the sweep's queues (broker
            retention — ``"block"`` or ``"drop_oldest"``); see
            :class:`repro.streamsim.queue.ByteBudget`.
        checkpoint : bool, default False
            Persist per-scenario completion markers through the stream
            store (namespace: :attr:`~repro.streamsim.plan.SweepPlan.
            sweep_id`). A killed sweep re-invoked with the same arguments
            resumes from the last completed scenario: finished scenarios'
            reports load from their markers, only the remainder is
            re-simulated/replayed, and the markers are cleared once the
            whole sweep completes. (Resume re-plans only the remaining
            scenarios, so its fidelity matrices cover the resumed subset;
            single-host sweeps are the intended scope.)
        chunk_s : int, default 0
            ``> 0`` routes the sweep through the chunked double-buffered
            pipeline (:class:`repro.streamsim.engine.ChunkedSweepRunner`
            + :func:`repro.streamsim.engine.run_sweep_chunked`): each
            scenario's timeline is computed, persisted and replayed in
            ``chunk_s``-second chunks with cross-chunk carry state
            device-resident, so host residency stays bounded (at most 2
            chunks per scenario buffered — the ``feed_hwm_chunks`` stat
            in each report's ``consumer_metrics`` proves it) while the
            reports compose to the monolithic answer. ``chunk_s`` does
            NOT enter the store cache key — chunked and monolithic runs
            share simulated streams. The chunked path does not support
            ``retry_policy``/``consumer_deadline_s`` (a consumed chunk
            cannot be rewound); ``on_failure="degrade"`` still applies.
        duration_s : int, default 0
            ``> 0`` simulates a MULTI-DAY source: one preprocessed day
            per 86 400 s (see :meth:`_prepare_all`), every scenario's
            effective simulated range growing to ``max_range`` per day
            (``ScenarioSpec.span_s``), preserving the per-day
            compression ratio. Requires ``chunk_s > 0`` (multi-day runs
            exist to be streamed, not held whole).
        service : bool, default False
            Run the sweep through the fault-tolerant lease-based sweep
            service (:mod:`repro.streamsim.service`) instead of static
            host partitioning: scenarios are published to a durable work
            queue in the store, any number of participants (this process
            plus every other ``run_many(service=True)`` pointed at the
            same store and sweep config) lease, execute, and publish
            them, expired leases of dead workers are requeued (and
            quarantined as ``status="poisoned"`` after
            ``breaker_threshold`` worker deaths on one scenario), and
            EVERY participant returns the full grid's merged reports
            plus the cross-host-merged full S×S fidelity matrix on
            :attr:`last_fidelity`. Incompatible with ``chunk_s`` and
            ``checkpoint`` (the service's queue IS the checkpoint).
        lease_ttl_s, service_poll_s, lease_batch, worker_id,
        service_deadline_s :
            Service knobs: lease time-to-live (must comfortably exceed
            one scenario batch's runtime — heartbeats renew it while the
            worker lives), idle poll interval, scenarios leased per
            claim, this participant's stable id (defaults to
            host-pid-nonce), and an overall give-up deadline.

        Returns
        -------
        list of SimulationReport
            One per (dataset, max_range) scenario, in ``for dataset: for
            max_range`` order, each equivalent to the per-scenario
            :meth:`run` report (``nsa_s`` holds the sweep's shared NSA wall
            time for scenarios simulated together and ``produce_s`` the
            shared replay-loop wall time; ``nsa_s`` is 0.0 for store cache
            hits).

        Notes
        -----
        As a side product, each sweep's full S×S trend-correlation matrix
        over [originals..., sims@max_range...] — the Fig.-6 fidelity
        check — is computed from ONE batched dispatch chain per
        ``max_range`` (consuming the engine's device-resident count rows
        on the pallas backend), saved as JSON under ``fidelity_dir``, and
        exposed on :attr:`last_fidelity`.
        """
        if duration_s and not chunk_s:
            raise ValueError(
                "duration_s requires chunk_s > 0 — multi-day sweeps run "
                "through the chunked pipeline")
        if chunk_s and (retry_policy is not None or
                        consumer_deadline_s is not None):
            raise ValueError(
                "retry_policy/consumer_deadline_s are monolithic-replay "
                "features; the chunked pipeline cannot rewind a "
                "scenario's consumed chunks")
        if service and (chunk_s or checkpoint):
            raise ValueError(
                "service mode is incompatible with chunk_s/checkpoint — "
                "the service's durable work queue is its own checkpoint "
                "and leases are scenario-granular")
        originals, t_pre = self._prepare_all(datasets, scale, seed,
                                             duration_s)
        if _resolve_backend(backend) == "numpy":
            # host mode ignores the partition; don't let the topology
            # defaults force a jax runtime initialization on the pure
            # numpy path
            n_devices = 1 if n_devices is None else n_devices
            host_index = 0 if host_index is None else host_index
            n_hosts = 1 if n_hosts is None else n_hosts
        row_counts = {d: len(originals[d]) for d in datasets}
        if service:
            return self._run_service(
                datasets, max_ranges, originals, t_pre, consumer,
                scale=scale, seed=seed, queue_size=queue_size,
                backend=backend, fidelity_window_s=fidelity_window_s,
                n_devices=n_devices, host_index=host_index,
                n_hosts=n_hosts, fault_plan=fault_plan,
                retry_policy=retry_policy,
                breaker_threshold=breaker_threshold,
                consumer_deadline_s=consumer_deadline_s,
                on_failure=on_failure, max_bytes=max_bytes,
                retention_policy=retention_policy,
                lease_ttl_s=lease_ttl_s, service_poll_s=service_poll_s,
                lease_batch=lease_batch, worker_id=worker_id,
                service_deadline_s=service_deadline_s)
        plan = plan_sweep(self.store, datasets, max_ranges, row_counts,
                          scale=scale, seed=seed, n_devices=n_devices,
                          host_index=host_index, n_hosts=n_hosts,
                          chunk_s=chunk_s, duration_s=duration_s)
        ckpt: Optional[SweepCheckpoint] = None
        prior: Dict = {}
        grid = [s.scenario for s in plan.scenarios]
        if plan.n_hosts > 1:
            local = {s.scenario for s in plan.local_missing} | \
                {s.scenario for s in plan.cached}
            grid = [sc for sc in grid if sc in local]
        if checkpoint:
            ckpt = SweepCheckpoint(self.store, plan.sweep_id)
            done = set(ckpt.done_scenarios()) & set(grid)
            if done:
                # resume: completed scenarios' reports come straight from
                # their markers; only the remainder is planned and run
                prior = {sc: r for sc, r in ckpt.load_reports().items()
                         if sc in done}
                remaining = [sc for sc in grid if sc not in done]
                plan = None if not remaining else plan_sweep(
                    self.store, datasets, max_ranges, row_counts,
                    scale=scale, seed=seed, pairs=remaining,
                    n_devices=n_devices, host_index=host_index,
                    n_hosts=n_hosts, chunk_s=chunk_s,
                    duration_s=duration_s)
        new_reports: List[SimulationReport] = []
        if plan is not None:
            if chunk_s:
                runner = engine.ChunkedSweepRunner(
                    plan, originals, self.store, backend=backend,
                    checkpoint=ckpt, autotune=autotune)
                new_reports, fidelity = engine.run_sweep_chunked(
                    runner, consumer, queue_size=queue_size,
                    fidelity_window_s=fidelity_window_s, t_pre=t_pre,
                    fault_plan=fault_plan, on_failure=on_failure,
                    max_bytes=max_bytes,
                    retention_policy=retention_policy, checkpoint=ckpt)
            else:
                result = engine.execute_sweep(plan, originals, self.store,
                                              backend=backend,
                                              checkpoint=ckpt,
                                              autotune=autotune)
                new_reports, fidelity = engine.run_sweep(
                    result, consumer, queue_size=queue_size,
                    fidelity_window_s=fidelity_window_s, t_pre=t_pre,
                    fault_plan=fault_plan, retry_policy=retry_policy,
                    breaker_threshold=breaker_threshold,
                    consumer_deadline_s=consumer_deadline_s,
                    on_failure=on_failure, max_bytes=max_bytes,
                    retention_policy=retention_policy, checkpoint=ckpt)
            if not chunk_s and plan.n_hosts > 1:
                # PR 5 gap closed: publish this host's exact count rows
                # into the shared store and, once every host's rows are
                # there, replace the partial per-host matrices with the
                # merged FULL S×S matrix (the last host to finish — and
                # any later re-run — sees the complete artifact)
                merged = self._publish_and_merge_fidelity(
                    result, plan, fidelity_window_s)
                if merged is not None:
                    fidelity = merged
            self.last_fidelity = fidelity
            for fr in fidelity:
                self.save_fidelity(fr)
        by_sc = dict(prior)
        by_sc.update({(r.dataset, r.max_range): r for r in new_reports})
        reports = [by_sc[sc] for sc in grid]
        for report in reports:
            self.save_metrics(report)
        if ckpt is not None:
            ckpt.clear()     # sweep complete: the next run starts fresh
        return reports

    def _run_service(self, datasets, max_ranges, originals, t_pre,
                     consumer, *, scale, seed, queue_size, backend,
                     fidelity_window_s, n_devices, host_index, n_hosts,
                     fault_plan, retry_policy, breaker_threshold,
                     consumer_deadline_s, on_failure, max_bytes,
                     retention_policy, lease_ttl_s, service_poll_s,
                     lease_batch, worker_id,
                     service_deadline_s) -> List[SimulationReport]:
        """The ``run_many(service=True)`` leg: one participant of the
        lease-based sweep service. Every participant gets the full
        grid's merged reports back; only the reports THIS worker
        computed land in its local metrics repository (the shared store
        carried them to every peer already)."""
        from repro.streamsim.service import run_service_sweep

        if n_hosts is None or host_index is None or n_devices is None:
            from repro.distributed import process_topology
            pidx, pcount, local = process_topology()
            n_hosts = pcount if n_hosts is None else n_hosts
            host_index = pidx if host_index is None else host_index
            n_devices = local if n_devices is None else n_devices
        if worker_id is None:
            import os
            worker_id = f"host{host_index}-{os.getpid()}"
        reports, fidelity, mine = run_service_sweep(
            self.store, datasets, max_ranges, originals, consumer,
            scale=scale, seed=seed, t_pre=t_pre, queue_size=queue_size,
            backend=backend, fidelity_window_s=fidelity_window_s,
            n_devices=n_devices, lease_ttl_s=lease_ttl_s,
            poll_s=service_poll_s, lease_batch=lease_batch,
            breaker_threshold=breaker_threshold, worker_id=worker_id,
            n_participants=n_hosts, deadline_s=service_deadline_s,
            fault_plan=fault_plan, retry_policy=retry_policy,
            consumer_deadline_s=consumer_deadline_s,
            on_failure=on_failure, max_bytes=max_bytes,
            retention_policy=retention_policy)
        self.last_fidelity = fidelity
        for fr in fidelity:
            self.save_fidelity(fr)
        from repro.streamsim.service import scenario_marker
        own = set(mine)
        for report in reports:
            if scenario_marker(report.dataset, report.max_range) in own:
                self.save_metrics(report)
        return reports

    def _publish_and_merge_fidelity(self, result, plan, window_s):
        """Cross-host fidelity merge for STATIC multi-host sweeps.

        Publishes this host's exact per-scenario count rows (plus the
        per-dataset original rows) under the host-independent
        ``sweep_group_id`` namespace, then attempts the same count-row
        merge the sweep service uses. Returns the merged full-grid
        :class:`FidelityReport` list, or None while peers' rows are
        still missing (the caller keeps its partial per-host matrices —
        exactly the pre-PR 9 behavior — until the last host closes the
        sweep)."""
        from repro.streamsim.service import (merge_fidelity, pack_counts,
                                             scenario_marker)

        gid = plan.sweep_group_id
        ns = f"{gid}/fidelity"
        worker = f"host{plan.host_index}"
        for (d, mr), row in result.count_rows().items():
            name = f"sim__{scenario_marker(d, mr)}"
            # first-writer-wins: rows are deterministic (within backend
            # tolerance), and keeping the first writer preserves true
            # provenance — a later host re-reporting a cache hit must
            # not claim the row it never computed
            if not self.store.has_marker(ns, name):
                self.store.put_marker(ns, name,
                                      {"counts": pack_counts(row),
                                       "worker": worker})
        for d in plan.datasets:
            name = f"orig__{d}"
            if not self.store.has_marker(ns, name):
                self.store.put_marker(ns, name, {
                    "counts": pack_counts(result.om[d].counts),
                    "worker": worker})
        merged = merge_fidelity(self.store, gid, plan.datasets,
                                plan.max_ranges, window_s=window_s)
        D = len(plan.datasets)
        complete = len(merged) == len(plan.max_ranges) and \
            all(len(fr.labels) == 2 * D for fr in merged)
        return merged if complete else None

    # -------------------------------------------------- (3) metrics manager
    def _unique_path(self, directory: Path, stem: str) -> Path:
        """ms stamp + a monotonic per-controller sequence number: two
        artifacts landing in the same millisecond (routine under
        ``run_many``) must not overwrite each other; the existence loop
        covers other controllers writing the same directory."""
        path = directory / f"{stem}_{next(self._metrics_seq):06d}.json"
        while path.exists():
            path = directory / f"{stem}_{next(self._metrics_seq):06d}.json"
        return path

    def save_metrics(self, report: SimulationReport) -> Path:
        stem = (f"{report.dataset}_max{report.max_range}_"
                f"{int(time.time() * 1e3)}")
        path = self._unique_path(self.metrics_dir, stem)
        with open(path, "w") as f:
            json.dump(report.to_json(), f, indent=2, default=_np_default)
        return path

    def save_fidelity(self, report: FidelityReport) -> Path:
        """Persist one sweep's S×S fidelity matrix under ``fidelity_dir``
        (kept out of ``metrics_dir`` proper so :meth:`list_metrics` keeps
        its one-file-per-scenario contract)."""
        self.fidelity_dir.mkdir(parents=True, exist_ok=True)
        stem = f"fidelity_max{report.max_range}_{int(time.time() * 1e3)}"
        path = self._unique_path(self.fidelity_dir, stem)
        with open(path, "w") as f:
            json.dump(report.to_json(), f, indent=2, default=_np_default)
        return path

    def list_fidelity(self) -> List[Path]:
        return sorted(self.fidelity_dir.glob("*.json"))

    def load_fidelity(self) -> List[Dict]:
        out = []
        for p in self.list_fidelity():
            with open(p) as f:
                out.append(json.load(f))
        return out

    def list_metrics(self) -> List[Path]:
        return sorted(self.metrics_dir.glob("*.json"))

    def load_metrics(self) -> List[Dict]:
        out = []
        for p in self.list_metrics():
            with open(p) as f:
                out.append(json.load(f))
        return out


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON serializable: {type(o)}")
