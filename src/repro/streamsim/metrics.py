"""Volatility & trend metrics (paper §5.2, formulas (2)-(4)).

The paper evaluates simulation quality with three per-second statistics —
Average, Variance, Standard Variance — over the arrival-count series
``q_i`` (records in second ``i``). Formulas (3)/(4) in the paper text drop
the square on the deviation (an obvious typesetting slip); we implement the
standard population variance/σ, which reproduces the tables' magnitudes.

Backends
--------
Every metric takes the same ``backend="numpy|pallas|auto"`` knob as
:func:`repro.streamsim.nsa.nsa`:

- ``"numpy"`` — vectorized host path (one ``bincount`` pass + exact f64
  moments).
- ``"pallas"`` — the fused device engine
  (:func:`repro.kernels.ops.stream_metrics`): histogram AND moments from one
  pass over the record tiles, int32-exact counts.
- ``"auto"`` — pallas on TPU, numpy otherwise.

Counts are **bit-exact** across backends; derived moments (average /
variance / σ) agree within 1e-3 relative tolerance (the device reduces in
f32).

:func:`metrics_batched` evaluates S streams — possibly with different time
ranges — through ONE batched engine dispatch, which is what
``Controller.run`` / ``Controller.run_many`` use so the whole reporting path
re-reads each stream once instead of ~4 times.

:func:`trend` is an O(n) cumulative-sum sliding mean on every backend
(window sums via two prefix-sum lookups), replacing the seed's
O(n·window) ``np.convolve``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.streamsim.nsa import BACKENDS, _resolve_backend  # noqa: F401
from repro.streamsim.preprocess import Stream


@dataclasses.dataclass(frozen=True)
class Volatility:
    average: float
    variance: float
    std_variance: float
    time_range: int

    def as_row(self) -> str:
        return (f"{self.time_range},{self.average:.2f},"
                f"{self.variance:.2f},{self.std_variance:.2f}")


@dataclasses.dataclass(frozen=True)
class StreamMetrics:
    """One stream's reporting bundle from a single engine pass."""

    counts: np.ndarray          # int64 (time_range,) per-second counts q_i
    volatility: Volatility


# --------------------------------------------------------------- bucketing
def _bucket_series(stream: Stream, time_range: Optional[int],
                   use_scale_stamp: Optional[bool]):
    """Integer bucket per record + the series length (shared by backends).

    For simulated streams the bucket is ``scale_stamp``; for original
    streams it is ``floor(t - t_0)``. Returns ``(buckets int64, time_range)``
    — ``time_range`` 0 means the empty/degenerate series.
    """
    if use_scale_stamp is None:
        use_scale_stamp = stream.scale_stamp is not None
    if use_scale_stamp:
        if stream.scale_stamp is None:
            raise ValueError("stream has no scale_stamp; run NSA first")
        buckets = np.asarray(stream.scale_stamp, np.int64)
        if time_range is None:
            time_range = int(buckets.max()) + 1 if len(buckets) else 0
        elif len(buckets):
            # scale stamps are never clipped to a user time range (seed
            # bincount semantics: the series covers max(tr, max stamp + 1)
            # seconds), so a too-small tr expands rather than mis-binning
            # on numpy or raising on pallas
            time_range = max(time_range, int(buckets.max()) + 1)
    else:
        if len(stream.t) == 0:
            return np.zeros(0, np.int64), (time_range or 0)
        buckets = np.floor(stream.t - stream.t[0]).astype(np.int64)
        if time_range is None:
            time_range = int(buckets.max()) + 1
        buckets = np.clip(buckets, 0, time_range - 1)
    return buckets, time_range


def _volatility_from_moments(s: float, s2: float, tr: int) -> Volatility:
    if tr <= 0:
        return Volatility(0.0, 0.0, 0.0, 0)
    avg = s / tr
    var = max(s2 / tr - avg * avg, 0.0)
    return Volatility(float(avg), float(var), float(np.sqrt(var)), tr)


def _numpy_metrics(buckets: np.ndarray, tr: int) -> StreamMetrics:
    q = np.bincount(buckets, minlength=tr)
    s = float(q.sum())
    s2 = float((q.astype(np.float64) ** 2).sum())
    return StreamMetrics(q, _volatility_from_moments(s, s2, tr))


# ------------------------------------------------------------- public API
def per_second_counts(stream: Stream, time_range: Optional[int] = None,
                      *, use_scale_stamp: Optional[bool] = None,
                      backend: str = "numpy") -> np.ndarray:
    """Arrival counts q_i per (simulated or original) second.

    Bit-exact across backends (int64 out; the device path counts in int32,
    exact within the engine's guarded domain).
    """
    buckets, tr = _bucket_series(stream, time_range, use_scale_stamp)
    if _resolve_backend(backend) == "pallas" and tr > 0:
        from repro.kernels import ops
        hist, _ = ops.stream_metrics(buckets, tr)
        return np.asarray(hist, np.int64)
    return np.bincount(buckets, minlength=tr)


def volatility(stream: Stream, time_range: Optional[int] = None,
               *, backend: str = "numpy") -> Volatility:
    """Average / Variance / StdVariance of q_i (paper formulas (2)-(4))."""
    buckets, tr = _bucket_series(stream, time_range, None)
    if _resolve_backend(backend) == "pallas" and tr > 0:
        from repro.kernels import ops
        _, mom = ops.stream_metrics(buckets, tr)
        mom = np.asarray(mom, np.float64)
        return _volatility_from_moments(mom[0], mom[1], tr)
    return _numpy_metrics(buckets, tr).volatility


def metrics_batched(streams: Sequence[Stream],
                    time_ranges: Sequence[Optional[int]],
                    *, use_scale_stamps: Optional[Sequence[Optional[bool]]]
                    = None,
                    backend: str = "auto") -> List[StreamMetrics]:
    """Counts + volatility for S streams from ONE batched engine call.

    ``time_ranges[i]`` is the i-th stream's series length (None infers it:
    the NSA ``max_range`` convention for simulated streams, the spanned
    seconds for originals). On the pallas backend all S histograms and
    moment pairs come from a single 2-D-grid kernel dispatch padded to the
    largest time range — trailing zero buckets perturb neither counts nor
    moments; per-stream statistics divide by the true range.
    """
    if len(streams) != len(time_ranges):
        raise ValueError("streams and time_ranges must align")
    if use_scale_stamps is None:
        use_scale_stamps = [None] * len(streams)
    series = [_bucket_series(s, tr, uss)
              for s, tr, uss in zip(streams, time_ranges, use_scale_stamps)]
    resolved = _resolve_backend(backend)
    max_tr = max((tr for _, tr in series), default=0)
    if resolved != "pallas" or max_tr == 0 or not series:
        return [_numpy_metrics(b, tr) for b, tr in series]
    from repro.kernels import ops
    try:
        hist, mom, _ = ops.stream_metrics_batched(
            [b for b, _ in series], max_tr)
    except ops.PallasDomainError:
        return [_numpy_metrics(b, tr) for b, tr in series]
    hist = np.asarray(hist, np.int64)
    mom = np.asarray(mom, np.float64)
    return [StreamMetrics(hist[s, :tr],
                          _volatility_from_moments(mom[s, 0], mom[s, 1], tr))
            for s, (_, tr) in enumerate(series)]


# ------------------------------------------------------------------- trend
def sliding_mean(q: np.ndarray, window: int) -> np.ndarray:
    """O(n) cumulative-sum sliding mean, same semantics as
    ``np.convolve(q, np.ones(w)/w, mode="same")`` (zero-padded edges,
    constant 1/w weight) but without the O(n·w) inner product."""
    n = len(q)
    if n == 0:
        return q.astype(np.float64)
    w = max(min(window, n), 1)
    half = (w - 1) // 2
    # out[i] = (c[min(i+half+1, n)] - c[max(i+half+1-w, 0)]) / w over the
    # exclusive prefix sums c, written as three plain slice subtractions
    # (clamped head / core / clamped tail) with no index-array gathers and
    # only two allocations, so the O(n) path stays memory-bound
    c = np.empty(n + 1, np.float64)
    c[0] = 0.0
    np.cumsum(q, out=c[1:])
    out = np.empty(n, np.float64)
    head, tail = w - half - 1, half
    np.subtract(c[w:], c[:n + 1 - w], out=out[head:n - tail])
    out[:head] = c[half + 1:w]                       # lo clamped to 0
    np.subtract(c[n], c[n + 1 - w:n + 1 - w + tail],
                out=out[n - tail:])                  # hi clamped to n
    out /= w
    return out


def trend(stream: Stream, window_s: int = 600,
          time_range: Optional[int] = None,
          *, backend: str = "numpy") -> np.ndarray:
    """Moving-average trend of the per-second counts (the Figs. 1-3 curves).

    The window mean is computed by the cumsum sliding mean on every backend;
    ``backend`` selects where the underlying counts come from.
    """
    q = per_second_counts(stream, time_range, backend=backend)
    return sliding_mean(q.astype(np.float64), window_s)


def trend_correlation_from_counts(qa: np.ndarray, qb: np.ndarray,
                                  window_s: int = 60) -> float:
    """Pearson correlation between two count series' trends, resampled to
    the shorter series — quantifies the paper's 'similar trend' claim
    (Fig. 6). Takes precomputed counts so a batched metrics call can feed
    both streams without re-reading them."""
    ta = sliding_mean(np.asarray(qa, np.float64), window_s)
    tb = sliding_mean(np.asarray(qb, np.float64), window_s)
    if len(ta) == 0 or len(tb) == 0:
        return float("nan")
    n = min(len(ta), len(tb))
    # resample both to n points
    ra = np.interp(np.linspace(0, 1, n), np.linspace(0, 1, len(ta)), ta)
    rb = np.interp(np.linspace(0, 1, n), np.linspace(0, 1, len(tb)), tb)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / denom) if denom > 0 else float("nan")


def trend_correlation(a: Stream, b: Stream, window_s: int = 60,
                      *, backend: str = "numpy") -> float:
    """Trend correlation of two streams (counts computed here; when counts
    are already in hand use :func:`trend_correlation_from_counts`)."""
    return trend_correlation_from_counts(
        per_second_counts(a, backend=backend),
        per_second_counts(b, backend=backend), window_s)
