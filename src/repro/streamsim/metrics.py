"""Volatility & trend metrics (paper §5.2, formulas (2)-(4)).

The paper evaluates simulation quality with three per-second statistics —
Average, Variance, Standard Variance — over the arrival-count series
``q_i`` (records in second ``i``). Formulas (3)/(4) in the paper text drop
the square on the deviation (an obvious typesetting slip); we implement the
standard population variance/σ, which reproduces the tables' magnitudes.

Backends
--------
Every metric takes the same ``backend="numpy|pallas|auto"`` knob as
:func:`repro.streamsim.nsa.nsa`:

- ``"numpy"`` — vectorized host path (one ``bincount`` pass + exact f64
  moments).
- ``"pallas"`` — the fused device engine
  (:func:`repro.kernels.ops.stream_metrics`): histogram AND moments from one
  pass over the record tiles, int32-exact counts.
- ``"auto"`` — pallas on TPU, numpy otherwise.

Counts are **bit-exact** across backends; the engine's raw ``[Σq, Σq²]``
moments agree with exact f64 within ~1e-5 relative (pairwise-block + Kahan
f32 reduction in the kernel); derived moments (average / variance / σ)
keep the documented 1e-3 relative tolerance (the variance subtraction can
amplify the moment error).

:func:`metrics_batched` evaluates S streams — possibly with different time
ranges — through ONE batched engine dispatch; the sweep engine
(:mod:`repro.streamsim.engine`) uses it for host-side streams (originals,
store-cache hits) and the device-input ops forms
(``ops.stream_metrics_batched_device``, ``ops.trend_corr_pairwise``) for
store-missing scenarios, so the whole reporting path re-reads each stream
once instead of ~4 times and never gathers kept stamps to host.

:func:`trend` is an O(n) cumulative-sum sliding mean (window sums via two
prefix-sum lookups), replacing the seed's O(n·window) ``np.convolve``. On
the pallas backend the cumsum is the device scan kernel
(:mod:`repro.kernels.trend_scan`); only the O(time_range) count series
crosses host for the domain guard — the O(records) histogramming and the
scan itself stay on device.

:func:`trend_correlation_matrix` evaluates the Fig.-6 "similar trend"
claim for ALL S×S stream pairs at once: on the pallas backend the whole
chain — counts → prefix-sum scan → sliding-mean trends → resample →
centered Gram matrix — is one batched device dispatch chain (no per-pair
host loop); the numpy backend mirrors it in float64. Out-of-domain inputs
(totals past the int32 prefix-sum limit) fall back to numpy via
:class:`repro.kernels.ops.PallasDomainError`, like every other metric.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.streamsim.nsa import BACKENDS, _resolve_backend  # noqa: F401
from repro.streamsim.preprocess import Stream


@dataclasses.dataclass(frozen=True)
class Volatility:
    average: float
    variance: float
    std_variance: float
    time_range: int

    def as_row(self) -> str:
        return (f"{self.time_range},{self.average:.2f},"
                f"{self.variance:.2f},{self.std_variance:.2f}")


@dataclasses.dataclass(frozen=True)
class StreamMetrics:
    """One stream's reporting bundle from a single engine pass."""

    counts: np.ndarray          # int64 (time_range,) per-second counts q_i
    volatility: Volatility


# --------------------------------------------------------------- bucketing
def _bucket_series(stream: Stream, time_range: Optional[int],
                   use_scale_stamp: Optional[bool]):
    """Integer bucket per record + the series length (shared by backends).

    For simulated streams the bucket is ``scale_stamp``; for original
    streams it is ``floor(t - t_0)``. Returns ``(buckets int64, time_range)``
    — ``time_range`` 0 means the empty/degenerate series.
    """
    if use_scale_stamp is None:
        use_scale_stamp = stream.scale_stamp is not None
    if use_scale_stamp:
        if stream.scale_stamp is None:
            raise ValueError("stream has no scale_stamp; run NSA first")
        buckets = np.asarray(stream.scale_stamp, np.int64)
        if time_range is None:
            time_range = int(buckets.max()) + 1 if len(buckets) else 0
        elif len(buckets):
            # scale stamps are never clipped to a user time range (seed
            # bincount semantics: the series covers max(tr, max stamp + 1)
            # seconds), so a too-small tr expands rather than mis-binning
            # on numpy or raising on pallas
            time_range = max(time_range, int(buckets.max()) + 1)
    else:
        if len(stream.t) == 0:
            return np.zeros(0, np.int64), (time_range or 0)
        buckets = np.floor(stream.t - stream.t[0]).astype(np.int64)
        if time_range is None:
            time_range = int(buckets.max()) + 1
        buckets = np.clip(buckets, 0, time_range - 1)
    return buckets, time_range


def _volatility_from_moments(s: float, s2: float, tr: int) -> Volatility:
    if tr <= 0:
        return Volatility(0.0, 0.0, 0.0, 0)
    avg = s / tr
    var = max(s2 / tr - avg * avg, 0.0)
    return Volatility(float(avg), float(var), float(np.sqrt(var)), tr)


def _numpy_metrics(buckets: np.ndarray, tr: int) -> StreamMetrics:
    q = np.bincount(buckets, minlength=tr)
    s = float(q.sum())
    s2 = float((q.astype(np.float64) ** 2).sum())
    return StreamMetrics(q, _volatility_from_moments(s, s2, tr))


# ------------------------------------------------------------- public API
def per_second_counts(stream: Stream, time_range: Optional[int] = None,
                      *, use_scale_stamp: Optional[bool] = None,
                      backend: str = "numpy") -> np.ndarray:
    """Arrival counts q_i per (simulated or original) second.

    Parameters
    ----------
    stream : Stream
    time_range : int, optional
        Series length. ``None`` infers it: the NSA ``max_range``
        convention (``max scale_stamp + 1``) for simulated streams, the
        spanned seconds for originals. A ``time_range`` smaller than the
        largest scale stamp *expands* (seed bincount semantics) rather
        than mis-binning.
    use_scale_stamp : bool, optional
        Force bucketing by ``scale_stamp`` (simulated) or by wall time
        (original); ``None`` picks by whether ``scale_stamp`` is set.
    backend : {"numpy", "pallas", "auto"}
        ``"pallas"`` counts through the fused device engine
        (:func:`repro.kernels.ops.stream_metrics`, int32 accumulation —
        exact up to 2³¹ per bucket, guarded); ``"auto"`` is pallas on TPU.

    Returns
    -------
    np.ndarray, int64, shape (time_range,)
        **Bit-exact across backends.**
    """
    buckets, tr = _bucket_series(stream, time_range, use_scale_stamp)
    if _resolve_backend(backend) == "pallas" and tr > 0:
        from repro.kernels import ops
        hist, _ = ops.stream_metrics(buckets, tr)
        return np.asarray(hist, np.int64)
    return np.bincount(buckets, minlength=tr)


def volatility(stream: Stream, time_range: Optional[int] = None,
               *, backend: str = "numpy") -> Volatility:
    """Average / Variance / StdVariance of q_i (paper formulas (2)-(4)).

    Parameters
    ----------
    stream, time_range :
        As in :func:`per_second_counts`.
    backend : {"numpy", "pallas", "auto"}
        ``"numpy"`` reduces exact f64 moments on host; ``"pallas"`` reads
        the ``[Σq, Σq²]`` pair the fused engine produced in the same
        record pass as the histogram (f32 reduction — agrees with numpy
        within 1e-3 relative).

    Returns
    -------
    Volatility
        ``average``, ``variance``, ``std_variance`` over the count series,
        plus the ``time_range`` they were normalized by.
    """
    buckets, tr = _bucket_series(stream, time_range, None)
    if _resolve_backend(backend) == "pallas" and tr > 0:
        from repro.kernels import ops
        _, mom = ops.stream_metrics(buckets, tr)
        mom = np.asarray(mom, np.float64)
        return _volatility_from_moments(mom[0], mom[1], tr)
    return _numpy_metrics(buckets, tr).volatility


def metrics_batched(streams: Sequence[Stream],
                    time_ranges: Sequence[Optional[int]],
                    *, use_scale_stamps: Optional[Sequence[Optional[bool]]]
                    = None, backend: str = "auto",
                    autotune: Optional[str] = None) -> List[StreamMetrics]:
    """Counts + volatility for S streams from ONE batched engine call.

    Parameters
    ----------
    streams : sequence of Stream
        Ragged lengths, mixed simulated/original, and empty/degenerate
        members are all allowed.
    time_ranges : sequence of int or None
        Per-stream series length (``None`` infers it — see
        :func:`per_second_counts`). Must align with ``streams``.
    use_scale_stamps : sequence of bool or None, optional
        Per-stream ``use_scale_stamp`` override.
    backend : {"numpy", "pallas", "auto"}
        On ``"pallas"`` all S histograms and moment pairs come from a
        single 2-D-grid kernel dispatch padded to the largest time range —
        trailing zero buckets perturb neither counts nor moments;
        per-stream statistics divide by the true range. Inputs outside the
        engine's int32 domain fall back to numpy wholesale (the ops layer
        raises :class:`~repro.kernels.ops.PallasDomainError`, caught
        here).

    Returns
    -------
    list of StreamMetrics
        ``counts`` bit-exact across backends; ``volatility`` within 1e-3.

    Raises
    ------
    ValueError
        If ``streams`` and ``time_ranges`` lengths differ.
    """
    if len(streams) != len(time_ranges):
        raise ValueError("streams and time_ranges must align")
    if use_scale_stamps is None:
        use_scale_stamps = [None] * len(streams)
    series = [_bucket_series(s, tr, uss)
              for s, tr, uss in zip(streams, time_ranges, use_scale_stamps)]
    resolved = _resolve_backend(backend)
    max_tr = max((tr for _, tr in series), default=0)
    if resolved != "pallas" or max_tr == 0 or not series:
        return [_numpy_metrics(b, tr) for b, tr in series]
    from repro.kernels import ops, tuning
    try:
        with tuning.tuner_context(autotune):
            hist, mom, _ = ops.stream_metrics_batched(
                [b for b, _ in series], max_tr)
    except ops.PallasDomainError:
        return [_numpy_metrics(b, tr) for b, tr in series]
    hist = np.asarray(hist, np.int64)
    mom = np.asarray(mom, np.float64)
    return [StreamMetrics(hist[s, :tr],
                          _volatility_from_moments(mom[s, 0], mom[s, 1], tr))
            for s, (_, tr) in enumerate(series)]


# ------------------------------------------------------------------- trend
def sliding_mean(q: np.ndarray, window: int) -> np.ndarray:
    """O(n) cumulative-sum sliding mean, same semantics as
    ``np.convolve(q, np.ones(w)/w, mode="same")`` (zero-padded edges,
    constant 1/w weight) but without the O(n·w) inner product."""
    n = len(q)
    if n == 0:
        return q.astype(np.float64)
    w = max(min(window, n), 1)
    half = (w - 1) // 2
    # out[i] = (c[min(i+half+1, n)] - c[max(i+half+1-w, 0)]) / w over the
    # exclusive prefix sums c, written as three plain slice subtractions
    # (clamped head / core / clamped tail) with no index-array gathers and
    # only two allocations, so the O(n) path stays memory-bound
    c = np.empty(n + 1, np.float64)
    c[0] = 0.0
    np.cumsum(q, out=c[1:])
    out = np.empty(n, np.float64)
    head, tail = w - half - 1, half
    np.subtract(c[w:], c[:n + 1 - w], out=out[head:n - tail])
    out[:head] = c[half + 1:w]                       # lo clamped to 0
    np.subtract(c[n], c[n + 1 - w:n + 1 - w + tail],
                out=out[n - tail:])                  # hi clamped to n
    out /= w
    return out


def trend(stream: Stream, window_s: int = 600,
          time_range: Optional[int] = None,
          *, backend: str = "numpy") -> np.ndarray:
    """Moving-average trend of the per-second counts (the Figs. 1-3 curves).

    Parameters
    ----------
    stream : Stream
        Simulated (``scale_stamp`` set) or original stream.
    window_s : int, default 600
        Sliding-mean window in (simulated) seconds; clamped per series to
        ``max(min(window_s, n), 1)``.
    time_range : int, optional
        Series length; ``None`` infers it (see :func:`per_second_counts`).
    backend : {"numpy", "pallas", "auto"}
        ``"numpy"`` computes counts + an O(n) host cumsum sliding mean in
        float64. ``"pallas"`` chains the fused metrics engine into the
        device prefix-sum scan kernel (:func:`repro.kernels.ops.
        trend_scan`) — window sums are int32-exact, the final divide is
        f32, so the result agrees with numpy within 1e-3 relative.
        ``"auto"`` is pallas on TPU, numpy otherwise.

    Returns
    -------
    np.ndarray, float64, shape (time_range,)

    Notes
    -----
    Inputs past the device domain (total counts ≥ 2³¹) raise
    :class:`~repro.kernels.ops.PallasDomainError` inside the ops layer;
    this function catches it and falls back to the numpy path, so callers
    never see silently wrong trends.
    """
    buckets, tr = _bucket_series(stream, time_range, None)
    if _resolve_backend(backend) == "pallas" and tr > 0:
        from repro.kernels import ops
        try:
            hist, _ = ops.stream_metrics(buckets, tr)
            return np.asarray(ops.trend_scan(np.asarray(hist),
                                             max(window_s, 1)), np.float64)
        except ops.PallasDomainError:
            pass  # counts outside the int32 scan domain -> host path
    q = np.bincount(buckets, minlength=tr)
    return sliding_mean(q.astype(np.float64), window_s)


def trend_correlation_from_counts(qa: np.ndarray, qb: np.ndarray,
                                  window_s: int = 60) -> float:
    """Pearson correlation between two count series' trends, resampled to
    the shorter series — quantifies the paper's 'similar trend' claim
    (Fig. 6). Takes precomputed counts so a batched metrics call can feed
    both streams without re-reading them."""
    ta = sliding_mean(np.asarray(qa, np.float64), window_s)
    tb = sliding_mean(np.asarray(qb, np.float64), window_s)
    if len(ta) == 0 or len(tb) == 0:
        return float("nan")
    n = min(len(ta), len(tb))
    # resample both to n points
    ra = np.interp(np.linspace(0, 1, n), np.linspace(0, 1, len(ta)), ta)
    rb = np.interp(np.linspace(0, 1, n), np.linspace(0, 1, len(tb)), tb)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / denom) if denom > 0 else float("nan")


def trend_correlation(a: Stream, b: Stream, window_s: int = 60,
                      *, backend: str = "numpy") -> float:
    """Trend correlation of two streams.

    When counts are already in hand use
    :func:`trend_correlation_from_counts` (numpy) or
    :func:`trend_correlation_matrix` (batched, either backend).

    Parameters
    ----------
    a, b : Stream
    window_s : int, default 60
        Sliding-mean window for both trends.
    backend : {"numpy", "pallas", "auto"}
        ``"pallas"`` runs the device chain of
        :func:`trend_correlation_matrix` on the pair (one batched dispatch,
        agreeing with numpy within 1e-3); out-of-domain inputs fall back to
        the numpy path automatically.

    Returns
    -------
    float
        Pearson r in [-1, 1]; NaN when either series is empty or has zero
        trend variance.
    """
    qa = per_second_counts(a, backend=backend)
    qb = per_second_counts(b, backend=backend)
    if _resolve_backend(backend) == "pallas":
        from repro.kernels import ops
        try:
            return float(ops.trend_correlation_batched(
                [qa, qb], max(window_s, 1))[0, 1])
        except ops.PallasDomainError:
            pass  # totals outside the int32 scan domain -> host path
    return trend_correlation_from_counts(qa, qb, window_s)


# ------------------------------------------------- S x S correlation matrix
def _corr_matrix_numpy(counts: Sequence[np.ndarray], window_s: int,
                       n_points: Optional[int]) -> np.ndarray:
    """Float64 host mirror of :func:`repro.kernels.ops.
    trend_correlation_batched`: same resample-to-common-grid convention,
    same NaN/clip/diagonal contract."""
    from repro.kernels.ops import _corr_from_gram
    trends = [sliding_mean(np.asarray(q, np.float64), window_s)
              for q in counts]
    S = len(trends)
    live = [s for s in range(S) if len(trends[s])]
    if not live:
        return np.full((S, S), np.nan)
    K = int(n_points) if n_points is not None else \
        min(len(trends[s]) for s in live)
    if K < 1:
        raise ValueError("n_points must be >= 1")
    grid = np.linspace(0.0, 1.0, K)
    z = np.stack([np.interp(grid, np.linspace(0.0, 1.0, len(trends[s])),
                            trends[s]) for s in live])
    z -= z.mean(axis=1, keepdims=True)
    return _corr_from_gram(z @ z.T, np.asarray(live), S)


def trend_correlation_matrix(counts: Sequence[np.ndarray],
                             window_s: int = 60, *,
                             n_points: Optional[int] = None,
                             backend: str = "auto",
                             autotune: Optional[str] = None) -> np.ndarray:
    """Pearson trend-correlation matrix for ALL S×S count-series pairs.

    The batched form of the Fig.-6 fidelity check: every series' sliding-
    mean trend is resampled onto a common uniform grid (``n_points``
    points, default the shortest non-empty series' length), mean-centered,
    and correlated against every other.

    Parameters
    ----------
    counts : sequence of 1-D integer arrays
        Per-second count series (e.g. ``StreamMetrics.counts`` rows from
        :func:`metrics_batched`), ragged lengths allowed.
    window_s : int, default 60
        Sliding-mean window applied to every series (must be >= 1).
    n_points : int, optional
        Common resampling grid size; defaults to the shortest non-empty
        series' length, which for two series reproduces the pairwise
        :func:`trend_correlation_from_counts` convention.
    backend : {"numpy", "pallas", "auto"}
        ``"pallas"`` runs counts → prefix-sum scan → trends → resample →
        centered S×S Gram through ONE batched device dispatch chain
        (:func:`repro.kernels.ops.trend_correlation_batched`) — no
        per-pair host loop and no host cumsum. ``"numpy"`` mirrors the
        convention in float64; the backends agree within 1e-3.

    Returns
    -------
    np.ndarray, float64, shape (S, S)
        Symmetric, clipped to [-1, 1], diagonal exactly 1 for series with
        non-zero trend variance; rows/columns of empty or zero-variance
        series are NaN.

    Raises
    ------
    ValueError
        If ``window_s < 1`` or ``n_points < 1``. Device-domain violations
        (totals ≥ 2³¹) do NOT raise here — they fall back to numpy.
    """
    if window_s < 1:
        raise ValueError("window_s must be >= 1")
    counts = [np.asarray(q).reshape(-1) for q in counts]
    if _resolve_backend(backend) == "pallas" and counts:
        from repro.kernels import ops, tuning
        try:
            with tuning.tuner_context(autotune):
                return ops.trend_correlation_batched(counts, window_s,
                                                     n_points)
        except ops.PallasDomainError:
            pass  # totals outside the int32 scan domain -> host path
    return _corr_matrix_numpy(counts, window_s, n_points)
