"""Volatility & trend metrics (paper §5.2, formulas (2)-(4)).

The paper evaluates simulation quality with three per-second statistics —
Average, Variance, Standard Variance — over the arrival-count series
``q_i`` (records in second ``i``). Formulas (3)/(4) in the paper text drop
the square on the deviation (an obvious typesetting slip); we implement the
standard population variance/σ, which reproduces the tables' magnitudes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.streamsim.preprocess import Stream


@dataclasses.dataclass(frozen=True)
class Volatility:
    average: float
    variance: float
    std_variance: float
    time_range: int

    def as_row(self) -> str:
        return (f"{self.time_range},{self.average:.2f},"
                f"{self.variance:.2f},{self.std_variance:.2f}")


def per_second_counts(stream: Stream, time_range: Optional[int] = None,
                      *, use_scale_stamp: Optional[bool] = None) -> np.ndarray:
    """Arrival counts q_i per (simulated or original) second.

    For simulated streams the bucket is ``scale_stamp``; for original streams
    it is ``floor(t - t_0)``.
    """
    if use_scale_stamp is None:
        use_scale_stamp = stream.scale_stamp is not None
    if use_scale_stamp:
        if stream.scale_stamp is None:
            raise ValueError("stream has no scale_stamp; run NSA first")
        buckets = stream.scale_stamp
        if time_range is None:
            time_range = int(buckets.max()) + 1 if len(buckets) else 0
    else:
        if len(stream.t) == 0:
            return np.zeros(0, dtype=np.int64)
        buckets = np.floor(stream.t - stream.t[0]).astype(np.int64)
        if time_range is None:
            time_range = int(buckets.max()) + 1
        buckets = np.clip(buckets, 0, time_range - 1)
    return np.bincount(buckets, minlength=time_range)


def volatility(stream: Stream, time_range: Optional[int] = None) -> Volatility:
    """Average / Variance / StdVariance of q_i (paper formulas (2)-(4))."""
    q = per_second_counts(stream, time_range)
    tr = len(q)
    if tr == 0:
        return Volatility(0.0, 0.0, 0.0, 0)
    avg = float(q.mean())
    var = float(((q - avg) ** 2).mean())
    return Volatility(avg, var, float(np.sqrt(var)), tr)


def trend(stream: Stream, window_s: int = 600,
          time_range: Optional[int] = None) -> np.ndarray:
    """Moving-average trend of the per-second counts (the Figs. 1-3 curves)."""
    q = per_second_counts(stream, time_range).astype(np.float64)
    if len(q) == 0:
        return q
    w = min(window_s, len(q))
    kernel = np.ones(w) / w
    return np.convolve(q, kernel, mode="same")


def trend_correlation(a: Stream, b: Stream, window_s: int = 60) -> float:
    """Pearson correlation between two streams' trends, resampled to the
    shorter series — quantifies the paper's 'similar trend' claim (Fig. 6)."""
    ta, tb = trend(a, window_s), trend(b, window_s)
    if len(ta) == 0 or len(tb) == 0:
        return float("nan")
    n = min(len(ta), len(tb))
    # resample both to n points
    ra = np.interp(np.linspace(0, 1, n), np.linspace(0, 1, len(ta)), ta)
    rb = np.interp(np.linspace(0, 1, n), np.linspace(0, 1, len(tb)), tb)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / denom) if denom > 0 else float("nan")
