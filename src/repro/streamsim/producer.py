"""PSDA — Producing Stream Data (paper Algorithm 2).

The paper's producer loads the simulated stream from the database and emits
the records of scale-stamp second ``i`` at wall-clock second ``i``, each emit
scheduling the next via ``threading.Timer`` (a chained-timer parallel send).

Two clocks are provided:

- :class:`RealClock` — faithful to the paper: chained ``threading.Timer``
  ticks, one bucket per wall-clock second (for live demos / load tests).
- :class:`VirtualClock` — identical ordering/batching semantics but time
  advances instantly; this is what tests and CPU benchmarks use, so a
  600-second simulation does not sleep for 10 minutes. The *consumer* still
  observes the same bucket sequence with the same emit_time stamps.

Emitting a bucket means a single vectorized slice (records are pre-grouped by
scale_stamp), not a per-record loop — the beyond-paper optimization; the
per-record variant is kept for the §Perf baseline comparison.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.streamsim.preprocess import Stream
from repro.streamsim.queue import Bucket, StreamQueue

STATUS_SUCCESS = 0  # paper: success:0
STATUS_FAULT = 1    # paper: fault:1


class VirtualClock:
    """Simulated time: sleep() advances a counter instantly."""

    def __init__(self):
        self.now = 0.0

    def sleep(self, s: float) -> None:
        self.now += s

    def time(self) -> float:
        return self.now


class RealClock:
    """Wall-clock time (the paper's timer-thread behaviour)."""

    def sleep(self, s: float) -> None:
        time.sleep(s)

    def time(self) -> float:
        return time.time()


def _group_by_scale_stamp(stream: Stream):
    """Pre-slice the stream into per-bucket views (sorted by construction).

    ``np.unique(ss, return_index=True)`` on the non-decreasing stamps gives
    every non-empty bucket's first offset in one vectorized pass, so host
    work is O(n + #non-empty buckets) instead of a Python loop over the full
    ``max_range`` (which dominates for sparse simulated streams).
    """
    ss = stream.scale_stamp
    if ss is None:
        raise ValueError("producer needs a simulated stream (run NSA first)")
    if len(ss) == 0:
        return {}, 0
    max_range = int(ss[-1]) + 1
    buckets, first = np.unique(ss, return_index=True)
    bounds = np.append(first, len(ss))
    slices = {int(b): slice(int(lo), int(hi))
              for b, lo, hi in zip(buckets, bounds[:-1], bounds[1:])}
    return slices, max_range


class Producer:
    """Sends the simulated stream to the SPS in chronological order.

    ``run()`` returns the paper's status code (success:0 / fault:1)."""

    def __init__(self, stream: Stream, queue: StreamQueue,
                 clock: Optional[object] = None,
                 tick_s: float = 1.0,
                 on_emit: Optional[Callable[[Bucket], None]] = None):
        self.stream = stream
        self.queue = queue
        self.clock = clock if clock is not None else VirtualClock()
        self.tick_s = tick_s
        self.on_emit = on_emit
        self.emitted_buckets = 0
        self.emitted_records = 0

    # ------------------------------------------------------------- emission
    def _emit(self, b: int, sl: slice) -> None:
        bucket = Bucket(
            scale_stamp=b,
            t=self.stream.t[sl],
            payload={k: v[sl] for k, v in self.stream.payload.items()},
            emit_time=self.clock.time(),
        )
        self.queue.put(bucket)
        self.emitted_buckets += 1
        self.emitted_records += len(bucket)
        if self.on_emit is not None:
            self.on_emit(bucket)

    # ------------------------------------------------------------ main loop
    def run(self) -> int:
        """Virtual-time run (default): tick per simulated second, in order.

        Under a :class:`VirtualClock` the sleeps across empty-bucket gaps
        are batched into one ``sleep(gap * tick_s)`` call, so host work is
        O(#non-empty buckets) instead of O(max_range) — sparse simulated
        streams (large ``max_range``, few records) no longer pay a Python
        tick per empty second. The consumer-observable behaviour (bucket
        sequence, per-bucket ``emit_time``, final clock value) is identical
        to per-second ticking; any other clock keeps the paper's literal
        one-``sleep``-per-second loop (:meth:`_run_per_tick`).
        """
        try:
            if isinstance(self.clock, VirtualClock):
                # max_range is the last stamp + 1, so the final emit always
                # lands on the last simulated second — no trailing gap
                slices, _ = _group_by_scale_stamp(self.stream)
                prev = -1
                for b, sl in slices.items():   # sorted: stamps non-decreasing
                    self.clock.sleep((b - prev) * self.tick_s)
                    self._emit(b, sl)          # if len(block) != 0: P(block)
                    prev = b
                self.queue.close()
                return STATUS_SUCCESS
            return self._run_per_tick()
        except Exception:
            self.queue.close()
            return STATUS_FAULT

    def _run_per_tick(self) -> int:
        """The per-second loop (RealClock path, and the equivalence oracle
        for the gap-batched virtual run)."""
        try:
            slices, max_range = _group_by_scale_stamp(self.stream)
            for b in range(max_range):
                self.clock.sleep(self.tick_s)  # paper: time.sleep(1)
                if b in slices:                # if len(block) != 0: P(block)
                    self._emit(b, slices[b])
            self.queue.close()
            return STATUS_SUCCESS
        except Exception:
            self.queue.close()
            return STATUS_FAULT

    def run_threaded(self) -> int:
        """Paper-faithful chained ``threading.Timer`` emission (RealClock).

        Each tick schedules the next (Algorithm 2's ``emit`` defining
        ``timer <- threading.Timer(1.0, emit, [ite+1])``); the main thread
        plays the watchdog loop ("Detecting lived emit thread").
        """
        slices, max_range = _group_by_scale_stamp(self.stream)
        done = threading.Event()
        status = [STATUS_SUCCESS]

        def emit(ite: int) -> None:
            try:
                if ite >= max_range:
                    done.set()
                    return
                timer = threading.Timer(self.tick_s, emit, [ite + 1])
                timer.daemon = True
                timer.start()
                if ite in slices:
                    self._emit(ite, slices[ite])
            except Exception:
                status[0] = STATUS_FAULT
                done.set()

        first = threading.Timer(self.tick_s, emit, [0])
        first.daemon = True
        first.start()
        while not done.wait(timeout=self.tick_s):  # While TRUE do / sleep(1)
            pass
        self.queue.close()
        return status[0]

    def stats(self) -> Dict[str, int]:
        return {
            "emitted_buckets": self.emitted_buckets,
            "emitted_records": self.emitted_records,
        }
