"""PSDA — Producing Stream Data (paper Algorithm 2).

The paper's producer loads the simulated stream from the database and emits
the records of scale-stamp second ``i`` at wall-clock second ``i``, each emit
scheduling the next via ``threading.Timer`` (a chained-timer parallel send).

Two clocks are provided:

- :class:`RealClock` — faithful to the paper: chained ``threading.Timer``
  ticks, one bucket per wall-clock second (for live demos / load tests).
- :class:`VirtualClock` — identical ordering/batching semantics but time
  advances instantly; this is what tests and CPU benchmarks use, so a
  600-second simulation does not sleep for 10 minutes. The *consumer* still
  observes the same bucket sequence with the same emit_time stamps.

Emitting a bucket means a single vectorized slice (records are pre-grouped by
scale_stamp), not a per-record loop — the beyond-paper optimization; the
per-record variant is kept for the §Perf baseline comparison.

:class:`MultiQueueProducer` is the batched-replay form: S scenarios'
non-empty buckets interleave in ONE loop over a merged scale-stamp
timeline, each scenario feeding its own bounded queue
(:class:`repro.streamsim.queue.QueueGroup`) — so a whole (dataset ×
max_range) sweep replays with one loop's host work instead of S sequential
loops, while every scenario's consumer observes exactly the sequence of a
sequential :meth:`Producer.run` (and, under the virtual clock, the exact
``emit_time`` stamps too). Under a :class:`RealClock` the loop is a
heap-based timer wheel: one wall-clock loop fires every scenario's bucket
at its due second, so live demos can drive several SPS consumers at once
without one timer thread per stream.

Fault injection (chaos layer)
-----------------------------
Both producers accept a seeded fault schedule
(:mod:`repro.streamsim.faults`): ``Producer(faults=<FaultInjector>)`` and
``MultiQueueProducer(fault_plan=<FaultPlan>)``. Scheduled drops,
duplicates, bounded reorders, delay jitter, and producer stalls are
applied at the emission point; every event is counted and surfaced in
``stats()`` (``fault_*`` keys, present only when a schedule is attached),
so per-scenario delivery reconciles as ``delivered == emitted - dropped +
duplicated``. A no-op schedule leaves the replay **bit-identical** to the
fault-free pipeline.

Both multi-queue walks also tolerate a member queue being closed under
them (the engine's consumer-deadline watchdog does exactly that to shed a
wedged scenario): the dead scenario's remaining buckets are counted as
``aborted_buckets`` and every other scenario replays to completion,
instead of the whole sweep loop dying on the first
``RuntimeError("queue closed")``.
"""

from __future__ import annotations

import heapq
import threading
import time
from typing import Callable, Dict, Mapping, Optional

import numpy as np

from repro.streamsim.faults import FaultInjector, FaultPlan
from repro.streamsim.preprocess import Stream
from repro.streamsim.queue import Bucket, StreamQueue

STATUS_SUCCESS = 0  # paper: success:0
STATUS_FAULT = 1    # paper: fault:1


class VirtualClock:
    """Simulated time: sleep() advances a counter instantly."""

    def __init__(self):
        self.now = 0.0

    def sleep(self, s: float) -> None:
        self.now += s

    def time(self) -> float:
        return self.now


class RealClock:
    """Wall-clock time (the paper's timer-thread behaviour)."""

    def sleep(self, s: float) -> None:
        time.sleep(s)

    def time(self) -> float:
        return time.time()


def _group_by_scale_stamp(stream: Stream):
    """Pre-slice the stream into per-bucket views (sorted by construction).

    ``np.unique(ss, return_index=True)`` on the non-decreasing stamps gives
    every non-empty bucket's first offset in one vectorized pass, so host
    work is O(n + #non-empty buckets) instead of a Python loop over the full
    ``max_range`` (which dominates for sparse simulated streams).
    """
    ss = stream.scale_stamp
    if ss is None:
        raise ValueError("producer needs a simulated stream (run NSA first)")
    if len(ss) == 0:
        return {}, 0
    max_range = int(ss[-1]) + 1
    buckets, first = np.unique(ss, return_index=True)
    bounds = np.append(first, len(ss))
    slices = {int(b): slice(int(lo), int(hi))
              for b, lo, hi in zip(buckets, bounds[:-1], bounds[1:])}
    return slices, max_range


def _dup_bucket(bucket: Bucket) -> Bucket:
    """A duplicate delivery: fresh Bucket object, shared column views
    (the transport re-sent the message, it did not copy the records)."""
    return Bucket(scale_stamp=bucket.scale_stamp, t=bucket.t,
                  payload=bucket.payload, emit_time=bucket.emit_time)


class ChunkFeed:
    """Bounded hand-off of time-chunk :class:`Stream` s from the chunked
    engine (:class:`~repro.streamsim.engine.ChunkedSweepRunner`) to the
    replay walk — the piece that makes multi-day replay run in bounded
    host memory.

    One feed per scenario. The engine ``put()`` s chunk ``k`` as soon as
    its host gather lands; the producer ``get()`` s chunks in order and
    replays them. Both sides block on a :class:`threading.Condition` —
    a full feed stalls the engine (backpressure), an empty feed stalls
    the producer (**no busy-wait**: the producer thread sleeps in
    ``Condition.wait`` until the engine's next ``put`` or ``close``).
    ``close()`` marks the end of the scenario's timeline; ``get`` then
    drains the remaining chunks and returns ``None``.

    ``stats()`` exposes the bounded-residency proof:
    ``feed_hwm_chunks`` is the high-watermark of chunks simultaneously
    resident in the feed (≤ ``maxsize`` by construction — the acceptance
    bound "peak host buckets ≤ 2 chunks per scenario"), and
    ``feed_chunks`` the total handed through.
    """

    def __init__(self, maxsize: int = 2):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = maxsize
        self._items: list = []
        self._cond = threading.Condition()
        self._closed = False
        self.hwm = 0
        self.total = 0

    @property
    def closed(self) -> bool:
        return self._closed

    def put(self, stream: Stream, timeout: Optional[float] = None) -> None:
        with self._cond:
            while len(self._items) >= self.maxsize and not self._closed:
                if not self._cond.wait(timeout=timeout):
                    raise TimeoutError("ChunkFeed.put timed out")
            if self._closed:
                raise RuntimeError("feed closed")
            self._items.append(stream)
            self.total += 1
            self.hwm = max(self.hwm, len(self._items))
            self._cond.notify_all()

    def get(self, timeout: Optional[float] = None) -> Optional[Stream]:
        """Next chunk in timeline order; blocks (no busy-wait) while the
        feed is empty and open; ``None`` once closed and drained."""
        with self._cond:
            while not self._items and not self._closed:
                if not self._cond.wait(timeout=timeout):
                    raise TimeoutError("ChunkFeed.get timed out")
            if self._items:
                item = self._items.pop(0)
                self._cond.notify_all()
                return item
            return None

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def stats(self) -> Dict[str, int]:
        return {"feed_hwm_chunks": self.hwm, "feed_chunks": self.total}


class Producer:
    """Sends the simulated stream to the SPS in chronological order.

    ``run()`` returns the paper's status code (success:0 / fault:1).
    ``faults`` optionally attaches one scenario's deterministic fault
    schedule (:class:`repro.streamsim.faults.FaultInjector`); the caller
    owns the schedule lifecycle (``reset()`` it before re-running the
    same stream, as the engine's retry path does)."""

    def __init__(self, stream: Stream, queue: StreamQueue,
                 clock: Optional[object] = None,
                 tick_s: float = 1.0,
                 on_emit: Optional[Callable[[Bucket], None]] = None,
                 faults: Optional[FaultInjector] = None):
        self.stream = stream
        self.queue = queue
        self.clock = clock if clock is not None else VirtualClock()
        self.tick_s = tick_s
        self.on_emit = on_emit
        self.faults = faults
        self.emitted_buckets = 0
        self.emitted_records = 0
        self.aborted_buckets = 0

    # ------------------------------------------------------------- emission
    def _emit(self, b: int, sl: slice) -> None:
        faults = self.faults
        if faults is None or faults.spec.is_noop:
            bucket = Bucket(
                scale_stamp=b,
                t=self.stream.t[sl],
                payload={k: v[sl] for k, v in self.stream.payload.items()},
                emit_time=self.clock.time(),
            )
            self.queue.put(bucket)
            self.emitted_buckets += 1
            self.emitted_records += len(bucket)
            if self.on_emit is not None:
                self.on_emit(bucket)
            return
        # chaos path: stall/jitter sleeps happen BEFORE the bucket is
        # stamped (the transport delayed the send, so emit_time moves)
        action = faults.draw()
        if action.stall_s > 0.0:
            self.clock.sleep(action.stall_s)
        if action.delay_s > 0.0:
            self.clock.sleep(action.delay_s)
        bucket = Bucket(
            scale_stamp=b,
            t=self.stream.t[sl],
            payload={k: v[sl] for k, v in self.stream.payload.items()},
            emit_time=self.clock.time(),
        )
        self.emitted_buckets += 1          # emissions count ATTEMPTS
        self.emitted_records += len(bucket)
        # earlier holds advance on EVERY emission (held ones included),
        # so a hold of n releases exactly n emissions later
        released = faults.release_due()
        if action.hold:                    # bounded reorder: park it
            faults.hold(bucket, action.hold)
        elif not action.drop:
            self.queue.put(bucket)
            if action.duplicate:
                self.queue.put(_dup_bucket(bucket))
            if self.on_emit is not None:
                self.on_emit(bucket)
        for rb in released:                # late-delivered held buckets
            self.queue.put(rb)

    def _flush_faults(self) -> None:
        """Deliver any still-held (reordered) buckets before close —
        bounded reorder never silently becomes a drop."""
        if self.faults is not None:
            for rb in self.faults.flush():
                self.queue.put(rb)

    # ------------------------------------------------------------ main loop
    def run(self) -> int:
        """Virtual-time run (default): tick per simulated second, in order.

        Under a :class:`VirtualClock` the sleeps across empty-bucket gaps
        are batched into one ``sleep(gap * tick_s)`` call, so host work is
        O(#non-empty buckets) instead of O(max_range) — sparse simulated
        streams (large ``max_range``, few records) no longer pay a Python
        tick per empty second. The consumer-observable behaviour (bucket
        sequence, per-bucket ``emit_time``, final clock value) is identical
        to per-second ticking; any other clock keeps the paper's literal
        one-``sleep``-per-second loop (:meth:`_run_per_tick`).
        """
        try:
            if isinstance(self.clock, VirtualClock):
                # max_range is the last stamp + 1, so the final emit always
                # lands on the last simulated second — no trailing gap
                slices, _ = _group_by_scale_stamp(self.stream)
                prev = -1
                for b, sl in slices.items():   # sorted: stamps non-decreasing
                    self.clock.sleep((b - prev) * self.tick_s)
                    self._emit(b, sl)          # if len(block) != 0: P(block)
                    prev = b
                self._flush_faults()
                self.queue.close()
                return STATUS_SUCCESS
            return self._run_per_tick()
        except Exception:
            self.queue.close()
            return STATUS_FAULT

    def _run_per_tick(self) -> int:
        """The per-second loop (RealClock path, and the equivalence oracle
        for the gap-batched virtual run)."""
        try:
            slices, max_range = _group_by_scale_stamp(self.stream)
            for b in range(max_range):
                self.clock.sleep(self.tick_s)  # paper: time.sleep(1)
                if b in slices:                # if len(block) != 0: P(block)
                    self._emit(b, slices[b])
            self._flush_faults()
            self.queue.close()
            return STATUS_SUCCESS
        except Exception:
            self.queue.close()
            return STATUS_FAULT

    def run_threaded(self) -> int:
        """Paper-faithful chained ``threading.Timer`` emission (RealClock).

        Each tick schedules the next (Algorithm 2's ``emit`` defining
        ``timer <- threading.Timer(1.0, emit, [ite+1])``); the main thread
        plays the watchdog loop ("Detecting lived emit thread").
        """
        slices, max_range = _group_by_scale_stamp(self.stream)
        done = threading.Event()
        status = [STATUS_SUCCESS]

        def emit(ite: int) -> None:
            try:
                if ite >= max_range:
                    done.set()
                    return
                timer = threading.Timer(self.tick_s, emit, [ite + 1])
                timer.daemon = True
                timer.start()
                if ite in slices:
                    self._emit(ite, slices[ite])
            except Exception:
                status[0] = STATUS_FAULT
                done.set()

        first = threading.Timer(self.tick_s, emit, [0])
        first.daemon = True
        first.start()
        while not done.wait(timeout=self.tick_s):  # While TRUE do / sleep(1)
            pass
        if status[0] == STATUS_SUCCESS:
            try:
                self._flush_faults()
            except Exception:
                status[0] = STATUS_FAULT
        self.queue.close()
        return status[0]

    def stats(self) -> Dict[str, int]:
        out = {
            "emitted_buckets": self.emitted_buckets,
            "emitted_records": self.emitted_records,
            "aborted_buckets": self.aborted_buckets,
        }
        if self.faults is not None:
            out.update(self.faults.stats())
        return out


class MultiQueueProducer:
    """Replays S simulated streams through S bounded queues in ONE loop.

    The batched counterpart of :class:`Producer`: every scenario's
    non-empty buckets are merged into a single ascending scale-stamp
    timeline, and one loop walks it. Per simulated second, every scenario
    with a bucket there emits it (in the scenarios' given order) to its
    own queue.

    Under a :class:`VirtualClock` (tests, CPU benchmarks,
    ``Controller.run_many``) the walk is the gap-batched virtual-time
    loop: each empty-second gap costs one ``sleep`` for the WHOLE sweep.
    Under any other clock (:class:`RealClock` — live demos driving
    several SPS consumers at once) the walk is a heap-based timer wheel
    (:meth:`_run_timer_wheel`): each merged event is popped from a heap
    keyed by its due wall time and emitted when that time arrives, so S
    scenarios replay off ONE wall-clock loop instead of S timer threads.

    Equivalence contract (tested): for each scenario the consumer observes
    exactly what a sequential ``Producer(stream, queue).run()`` produces —
    same bucket sequence, same queue stats, same producer stats, and each
    scenario's queue closes right after its last bucket. Under the
    virtual clock the per-bucket ``emit_time`` stamps are also identical
    (bucket ``b`` emits at clock ``(b + 1) * tick_s``); under a real
    clock ``emit_time`` is the wall time the wheel fired (the sequential
    real-clock producer's semantics). Only the shared loop's *final*
    clock value differs per scenario (it runs to the sweep's last stamp).

    Backpressure is shared: one full queue stalls the loop (and therefore
    every scenario) until its consumer drains — so consumers must run
    concurrently, one per queue.

    ``fault_plan`` attaches a seeded per-scenario fault schedule
    (:class:`repro.streamsim.faults.FaultPlan`); each scenario draws from
    its OWN deterministic RNG stream, so its schedule is identical to the
    one a sequential fault-injected :class:`Producer` replay would apply,
    regardless of how scenarios interleave. A member queue closed under
    the walk (the engine's consumer-deadline watchdog shedding a wedged
    scenario) only kills THAT scenario — its remaining buckets count as
    ``aborted_buckets`` and the walk continues; producer stalls, however,
    stall the whole merged walk (one transport, one loop — the
    broker-stall semantics).
    """

    def __init__(self, streams: Mapping, queues: Mapping,
                 clock: Optional[object] = None, tick_s: float = 1.0,
                 on_emit: Optional[Callable[[object, Bucket], None]] = None,
                 fault_plan: Optional[FaultPlan] = None):
        if set(streams) != set(queues):
            raise ValueError("streams and queues must share the same keys")
        self.streams = dict(streams)
        # chunked mode (PR 7): values are ChunkFeed s of time-chunk
        # streams instead of whole Stream s — all-or-nothing
        n_feeds = sum(isinstance(v, ChunkFeed) for v in self.streams.values())
        if n_feeds and n_feeds != len(self.streams):
            raise ValueError("mix of ChunkFeed and Stream values — chunked "
                             "replay is all-or-nothing per sweep")
        self.chunked = bool(n_feeds)
        self.queues = {k: queues[k] for k in self.streams}
        self.clock = clock if clock is not None else VirtualClock()
        self.tick_s = tick_s
        self.on_emit = on_emit
        self.fault_plan = fault_plan
        self.emitted_buckets: Dict[object, int] = {k: 0 for k in self.streams}
        self.emitted_records: Dict[object, int] = {k: 0 for k in self.streams}
        self.aborted_buckets: Dict[object, int] = {k: 0 for k in self.streams}

    def _injectors(self, keys):
        """Per-scenario injectors (None where the schedule is a no-op —
        the hot loop keeps its fault-free fast path for those rows)."""
        if self.fault_plan is None:
            return [None] * len(keys)
        return [None if self.fault_plan.is_noop_for(k)
                else self.fault_plan.injector(k) for k in keys]

    def _emit_one(self, i, b, bucket_args, queues, injectors, n_buckets,
                  n_records, keys):
        """Apply one scenario's next bucket (chaos-aware); returns False
        when the scenario's queue was closed under us (scenario dead)."""
        t_col, payload_items, clock = bucket_args
        inj = injectors[i]
        try:
            if inj is not None:
                action = inj.draw()
                if action.stall_s > 0.0:
                    clock.sleep(action.stall_s)
                if action.delay_s > 0.0:
                    clock.sleep(action.delay_s)
            sl_t = t_col
            bucket = Bucket(
                scale_stamp=b,
                t=sl_t,
                payload=dict(payload_items),
                emit_time=clock.time(),
            )
            n_buckets[i] += 1
            n_records[i] += len(bucket)
            if inj is not None:
                # earlier holds advance on EVERY emission (held ones
                # included) — the sequential _emit discipline
                released = inj.release_due()
                if action.hold:
                    inj.hold(bucket, action.hold)
                elif not action.drop:
                    queues[i].put(bucket)
                    if action.duplicate:
                        queues[i].put(_dup_bucket(bucket))
                    if self.on_emit is not None:
                        self.on_emit(keys[i], bucket)
                for rb in released:
                    queues[i].put(rb)
                return True
            queues[i].put(bucket)
            if self.on_emit is not None:
                self.on_emit(keys[i], bucket)
            return True
        except RuntimeError:
            if not queues[i].closed:
                raise
            return False                    # shed scenario, walk continues

    def _close_scenario(self, i, queues, injectors) -> None:
        """Flush the scenario's held (reordered) buckets, then close."""
        inj = injectors[i]
        if inj is not None and not queues[i].closed:
            try:
                for rb in inj.flush():
                    queues[i].put(rb)
            except RuntimeError:
                if not queues[i].closed:
                    raise
        queues[i].close()

    def run(self) -> int:
        """Walk the merged timeline once; returns the paper status code.

        Host work is O(total #non-empty buckets) plus one ``np.lexsort``
        over the merged events — empty simulated seconds cost one batched
        ``sleep`` for the WHOLE sweep, not one per scenario. Per-scenario
        state (timestamp/payload columns, queue, counters) is hoisted into
        index-addressed locals before the loop, so the per-event cost
        matches the sequential :class:`Producer` hot path. Non-virtual
        clocks take the timer-wheel walk instead
        (:meth:`_run_timer_wheel`).
        """
        if self.chunked:
            return self._run_chunked()
        if not isinstance(self.clock, VirtualClock):
            return self._run_timer_wheel()
        try:
            keys = list(self.streams)
            # hoisted per-scenario state, addressed by scenario index
            t_cols = [self.streams[k].t for k in keys]
            payloads = [list(self.streams[k].payload.items()) for k in keys]
            queues = [self.queues[k] for k in keys]
            injectors = self._injectors(keys)
            on_emit = self.on_emit
            clock, tick_s = self.clock, self.tick_s
            n_buckets = [0] * len(keys)
            n_records = [0] * len(keys)
            dead = [False] * len(keys)
            slices = []
            events_b, events_s = [], []
            last_bucket = [-1] * len(keys)
            for i, key in enumerate(keys):
                sl, _ = _group_by_scale_stamp(self.streams[key])
                slices.append(sl)
                if sl:
                    bs = np.fromiter(sl, np.int64, len(sl))
                    events_b.append(bs)
                    events_s.append(np.full(len(bs), i, np.int64))
                    last_bucket[i] = int(bs[-1])
                else:
                    queues[i].close()          # empty stream: nothing to emit
            if events_b:
                bs = np.concatenate(events_b)
                si = np.concatenate(events_s)
                # ascending simulated second; scenario order within a second
                order = np.lexsort((si, bs))
                prev = -1
                # .tolist() up front: the loop then touches only native
                # ints (per-event numpy scalar unboxing would dominate)
                for b, i in zip(bs[order].tolist(), si[order].tolist()):
                    if b != prev:
                        clock.sleep((b - prev) * tick_s)
                        prev = b
                    if dead[i]:
                        self.aborted_buckets[keys[i]] += 1
                        continue
                    sl = slices[i][b]
                    inj = injectors[i]
                    if inj is None:
                        # fault-free fast path (the PR-4 hot loop)
                        bucket = Bucket(
                            scale_stamp=b,
                            t=t_cols[i][sl],
                            payload={k: v[sl] for k, v in payloads[i]},
                            emit_time=clock.time(),
                        )
                        try:
                            queues[i].put(bucket)
                        except RuntimeError:
                            if not queues[i].closed:
                                raise
                            dead[i] = True
                            self.aborted_buckets[keys[i]] += 1
                            continue
                        n_buckets[i] += 1
                        n_records[i] += len(bucket)
                        if on_emit is not None:
                            on_emit(keys[i], bucket)
                    else:
                        alive = self._emit_one(
                            i, b,
                            (t_cols[i][sl],
                             [(k, v[sl]) for k, v in payloads[i]],
                             clock),
                            queues, injectors, n_buckets, n_records, keys)
                        if not alive:
                            dead[i] = True
                            self.aborted_buckets[keys[i]] += 1
                            continue
                    if b == last_bucket[i]:
                        # scenario done: close so its consumer can finish
                        # without waiting for the rest of the sweep
                        self._close_scenario(i, queues, injectors)
            for i, key in enumerate(keys):
                self.emitted_buckets[key] = n_buckets[i]
                self.emitted_records[key] = n_records[i]
            return STATUS_SUCCESS
        except Exception:
            for q in self.queues.values():
                q.close()
            return STATUS_FAULT

    def _run_timer_wheel(self) -> int:
        """Wall-clock batched replay: ONE heap of due times feeds S queues.

        Every scenario's non-empty buckets become timer events due at
        ``t0 + (b + 1) * tick_s`` — the sequential :class:`Producer`'s
        schedule (bucket ``b`` fires after ``b + 1`` ticks). The wheel
        pops the earliest event, sleeps until its due time, emits the
        bucket, and pushes that scenario's next one — S live consumers
        ride one loop and one heap instead of S chained-timer threads
        (Algorithm 2 spawned a ``threading.Timer`` per tick per stream).
        Ties fire in scenario order (heap entries carry the scenario
        index), matching the virtual-time walk; a bounded queue that
        fills stalls the wheel exactly like the virtual loop (shared
        backpressure — consumers must drain concurrently). Per-scenario
        bucket sequence, queue stats, and producer stats equal the
        sequential per-stream replay; ``emit_time`` is the wall time the
        wheel fired.
        """
        try:
            keys = list(self.streams)
            t_cols = [self.streams[k].t for k in keys]
            payloads = [list(self.streams[k].payload.items()) for k in keys]
            queues = [self.queues[k] for k in keys]
            injectors = self._injectors(keys)
            clock, tick_s = self.clock, self.tick_s
            n_buckets = [0] * len(keys)
            n_records = [0] * len(keys)
            dead = [False] * len(keys)
            slices, events = [], []
            heap = []
            for i, key in enumerate(keys):
                sl, _ = _group_by_scale_stamp(self.streams[key])
                slices.append(sl)
                bs = sorted(sl)
                events.append(bs)
                if bs:
                    heap.append((bs[0], i, 0))
                else:
                    queues[i].close()          # empty stream: nothing to emit
            heapq.heapify(heap)
            t0 = clock.time()
            while heap:
                b, i, j = heapq.heappop(heap)
                delay = t0 + (b + 1) * tick_s - clock.time()
                if delay > 0:
                    clock.sleep(delay)
                if not dead[i]:
                    sl = slices[i][b]
                    alive = self._emit_one(
                        i, b,
                        (t_cols[i][sl],
                         [(k, v[sl]) for k, v in payloads[i]],
                         clock),
                        queues, injectors, n_buckets, n_records, keys)
                    if not alive:
                        dead[i] = True
                        self.aborted_buckets[keys[i]] += 1
                else:
                    self.aborted_buckets[keys[i]] += 1
                if j + 1 < len(events[i]):
                    heapq.heappush(heap, (events[i][j + 1], i, j + 1))
                elif not dead[i]:
                    # scenario done: close so its consumer can finish
                    # without waiting for the rest of the sweep
                    self._close_scenario(i, queues, injectors)
            for i, key in enumerate(keys):
                self.emitted_buckets[key] = n_buckets[i]
                self.emitted_records[key] = n_records[i]
            return STATUS_SUCCESS
        except Exception:
            for q in self.queues.values():
                q.close()
            return STATUS_FAULT

    def _run_chunked(self) -> int:
        """Replay from :class:`ChunkFeed` s of time-chunk streams (PR 7).

        The walk proceeds in *rounds*: one chunk per live scenario per
        round (the engine pushes every scenario's chunk ``k`` before any
        chunk ``k+1``, so the sweep stays on one aligned chunk grid),
        merged-lexsorted and emitted exactly like :meth:`run` — the clock
        and ``prev`` gap state carry ACROSS rounds, so under a
        :class:`VirtualClock` per-bucket ``emit_time`` stamps are
        identical to the whole-stream walk, and each scenario's consumer
        observes the same bucket sequence either way. Replay of chunk 0
        starts as soon as it lands: nothing waits for the full timeline.

        **Stalled chunk iterator** (the timer-wheel satellite): when a
        feed has no chunk ready — the engine's next dispatch is still in
        flight — the producer *blocks* in ``ChunkFeed.get`` on a
        condition variable until the engine's ``put``/``close``. There is
        no busy-wait and no timeout-retry loop, and fault injectors
        persist across rounds (one draw per emission attempt, same RNG
        walk as the whole-stream replay), so the PR 6 reconciliation
        identity ``delivered == emitted - dropped + duplicated`` holds
        per scenario regardless of how the engine paces chunks. Under a
        non-virtual clock each bucket still fires at its absolute due
        time ``t0 + (b + 1) * tick_s`` (the timer-wheel schedule); a
        stalled feed can only make buckets late, never reordered.

        A scenario whose queue is closed under the walk goes dead but its
        feed keeps draining (counting ``aborted_buckets``) — otherwise
        the engine would block forever on a full feed of a shed scenario.
        """
        try:
            keys = list(self.streams)
            feeds = [self.streams[k] for k in keys]
            queues = [self.queues[k] for k in keys]
            injectors = self._injectors(keys)
            clock, tick_s = self.clock, self.tick_s
            virtual = isinstance(clock, VirtualClock)
            n = len(keys)
            n_buckets = [0] * n
            n_records = [0] * n
            dead = [False] * n
            live = [True] * n
            prev = -1                      # gap state carried across rounds
            t0 = clock.time()              # wall-clock schedule origin
            while any(live):
                # ---- fetch this round's chunks (blocks, no busy-wait)
                round_chunks = {}
                for i in range(n):
                    if not live[i]:
                        continue
                    chunk = feeds[i].get()
                    if chunk is None:      # closed + drained: timeline over
                        live[i] = False
                        if dead[i]:
                            queues[i].close()
                        else:
                            self._close_scenario(i, queues, injectors)
                        continue
                    round_chunks[i] = chunk
                # ---- merged walk over the round's events (run() body)
                events_b, events_s, slices = [], [], {}
                for i, chunk in round_chunks.items():
                    sl, _ = _group_by_scale_stamp(chunk)
                    if not sl:
                        continue           # empty chunk: nothing this round
                    slices[i] = (sl, chunk)
                    bs = np.fromiter(sl, np.int64, len(sl))
                    events_b.append(bs)
                    events_s.append(np.full(len(bs), i, np.int64))
                if not events_b:
                    continue
                bs = np.concatenate(events_b)
                si = np.concatenate(events_s)
                order = np.lexsort((si, bs))
                for b, i in zip(bs[order].tolist(), si[order].tolist()):
                    if virtual:
                        if b != prev:
                            clock.sleep((b - prev) * tick_s)
                            prev = b
                    else:
                        delay = t0 + (b + 1) * tick_s - clock.time()
                        if delay > 0:
                            clock.sleep(delay)
                    if dead[i]:
                        self.aborted_buckets[keys[i]] += 1
                        continue
                    sl, chunk = slices[i]
                    s = sl[b]
                    alive = self._emit_one(
                        i, b,
                        (chunk.t[s],
                         [(k, v[s]) for k, v in chunk.payload.items()],
                         clock),
                        queues, injectors, n_buckets, n_records, keys)
                    if not alive:
                        dead[i] = True
                        self.aborted_buckets[keys[i]] += 1
            for i, key in enumerate(keys):
                self.emitted_buckets[key] = n_buckets[i]
                self.emitted_records[key] = n_records[i]
            return STATUS_SUCCESS
        except Exception:
            for q in self.queues.values():
                q.close()
            for f in self.streams.values():
                f.close()   # unblock the engine side — no orphaned put()
            return STATUS_FAULT

    def stats(self, key=None) -> Dict:
        """Per-scenario producer stats (matching :meth:`Producer.stats`),
        or the whole mapping when ``key`` is omitted. Chunked replays add
        the feed's bounded-residency stats (``feed_hwm_chunks`` /
        ``feed_chunks``)."""
        if key is not None:
            out = {"emitted_buckets": self.emitted_buckets[key],
                   "emitted_records": self.emitted_records[key],
                   "aborted_buckets": self.aborted_buckets[key]}
            if self.fault_plan is not None and \
                    not self.fault_plan.is_noop_for(key):
                out.update(self.fault_plan.injector(key).stats())
            if self.chunked:
                out.update(self.streams[key].stats())
            return out
        return {k: self.stats(k) for k in self.streams}
