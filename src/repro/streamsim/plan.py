"""Sweep planning — the plan layer of the scenario-sweep architecture.

``Controller.run_many`` used to be a monolith that hand-interleaved store
lookups, NSA dispatch, per-scenario host gathers, metrics, fidelity, and
replay for ONE process's scenarios. This module makes the sweep an explicit
*plan* that the engine (:mod:`repro.streamsim.engine`) then executes:

1. **Enumerate** — the (dataset × max_range) grid at a given
   (scale, seed), in the report order ``for dataset: for max_range``
   (:class:`ScenarioSpec` per cell).
2. **Resolve** — scenarios whose simulated stream already sits in the
   :class:`~repro.streamsim.store.StreamStore` become cache *hits* (no NSA
   work); the rest are *missing* and must be simulated.
3. **Partition** — missing scenarios are sharded twice:

   - across **hosts** (``jax.process_count()`` under ``jax.distributed``;
     1 in a single-process run): hosts take strided slices of the
     size-sorted scenario list, so every host gets a similar record-count
     mix;
   - across this host's **devices**: a contiguous linear partition of the
     size-sorted list into at most ``n_devices`` :class:`Shard` s,
     minimizing the maximum *range-padded* shard cost. A shard's kernel
     cost is ``len(shard) × padded_rows(shard)`` — every row of a batched
     NSA launch is padded to the shard's longest stream — so grouping
     similar-length scenarios both balances devices AND shrinks total
     padded area versus one monolithic launch padded to the global
     maximum.

The plan is pure data (no jax imports at module load, no device work):
cheap to build, easy to test, and printable. ``Controller.run`` /
``run_many`` are thin drivers over ``plan_sweep`` + the engine.
"""

from __future__ import annotations

import dataclasses
from typing import List, Mapping, Optional, Sequence, Tuple

#: record-tile width of the batched NSA kernels — the quantum a shard's
#: row length is padded to (kept in sync with ``repro.kernels`` TILE)
ROW_TILE = 1024

#: one day of wall-clock seconds — the native timeline of every dataset
#: (kept in sync with ``repro.streamsim.datasets.DAY``)
DAY_S = 86_400


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One (dataset × max_range) cell of the sweep grid."""

    dataset: str
    max_range: int
    scale: float
    seed: int
    rows: int      #: source-stream record count (the shard-cost input)
    cached: bool   #: simulated stream already in the store (no NSA work)
    #: time axis (PR 7): 0 keeps the monolithic single-dispatch path.
    #: ``chunk_s`` slices the scale-stamp timeline into fixed chunks;
    #: ``duration_s`` stretches the scenario past one day (0 = the
    #: dataset's native range, i.e. ``max_range``).
    chunk_s: int = 0
    duration_s: int = 0

    @property
    def store_key(self) -> str:
        # chunk_s deliberately does NOT enter the key: chunked and
        # monolithic runs produce bit-equal simulated streams, so they
        # share the cache. A non-default duration is a different stream.
        base = f"{self.dataset}__sim{self.max_range}"
        if self.duration_s:
            base += f"__d{self.duration_s}"
        return base

    @property
    def scenario(self) -> Tuple[str, int]:
        """The (dataset, max_range) report key."""
        return (self.dataset, self.max_range)

    @property
    def n_days(self) -> int:
        """Days of original data the scenario covers (1 when
        ``duration_s`` is 0 — the native one-day stream)."""
        if self.duration_s <= 0:
            return 1
        return -(-self.duration_s // DAY_S)

    @property
    def span_s(self) -> int:
        """Seconds of simulated (scale-stamp) timeline this scenario
        covers: each original day compresses into ``max_range`` simulated
        seconds, so multi-day runs keep the per-day compression ratio and
        diurnal cycles stay aligned across days."""
        return int(self.max_range) * self.n_days

    @property
    def n_chunks(self) -> int:
        """Number of time chunks (1 when ``chunk_s`` is 0/monolithic)."""
        if self.chunk_s <= 0:
            return 1
        return -(-self.span_s // self.chunk_s)


@dataclasses.dataclass(frozen=True)
class Shard:
    """One device's slice of the store-missing scenarios.

    ``device_index`` is a *local* device slot (``jax.local_devices()``
    index); the engine places the shard's whole NSA→metrics chain there
    and runs it as ONE dispatch per kernel stage.
    """

    device_index: int
    specs: Tuple[ScenarioSpec, ...]

    @property
    def padded_rows(self) -> int:
        """Row length every spec pads to inside this shard's launch."""
        if not self.specs:
            return 0
        longest = max(s.rows for s in self.specs)
        return -(-max(longest, 1) // ROW_TILE) * ROW_TILE

    @property
    def cost(self) -> int:
        """Padded kernel area = rows of the batched launch × padded width."""
        return len(self.specs) * self.padded_rows

    @property
    def max_range(self) -> int:
        """The range the shard's bucket tables pad to (its own maximum —
        NOT the sweep-wide maximum, which is the monolith's padding)."""
        return max((s.max_range for s in self.specs), default=0)

    @property
    def span_s(self) -> int:
        """Simulated-timeline width the shard's chunk grid covers — the
        per-spec :attr:`ScenarioSpec.span_s` maximum (equals
        :attr:`max_range` for single-day sweeps)."""
        return max((s.span_s for s in self.specs), default=0)


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """A fully resolved sweep: grid + cache hits + per-device shards."""

    datasets: Tuple[str, ...]
    max_ranges: Tuple[int, ...]
    scale: float
    seed: int
    scenarios: Tuple[ScenarioSpec, ...]  #: full grid, report order
    cached: Tuple[ScenarioSpec, ...]     #: store-cache hits (no NSA)
    missing: Tuple[ScenarioSpec, ...]    #: all store-missing scenarios
    shards: Tuple[Shard, ...]            #: THIS host's device shards
    host_index: int
    n_hosts: int
    n_devices: int
    chunk_s: int = 0      #: time-chunk size in seconds (0 = monolithic)
    duration_s: int = 0   #: timeline length in seconds (0 = native range)

    @property
    def local_missing(self) -> Tuple[ScenarioSpec, ...]:
        """The store-missing scenarios this host's shards cover."""
        return tuple(s for sh in self.shards for s in sh.specs)

    @property
    def n_chunks(self) -> int:
        """Chunk rounds the engine runs — the max over scenarios (chunked
        runs keep the whole sweep on one aligned chunk grid; scenarios
        with a shorter timeline simply finish early)."""
        return max((s.n_chunks for s in self.scenarios), default=1)

    @property
    def sweep_id(self) -> str:
        """Stable identity of the sweep *configuration* (grid + scale +
        seed + host slot) — the checkpoint namespace key. Deliberately
        independent of cache-hit state: a restarted run whose first
        attempt already materialized some scenarios must still find its
        own markers. The time axis enters the hash only when non-default,
        so every pre-existing sweep keeps its id."""
        import hashlib
        ident = repr((tuple(self.datasets), tuple(self.max_ranges),
                      self.scale, self.seed, self.host_index, self.n_hosts))
        if self.chunk_s or self.duration_s:
            ident += repr((self.chunk_s, self.duration_s))
        return hashlib.sha256(ident.encode()).hexdigest()[:16]

    @property
    def sweep_group_id(self) -> str:
        """Host-independent sweep identity — :attr:`sweep_id` minus the
        host slot. Every host of a distributed run hashes the SAME value,
        which is what makes it the *shared* marker namespace: the
        service's work queue / leases / results and the cross-host
        fidelity rows all live under this key, while per-host
        checkpoints keep using :attr:`sweep_id`."""
        import hashlib
        ident = repr((tuple(self.datasets), tuple(self.max_ranges),
                      self.scale, self.seed))
        if self.chunk_s or self.duration_s:
            ident += repr((self.chunk_s, self.duration_s))
        return "g" + hashlib.sha256(ident.encode()).hexdigest()[:16]

    def padded_area(self) -> int:
        """Σ shard cost — the kernel work the plan actually dispatches."""
        return sum(sh.cost for sh in self.shards)

    def monolithic_area(self) -> int:
        """The cost of the unplanned PR-4 shape: ONE launch over all of
        this host's missing scenarios, padded to their global maximum."""
        specs = self.local_missing
        if not specs:
            return 0
        width = -(-max(s.rows for s in specs) // ROW_TILE) * ROW_TILE
        return len(specs) * width

    def summary(self) -> str:
        cells = len(self.scenarios)
        return (f"SweepPlan: {cells} scenarios ({len(self.cached)} cached, "
                f"{len(self.missing)} missing), host {self.host_index}/"
                f"{self.n_hosts} runs {len(self.shards)} shard(s) on "
                f"{self.n_devices} device(s), padded area "
                f"{self.padded_area()} vs monolithic "
                f"{self.monolithic_area()}")


def _partition_min_max_cost(sorted_specs: List[ScenarioSpec],
                            n_shards: int) -> List[List[ScenarioSpec]]:
    """Contiguous partition of a rows-descending spec list into at most
    ``n_shards`` groups minimizing the maximum padded group cost.

    Classic linear-partition DP (O(S²·R) — sweep grids are small). Because
    the list is sorted by record count descending, a contiguous group's
    padded width is its FIRST element's, so grouping neighbours both
    balances shards and minimizes padding waste.
    """
    S = len(sorted_specs)
    n = min(n_shards, S)
    if n <= 1:
        return [list(sorted_specs)] if S else []

    def width(i: int) -> int:  # padded row length of group starting at i
        return -(-max(sorted_specs[i].rows, 1) // ROW_TILE) * ROW_TILE

    def cost(i: int, j: int) -> int:  # group = specs[i:j]
        return (j - i) * width(i)

    INF = float("inf")
    # best[k][j] = minimal max-cost splitting specs[:j] into k groups
    best = [[INF] * (S + 1) for _ in range(n + 1)]
    cut = [[0] * (S + 1) for _ in range(n + 1)]
    best[0][0] = 0
    for k in range(1, n + 1):
        for j in range(k, S + 1):
            for i in range(k - 1, j):
                c = max(best[k - 1][i], cost(i, j))
                if c < best[k][j]:
                    best[k][j], cut[k][j] = c, i
    groups: List[List[ScenarioSpec]] = []
    j = S
    for k in range(n, 0, -1):
        i = cut[k][j]
        groups.append(list(sorted_specs[i:j]))
        j = i
    groups.reverse()
    return [g for g in groups if g]


def plan_sweep(store, datasets: Sequence[str], max_ranges: Sequence[int],
               row_counts: Mapping[str, int], *,
               scale: float = 1.0, seed: int = 0, force: bool = False,
               pairs: Optional[Sequence[Tuple[str, int]]] = None,
               n_devices: Optional[int] = None,
               host_index: Optional[int] = None,
               n_hosts: Optional[int] = None,
               chunk_s: int = 0, duration_s: int = 0) -> SweepPlan:
    """Build the :class:`SweepPlan` for a (datasets × max_ranges) sweep.

    Parameters
    ----------
    store : StreamStore
        Cache-hit resolution: scenarios with ``store.exists`` become
        :attr:`SweepPlan.cached` (skipped by the engine's NSA stage).
    datasets, max_ranges :
        The sweep grid axes; the grid is their cross product unless
        ``pairs`` overrides it.
    row_counts : mapping of dataset -> int
        Source-stream record counts (drives shard balancing and padding).
    scale, seed :
        Recorded on every spec (the synthetic-dataset cache key).
    force : bool
        Treat every scenario as store-missing (``Controller.simulate``'s
        ``force=True`` semantics).
    pairs : sequence of (dataset, max_range), optional
        Explicit scenario subset instead of the cross product.
    n_devices, host_index, n_hosts :
        Partition geometry. Default to ``jax.local_device_count()`` /
        ``jax.process_index()`` / ``jax.process_count()`` — i.e. under
        ``jax.distributed.initialize`` every host plans the SAME sweep and
        automatically takes only its own strided slice of the missing
        scenarios. Override for tests (e.g. forcing 4 shards on 1 device)
        or external schedulers.
    chunk_s, duration_s :
        Time axis (PR 7). ``chunk_s > 0`` routes execution through the
        chunked double-buffered pipeline (``ChunkedSweepRunner``) in
        ``chunk_s``-second time slices; ``duration_s > 0`` extends each
        scenario's timeline past its native range (multi-day sweeps).
        Defaults keep the monolithic behavior, store keys, and sweep ids
        unchanged.

    Returns
    -------
    SweepPlan
        Pure data; the engine executes it. Shards never split a scenario.
    """
    if pairs is None:
        pairs = [(d, int(mr)) for d in datasets for mr in max_ranges]
    else:
        pairs = [(d, int(mr)) for d, mr in pairs]
    if any(mr <= 0 for _, mr in pairs):
        raise ValueError("max_range must be positive")
    chunk_s, duration_s = int(chunk_s), int(duration_s)
    if chunk_s < 0:
        raise ValueError("chunk_s must be >= 0")
    if duration_s < 0:
        raise ValueError("duration_s must be >= 0")
    if n_devices is None or host_index is None or n_hosts is None:
        from repro.distributed import process_topology
        pidx, pcount, local = process_topology()
        if n_devices is None:
            n_devices = local
        if n_hosts is None:
            n_hosts = pcount
        if host_index is None:
            host_index = pidx
    if not (0 <= host_index < n_hosts):
        raise ValueError(f"host_index {host_index} outside [0, {n_hosts})")
    if n_devices < 1:
        raise ValueError("n_devices must be >= 1")

    def _key(d: str, mr: int) -> str:
        return (f"{d}__sim{mr}__d{duration_s}" if duration_s
                else f"{d}__sim{mr}")

    specs = tuple(
        ScenarioSpec(dataset=d, max_range=mr, scale=scale, seed=seed,
                     rows=int(row_counts[d]),
                     cached=bool(not force and store.exists(_key(d, mr))),
                     chunk_s=chunk_s, duration_s=duration_s)
        for d, mr in pairs)
    cached = tuple(s for s in specs if s.cached)
    missing = tuple(s for s in specs if not s.cached)

    # hosts take strided slices of the size-sorted list: similar record
    # mix per host, deterministic across processes (same plan everywhere)
    by_size = sorted(missing, key=lambda s: (-s.rows, s.dataset,
                                             s.max_range))
    mine = by_size[host_index::n_hosts]
    groups = _partition_min_max_cost(mine, n_devices)
    shards = tuple(Shard(device_index=i, specs=tuple(g))
                   for i, g in enumerate(groups))
    return SweepPlan(datasets=tuple(datasets),
                     max_ranges=tuple(int(m) for m in max_ranges),
                     scale=scale, seed=seed, scenarios=specs, cached=cached,
                     missing=missing, shards=shards, host_index=host_index,
                     n_hosts=n_hosts, n_devices=n_devices,
                     chunk_s=chunk_s, duration_s=duration_s)
