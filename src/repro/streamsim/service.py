"""Fault-tolerant distributed sweep service — leased work queue + merge.

PR 5 made multi-host sweeps possible (every host plans the same grid and
takes a static strided slice) but not *survivable*: a host that dies
silently loses its slice, and each host emits only a partial per-host
fidelity matrix. This module replaces static partitioning with a
**lease-based work queue** arbitrated entirely through the
:class:`~repro.streamsim.store.StreamStore`'s atomic marker primitives —
there is no coordinator process to keep alive, so the service is exactly
as available as the shared store directory.

Marker layout (all under ``_markers/<group>/`` where ``group`` is
:attr:`~repro.streamsim.plan.SweepPlan.sweep_group_id` — the
host-independent sweep identity)::

    meta/      claimant.json, ready.json      publisher election
    queue/     <dataset>__<max_range>.json    unclaimed scenarios
    leases/    <dataset>__<max_range>.json    Lease payloads (live claims)
    results/   <dataset>__<max_range>.json    report + worker provenance
    poison/    <dataset>__<max_range>.json    quarantined scenarios
    fidelity/  orig__<d>.json, sim__<d>__<mr>.json    exact count rows
    done/      <worker>.json                  finalization barrier

Protocol (documented in full in ``docs/robustness.md``):

1. **Publish** — exactly one process wins the ``meta/claimant``
   exclusive-create election, enqueues every unresolved grid scenario,
   then writes ``meta/ready``; everyone else waits for ``ready`` (with a
   dead-publisher takeover after a timeout — safe because nobody claims
   before ``ready`` exists).
2. **Claim** — a worker *moves* ``queue/<item>`` to ``leases/<item>``
   (one ``os.replace``: of N racing claimants exactly one wins), then
   rewrites the lease with its :class:`~repro.streamsim.resilience.Lease`
   (worker id, wall-clock deadline, attempt count). A background
   :class:`~repro.streamsim.resilience.Heartbeat` renews the deadline
   while the batch executes through the ordinary
   :func:`~repro.streamsim.engine.run_sweep` path.
3. **Publish results** — each report is published the moment it is
   assembled (``run_sweep(on_report=...)``), together with the
   scenario's exact per-second count row, so a worker killed mid-batch
   loses only its unpublished tail.
4. **Reap** — every worker doubles as reaper: a lease past its deadline
   means a *dead* worker (wedged-but-alive workers keep heartbeating —
   wedge detection belongs to the engine's ``consumer_deadline_s``).
   Expired leases are requeued behind the PR 6
   :class:`~repro.streamsim.resilience.CircuitBreaker`: a scenario whose
   lease count reaches ``breaker_threshold`` has killed that many
   workers and is quarantined to ``poison/`` instead of retried forever,
   surfacing as a ``status="poisoned"`` report.
5. **Merge** — finalization recomputes the FULL S×S fidelity matrix
   from the published *count rows* (exact integers through JSON) with
   the numpy reduction a single-host run uses, so the merged matrix
   equals the single-host artifact instead of being approximately
   stitched from partial sub-matrices. ``FidelityReport.provenance``
   records which worker produced each row.

Execution is **at-least-once**: a lease that expires while its worker is
merely slow (not dead) lets a second worker re-run the scenario. That is
safe by construction — scenario execution is deterministic and result
publication is an atomic last-writer-wins marker write — but it is the
reason ``lease_ttl_s`` should comfortably exceed a scenario's runtime.
"""

from __future__ import annotations

import base64
import os
import socket
import time
import uuid
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.streamsim import engine
from repro.streamsim.engine import FidelityReport, SimulationReport
from repro.streamsim.metrics import Volatility, trend_correlation_matrix
from repro.streamsim.plan import plan_sweep
from repro.streamsim.resilience import CircuitBreaker, Heartbeat, Lease

__all__ = [
    "SweepService",
    "run_service_sweep",
    "merge_fidelity",
    "scenario_marker",
    "pack_counts",
    "unpack_counts",
]

#: how long ``ready``-waiters allow the elected publisher before assuming
#: it died mid-publish and taking over (takeover is idempotent: nobody
#: claims until ``ready`` exists, so no queue item can be in flight)
PUBLISH_TAKEOVER_S = 30.0


def pack_counts(counts) -> str:
    """``"<dtype>:<base64>"`` of the row as little-endian ints — exact
    (count rows are integers) and ~20x cheaper to round-trip through a
    JSON marker than a list of Python ints, which is what keeps the
    fidelity-row publication cheap enough for the service-overhead
    gate. Rows are day-long per-second vectors, so the int32/int64
    choice halves most payloads."""
    a = np.asarray(counts)
    code = "<i4" if (a.size == 0 or
                     (np.iinfo(np.int32).min <= int(a.min()) and
                      int(a.max()) <= np.iinfo(np.int32).max)) else "<i8"
    a = np.ascontiguousarray(a.astype(code))
    return f"{code}:" + base64.b64encode(a.tobytes()).decode("ascii")


def unpack_counts(counts) -> np.ndarray:
    """Inverse of :func:`pack_counts`; also accepts a plain int list (or
    an ndarray) so hand-written marker payloads and in-memory local rows
    merge identically."""
    if isinstance(counts, str):
        code, _, b64 = counts.partition(":")
        raw = base64.b64decode(b64.encode("ascii"))
        return np.frombuffer(raw, dtype=code).astype(np.int64)
    return np.asarray(counts, dtype=np.int64)


def scenario_marker(dataset: str, max_range: int) -> str:
    """Queue/lease/result marker name for one scenario. Dataset names
    must not contain ``"__"`` (the same naming contract
    :class:`~repro.streamsim.resilience.SweepCheckpoint` relies on);
    payloads carry the authoritative ``dataset``/``max_range`` anyway."""
    return f"{dataset}__{int(max_range)}"


def default_worker_id() -> str:
    return f"{socket.gethostname()}-{os.getpid()}-{uuid.uuid4().hex[:6]}"


class SweepService:
    """One worker's view of a lease-based sweep over a shared store.

    All coordination state lives in the store; any number of
    ``SweepService`` instances (across processes and hosts) pointed at
    the same store directory and the same sweep configuration cooperate
    on — and survive each other's deaths during — one sweep.
    """

    def __init__(self, store, datasets: Sequence[str],
                 max_ranges: Sequence[int], *,
                 scale: float = 1.0, seed: int = 0,
                 lease_ttl_s: float = 60.0, poll_s: float = 0.2,
                 lease_batch: int = 1, breaker_threshold: int = 3,
                 worker_id: Optional[str] = None,
                 clock: Callable[[], float] = time.time):
        if lease_ttl_s <= 0:
            raise ValueError("lease_ttl_s must be > 0")
        if lease_batch < 1:
            raise ValueError("lease_batch must be >= 1")
        self.store = store
        self.datasets = list(datasets)
        self.max_ranges = [int(m) for m in max_ranges]
        self.scale = float(scale)
        self.seed = int(seed)
        self.ttl_s = float(lease_ttl_s)
        self.poll_s = float(poll_s)
        self.lease_batch = int(lease_batch)
        self.breaker_threshold = int(breaker_threshold)
        self.worker_id = worker_id or default_worker_id()
        self._clock = clock
        #: fidelity rows THIS worker published, kept in memory so
        #: :meth:`finalize` merges them without re-reading its own
        #: markers (peers' rows still come from the store)
        self._local_rows: Dict[str, Dict] = {}
        # the group id is host-independent by construction, so a probe
        # plan with any host slot yields the shared namespace key
        probe = plan_sweep(store, self.datasets, self.max_ranges,
                           {d: 1 for d in self.datasets},
                           scale=self.scale, seed=self.seed,
                           n_devices=1, host_index=0, n_hosts=1)
        self.group = probe.sweep_group_id
        self.grid: List[Tuple[str, int]] = [
            (d, mr) for d in self.datasets for mr in self.max_ranges]

    # ------------------------------------------------------------ namespaces
    @property
    def ns_meta(self) -> str:
        return f"{self.group}/meta"

    @property
    def ns_queue(self) -> str:
        return f"{self.group}/queue"

    @property
    def ns_leases(self) -> str:
        return f"{self.group}/leases"

    @property
    def ns_results(self) -> str:
        return f"{self.group}/results"

    @property
    def ns_poison(self) -> str:
        return f"{self.group}/poison"

    @property
    def ns_fidelity(self) -> str:
        return f"{self.group}/fidelity"

    @property
    def ns_done(self) -> str:
        return f"{self.group}/done"

    # --------------------------------------------------------------- publish
    def publish_queue(self, *, wait_s: float = PUBLISH_TAKEOVER_S) -> bool:
        """Ensure the work queue exists; returns True if THIS worker
        published it. One exclusive-create election picks the publisher;
        losers block until ``meta/ready`` appears. A waiter that outlives
        ``wait_s`` assumes the publisher died mid-publish and publishes
        itself — idempotent, because no worker claims before ``ready``
        exists, so no queue item can be moving concurrently."""
        if self.store.has_marker(self.ns_meta, "ready"):
            return False
        won = self.store.put_marker(self.ns_meta, "claimant",
                                    {"worker": self.worker_id},
                                    exclusive=True)
        if not won:
            t0 = time.monotonic()
            while not self.store.has_marker(self.ns_meta, "ready"):
                if time.monotonic() - t0 > wait_s:
                    break                      # dead publisher: take over
                time.sleep(min(self.poll_s, 0.05))
            else:
                return False
            if self.store.has_marker(self.ns_meta, "ready"):
                return False
        resolved = set(self.store.list_markers(self.ns_results)) \
            | set(self.store.list_markers(self.ns_poison)) \
            | set(self.store.list_markers(self.ns_queue)) \
            | set(self.store.list_markers(self.ns_leases))
        for d, mr in self.grid:
            name = scenario_marker(d, mr)
            if name not in resolved:
                self.store.put_marker(self.ns_queue, name, {
                    "dataset": d, "max_range": mr, "attempts": 0})
        self.store.put_marker(self.ns_meta, "ready",
                              {"worker": self.worker_id})
        return True

    # ----------------------------------------------------------------- claim
    def claim_batch(self, n: Optional[int] = None) -> Dict[str, Lease]:
        """Lease up to ``n`` queued scenarios (atomic queue→lease moves;
        losing a race on an item just skips it). Returns marker name →
        :class:`Lease` for every item won."""
        n = self.lease_batch if n is None else n
        claimed: Dict[str, Lease] = {}
        for name in self.store.list_markers(self.ns_queue):
            if len(claimed) >= n:
                break
            if not self.store.claim_marker(self.ns_queue, name,
                                           self.ns_leases, name):
                continue
            payload = self.store.get_marker(self.ns_leases, name)
            lease = Lease(worker=self.worker_id,
                          dataset=payload["dataset"],
                          max_range=int(payload["max_range"]),
                          ttl_s=self.ttl_s,
                          deadline=self._clock() + self.ttl_s,
                          attempts=int(payload.get("attempts", 0)) + 1)
            self.store.put_marker(self.ns_leases, name, lease.to_json())
            claimed[name] = lease
        return claimed

    # ------------------------------------------------------------------ reap
    def _quarantine(self, name: str, payload: Dict,
                    error: Optional[str]) -> None:
        # move (atomic: one of N racing reapers wins) then normalize
        if self.store.claim_marker(self.ns_leases, name,
                                   self.ns_poison, name):
            self.store.put_marker(self.ns_poison, name, {
                "dataset": payload["dataset"],
                "max_range": int(payload["max_range"]),
                "attempts": int(payload.get("attempts", 0)),
                "last_worker": payload.get("worker"),
                "error": error,
            })

    def _requeue(self, name: str, payload: Dict,
                 error: Optional[str]) -> None:
        if self.store.claim_marker(self.ns_leases, name,
                                   self.ns_queue, name):
            self.store.put_marker(self.ns_queue, name, {
                "dataset": payload["dataset"],
                "max_range": int(payload["max_range"]),
                "attempts": int(payload.get("attempts", 0)),
                "error": error,
            })

    def _strike(self, name: str, payload: Dict,
                error: Optional[str]) -> None:
        """Requeue-or-poison one failed lease: the scenario's lease
        count replays into a fresh PR 6 breaker, so ``breaker_threshold``
        worker deaths on the same scenario open it → quarantine."""
        breaker = CircuitBreaker(
            failure_threshold=self.breaker_threshold)
        for _ in range(max(1, int(payload.get("attempts", 0)))):
            breaker.record_failure()
        if breaker.allow():
            self._requeue(name, payload, error)
        else:
            self._quarantine(name, payload, error)

    def reap(self) -> List[str]:
        """One reaper pass: requeue (or quarantine) every expired lease.
        Every worker calls this each loop iteration — there is no
        dedicated reaper process to die. Returns the reaped names."""
        reaped = []
        now = self._clock()
        for name in self.store.list_markers(self.ns_leases):
            if self.store.has_marker(self.ns_results, name):
                # worker published then died before releasing: the
                # result stands, the lease is garbage
                self.store.remove_marker(self.ns_leases, name)
                continue
            try:
                payload = self.store.get_marker(self.ns_leases, name)
            except FileNotFoundError:
                continue                      # released under our feet
            if "deadline" in payload:
                expired = now > float(payload["deadline"])
            else:
                # claim window: the queue→lease move landed but the
                # claimant died before writing its Lease; judge by file
                # age against the service TTL
                mtime = self.store.marker_mtime(self.ns_leases, name)
                expired = mtime is not None and now > mtime + self.ttl_s
                payload = dict(payload)
                payload["attempts"] = int(payload.get("attempts", 0)) + 1
            if not expired:
                continue
            self._strike(name, payload, "lease expired (worker dead?)")
            reaped.append(name)
        return reaped

    # ------------------------------------------------------------- lifecycle
    def outstanding(self) -> List[Tuple[str, int]]:
        """Grid scenarios not yet resolved (no result and no poison)."""
        done = set(self.store.list_markers(self.ns_results)) \
            | set(self.store.list_markers(self.ns_poison))
        return [sc for sc in self.grid
                if scenario_marker(*sc) not in done]

    def run_batch(self, leases: Dict[str, Lease], originals, consumer, *,
                  t_pre: Optional[Dict[str, float]] = None,
                  queue_size: int = 64, backend: str = "auto",
                  n_devices: int = 1, **replay_kw) -> List[str]:
        """Execute one claimed batch through the ordinary plan → engine →
        replay path and publish each result the moment its report exists.
        Returns the marker names actually published (a lease the reaper
        reclaimed mid-run is skipped — the rival owns the scenario now).
        Exceptions propagate AFTER the unpublished remainder is struck
        back to the queue/poison, so a deterministic per-scenario crash
        converges to quarantine instead of looping forever."""
        t_pre = t_pre or {}
        row_counts = {d: len(originals[d]) for d in self.datasets}
        pairs = [(l.dataset, l.max_range) for l in leases.values()]
        by_sc = {(l.dataset, l.max_range): (name, l)
                 for name, l in leases.items()}
        plan = plan_sweep(self.store, self.datasets, self.max_ranges,
                          row_counts, scale=self.scale, seed=self.seed,
                          pairs=pairs, n_devices=n_devices,
                          host_index=0, n_hosts=1)
        published: List[str] = []
        with Heartbeat(self.store, self.ns_leases, leases) as hb:
            try:
                result = engine.execute_sweep(plan, originals, self.store,
                                              backend=backend)
                counts = result.count_rows()
                self._publish_originals(result)

                def _publish(report: SimulationReport) -> None:
                    sc = (report.dataset, report.max_range)
                    name, lease = by_sc[sc]
                    if name in hb.lost:
                        return        # reaped: a rival owns this lease
                    self.store.put_marker(self.ns_results, name, {
                        "report": report.to_json(),
                        "worker": self.worker_id,
                        "attempts": lease.attempts,
                    })
                    row = {"counts": np.asarray(counts[sc]),
                           "worker": self.worker_id}
                    self.store.put_marker(
                        self.ns_fidelity, f"sim__{name}",
                        {"counts": pack_counts(row["counts"]),
                         "worker": self.worker_id})
                    self._local_rows[f"sim__{name}"] = row
                    published.append(name)

                engine.run_sweep(result, consumer, queue_size=queue_size,
                                 t_pre=t_pre, fidelity=False,
                                 on_report=_publish, **replay_kw)
            except BaseException as exc:
                hb.stop()
                for name, lease in leases.items():
                    if name in published or name in hb.lost:
                        continue
                    self._strike(name, lease.to_json(), repr(exc))
                raise
        # release leases we still own (lost ones belong to their reaper)
        for name in leases:
            if name not in hb.lost:
                self.store.remove_marker(self.ns_leases, name)
        return published

    def _publish_originals(self, result) -> None:
        """Exact per-dataset original count rows — the merge's left-hand
        block. Idempotent: originals are deterministic per (scale, seed),
        so a rewrite by another worker carries identical content."""
        for d in self.datasets:
            name = f"orig__{d}"
            if not self.store.has_marker(self.ns_fidelity, name):
                row = {"counts": np.asarray(result.om[d].counts),
                       "worker": self.worker_id}
                self.store.put_marker(self.ns_fidelity, name, {
                    "counts": pack_counts(row["counts"]),
                    "worker": self.worker_id})
                self._local_rows[name] = row

    def work(self, originals, consumer, *,
             t_pre: Optional[Dict[str, float]] = None,
             queue_size: int = 64, backend: str = "auto",
             n_devices: int = 1, deadline_s: Optional[float] = None,
             **replay_kw) -> None:
        """The worker loop: publish (or wait for) the queue, then
        reap → claim → execute until every grid scenario has a result
        or a poison marker. Raises TimeoutError past ``deadline_s``."""
        self.publish_queue()
        t0 = time.monotonic()
        while True:
            self.reap()
            leases = self.claim_batch()
            if leases:
                try:
                    self.run_batch(leases, originals, consumer,
                                   t_pre=t_pre, queue_size=queue_size,
                                   backend=backend, n_devices=n_devices,
                                   **replay_kw)
                except Exception:
                    # the batch was struck back to queue/poison; keep
                    # serving — quarantine bounds the retry budget
                    pass
                continue
            if not self.outstanding():
                return
            if deadline_s is not None and \
                    time.monotonic() - t0 > deadline_s:
                raise TimeoutError(
                    f"sweep service: {len(self.outstanding())} "
                    f"scenario(s) unresolved after {deadline_s}s")
            time.sleep(self.poll_s)

    # --------------------------------------------------------------- collect
    def collect(self) -> Tuple[List[SimulationReport], List[str]]:
        """The full grid's reports in grid order (poisoned scenarios get
        a quarantine stub), plus the marker names THIS worker produced
        (the controller persists only its own reports to its local
        metrics repository)."""
        reports, mine = [], []
        for d, mr in self.grid:
            name = scenario_marker(d, mr)
            if self.store.has_marker(self.ns_results, name):
                payload = self.store.get_marker(self.ns_results, name)
                r = SimulationReport.from_json(payload["report"])
                if payload.get("worker") == self.worker_id:
                    mine.append(name)
            elif self.store.has_marker(self.ns_poison, name):
                p = self.store.get_marker(self.ns_poison, name)
                vol0 = Volatility(average=0.0, variance=0.0,
                                  std_variance=0.0, time_range=int(mr))
                r = SimulationReport(
                    dataset=d, max_range=int(mr), original_rows=0,
                    simulated_rows=0, compression=0.0,
                    original_volatility=vol0, simulated_volatility=vol0,
                    trend_corr=0.0, preprocess_s=0.0, nsa_s=0.0,
                    produce_s=0.0,
                    consumer_metrics={"poisoned": True},
                    status="poisoned", failure=p.get("error"),
                    attempts=int(p.get("attempts", 0)))
            else:
                raise RuntimeError(
                    f"scenario {(d, mr)} neither resolved nor poisoned "
                    "— collect() called before work() finished?")
            reports.append(r)
        return reports, mine

    def finalize(self, *, n_participants: int = 1,
                 fidelity_window_s: int = 60
                 ) -> Tuple[List[SimulationReport], List[FidelityReport],
                            List[str]]:
        """Collect + cross-host merge + cooperative cleanup. Every
        participant collects BEFORE announcing itself done, and only an
        observer that sees all ``n_participants`` done markers clears the
        namespace — so nobody can clear state a peer still reads.
        (``clear_markers`` is atomic and concurrent-clear-safe, so two
        last observers racing is fine.)"""
        reports, mine = self.collect()
        fidelity = merge_fidelity(self.store, self.group, self.datasets,
                                  self.max_ranges,
                                  window_s=fidelity_window_s,
                                  local=self._local_rows)
        self.store.put_marker(self.ns_done, self.worker_id,
                              {"t": time.time()})
        if len(self.store.list_markers(self.ns_done)) >= n_participants:
            self.store.clear_markers(self.group)
        return reports, fidelity, mine


def merge_fidelity(store, group: str, datasets: Sequence[str],
                   max_ranges: Sequence[int], *, window_s: int = 60,
                   local: Optional[Dict[str, Dict]] = None
                   ) -> List[FidelityReport]:
    """Recompute the FULL S×S fidelity matrix per ``max_range`` from the
    published exact count rows (``fidelity/orig__*`` + ``fidelity/sim__*``
    markers), regardless of which worker/host produced each row.

    Count rows are integers carried exactly (packed little-endian int64
    via :func:`pack_counts`, or a plain int list), and the reduction
    is the numpy :func:`~repro.streamsim.metrics.trend_correlation_matrix`
    a single-host numpy run uses — so the merged matrix EQUALS the
    single-host artifact (pallas-produced rows agree within the
    documented 1e-3 backend tolerance). Rows whose scenario is poisoned
    or still unpublished are omitted; ``labels`` record the subset and
    ``provenance`` the producing worker per row.

    ``local`` is an optional overlay of rows the CALLER itself published
    (marker name -> ``{"counts", "worker"}`` with in-memory counts):
    those skip the store read-back entirely, so a worker that computed a
    row never pays to re-parse its own marker. Rows are deterministic,
    so an overlay row always matches what any rival published."""
    ns = f"{group}/fidelity"
    local = local or {}

    def _payload(name: str) -> Optional[Dict]:
        if name in local:
            return local[name]
        if store.has_marker(ns, name):
            return store.get_marker(ns, name)
        return None

    orig: Dict[str, Dict] = {}
    for d in datasets:
        p = _payload(f"orig__{d}")
        if p is not None:
            orig[d] = p
    out: List[FidelityReport] = []
    for mr in max_ranges:
        rows = []
        for d in datasets:
            p = _payload(f"sim__{scenario_marker(d, mr)}")
            if d in orig and p is not None:
                rows.append((d, p))
        if not rows:
            continue
        labels = [f"{d}/original" for d, _ in rows] + \
            [f"{d}/sim{mr}" for d, _ in rows]
        provenance = [orig[d].get("worker") for d, _ in rows] + \
            [p.get("worker") for _, p in rows]
        counts = [unpack_counts(orig[d]["counts"]) for d, _ in rows] + \
            [unpack_counts(p["counts"]) for _, p in rows]
        matrix = trend_correlation_matrix(counts, window_s=window_s,
                                          backend="numpy")
        out.append(FidelityReport(int(mr), int(window_s), labels,
                                  np.asarray(matrix).tolist(),
                                  provenance=provenance))
    return out


def run_service_sweep(store, datasets: Sequence[str],
                      max_ranges: Sequence[int], originals, consumer, *,
                      scale: float = 1.0, seed: int = 0,
                      t_pre: Optional[Dict[str, float]] = None,
                      queue_size: int = 64, backend: str = "auto",
                      fidelity_window_s: int = 60, n_devices: int = 1,
                      lease_ttl_s: float = 60.0, poll_s: float = 0.2,
                      lease_batch: int = 1, breaker_threshold: int = 3,
                      worker_id: Optional[str] = None,
                      n_participants: int = 1,
                      deadline_s: Optional[float] = None,
                      **replay_kw
                      ) -> Tuple[List[SimulationReport],
                                 List[FidelityReport], List[str]]:
    """One participant's complete service run: publish/join the queue,
    serve until the grid is resolved, then finalize (collect + merged
    fidelity + cooperative cleanup). Returns ``(reports, fidelity,
    own_marker_names)`` — reports cover the FULL grid on every
    participant; ``own_marker_names`` identifies the subset this worker
    computed."""
    svc = SweepService(store, datasets, max_ranges, scale=scale,
                       seed=seed, lease_ttl_s=lease_ttl_s, poll_s=poll_s,
                       lease_batch=lease_batch,
                       breaker_threshold=breaker_threshold,
                       worker_id=worker_id)
    svc.work(originals, consumer, t_pre=t_pre, queue_size=queue_size,
             backend=backend, n_devices=n_devices, deadline_s=deadline_s,
             **replay_kw)
    return svc.finalize(n_participants=n_participants,
                        fidelity_window_s=fidelity_window_s)
