"""Task benchmark harness — the paper's comparison, end to end.

The paper validates its framework by running a stream task twice — once
against the original day-long stream, once against the NSA-compressed
simulated stream — and showing the simulated run is >= 24x faster while
the task sees the same volatility/trends. :class:`TaskBenchRunner` is that
experiment as code: for every (task, dataset, max_range) cell it replays
the *original* stream (per-second scale stamps over its natural span) and
the *simulated* stream (compressed to ``max_range`` virtual seconds)
through the same :func:`repro.streamsim.engine.replay_many` transport
(MultiQueueProducer + QueueGroup, virtual clock), and emits a
:class:`TaskReport` carrying:

- ``speedup`` — original-replay wall time over simulated-replay wall time
  (both at virtual speed, so the ratio reflects the data-volume
  compression the paper buys, not sleep time);
- ``trend_fidelity`` — Pearson correlation between the task's OWN output
  series (``task_output_counts``) under the two replays, via
  :func:`repro.streamsim.metrics.trend_correlation_matrix` (the
  device-resident ``trend_correlation_batched`` chain on the pallas
  backend), plus the two output streams' coefficients of variation
  (the volatility half of the claim);
- ``latency`` — p50/p99/p999/mean/jitter of the task's per-bucket (or,
  for the serving task, per-request) latency, summarized from
  device-resident histograms: ALL sim scenarios' latency-bin arrays for a
  task feed ONE fused :func:`repro.kernels.ops.stream_metrics_batched`
  dispatch (:func:`summarize_latencies`).

``FIDELITY_FLOOR`` is the documented floor the equivalence suite and the
CI benchmark gate hold the trend correlation to (docs/tasks.md).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.streamsim.datasets import make_stream
from repro.streamsim.engine import REPORT_TREND_WINDOW_S, replay_many
from repro.streamsim.metrics import trend_correlation_matrix
from repro.streamsim.nsa import nsa
from repro.streamsim.preprocess import Stream, preprocess
from repro.streamsim.tasks import LATENCY_BINS, LATENCY_BIN_US

__all__ = [
    "FIDELITY_FLOOR",
    "PAPER_SPEEDUP",
    "LatencySummary",
    "TaskBenchRunner",
    "TaskReport",
    "original_replay_stream",
    "slice_stream",
    "summarize_latencies",
]

#: documented trend-fidelity floor for the task-output equivalence check
#: (the paper's "ensure volatility and trends" premise as a number): the
#: Pearson correlation of a task's output trend between original and
#: simulated replay, at the report window, must not fall below this.
FIDELITY_FLOOR = 0.75

#: the paper's headline task-acceleration figure (§6): one day compressed
#: into <= 1 hour makes the stream task >= 24x faster. Recorded on every
#: benchmark row as ``paper_ratio``; CI gates a conservative floor.
PAPER_SPEEDUP = 24.0


# ---------------------------------------------------------- latency summary
@dataclasses.dataclass
class LatencySummary:
    """Per-scenario latency digest from one device histogram row."""

    samples: int
    p50_us: float
    p99_us: float
    p999_us: float
    mean_us: float
    jitter_us: float      # std of the latency distribution

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _hist_rows(arrays: List[np.ndarray], n_bins: int,
               backend: str) -> np.ndarray:
    """(S, n_bins) histogram matrix — ONE fused device dispatch on the
    pallas path, plain bincount on numpy / domain fallback."""
    if backend != "numpy":
        from repro.kernels import ops
        try:
            hist, _, _ = ops.stream_metrics_batched(arrays, n_bins)
            return np.asarray(hist, np.int64)
        except ops.PallasDomainError:
            pass
    return np.stack([np.bincount(a, minlength=n_bins).astype(np.int64)
                     for a in arrays])


def summarize_latencies(bin_arrays: Sequence,
                        *, bin_us: float = LATENCY_BIN_US,
                        n_bins: int = LATENCY_BINS,
                        backend: str = "auto") -> List[LatencySummary]:
    """Latency summaries for S scenarios from ONE fused histogram dispatch.

    ``bin_arrays`` are the tasks' ``task_latency_bins`` outputs (integer
    bin indices in ``[0, n_bins)``, ragged lengths, empties allowed).
    The bins are scale-stamp-shaped, so the whole sweep goes through a
    single :func:`repro.kernels.ops.stream_metrics_batched` call; the
    quantiles (nearest-rank over the cumulative histogram, reported at
    bin centers), mean, and jitter (std) all derive from the returned
    histogram rows. Empty scenarios yield NaN summaries.
    """
    arrays = [np.asarray(a, np.int32).reshape(-1) for a in bin_arrays]
    if not arrays:
        return []
    hist = _hist_rows(arrays, n_bins, backend)
    centers = (np.arange(n_bins, dtype=np.float64) + 0.5) * bin_us
    out = []
    for s, a in enumerate(arrays):
        n = len(a)
        if n == 0:
            out.append(LatencySummary(0, *([float("nan")] * 5)))
            continue
        cum = np.cumsum(hist[s])

        def pct(p, cum=cum, n=n):
            rank = max(1, int(np.ceil(p * n)))
            return float(centers[np.searchsorted(cum, rank, side="left")])

        mean = float((hist[s] * centers).sum() / n)
        var = float((hist[s] * centers ** 2).sum() / n - mean ** 2)
        out.append(LatencySummary(n, pct(0.50), pct(0.99), pct(0.999),
                                  mean, float(np.sqrt(max(var, 0.0)))))
    return out


# ------------------------------------------------------------- task report
def _cv(q: np.ndarray) -> float:
    """Coefficient of variation of a count series (volatility digest)."""
    q = np.asarray(q, np.float64)
    if len(q) == 0 or q.mean() == 0:
        return float("nan")
    return float(q.std() / q.mean())


@dataclasses.dataclass
class TaskReport:
    """One (task, dataset, max_range) cell of the paper comparison."""

    task: str
    dataset: str
    max_range: int
    t_original_s: float       # original-replay wall (virtual clock)
    t_simulated_s: float      # simulated-replay wall (virtual clock)
    speedup: float            # t_original_s / t_simulated_s
    paper_ratio: float        # the paper's >= 24x figure, for the record
    trend_fidelity: float     # task-output trend corr, original vs sim
    cv_original: float        # output-series volatility (std/mean)
    cv_simulated: float
    records_original: int
    records_simulated: int
    latency: Dict[str, float]  # sim-run LatencySummary.to_dict()

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def slice_stream(stream: Stream, span_s: int) -> Stream:
    """The stream's first ``span_s`` seconds (payload column-sliced).

    Reduced-span runs keep the CI smoke fast while leaving enough diurnal
    structure for the fidelity check; full-day runs are the paper
    numbers. The slice is taken BEFORE NSA so original and simulated
    replays see the same source window.
    """
    if span_s <= 0:
        raise ValueError("span_s must be positive")
    if len(stream.t) == 0:
        return stream
    mask = stream.t < stream.t.min() + span_s
    return Stream(name=stream.name, t=stream.t[mask],
                  payload={k: v[mask] for k, v in stream.payload.items()},
                  scale_stamp=None)


def original_replay_stream(stream: Stream) -> Stream:
    """The original stream readied for replay: per-second scale stamps
    over its natural span (stamp = floor(t - t0)), so the producer walks
    it exactly like a simulated stream whose max_range is the full day.
    The payload is shared, not copied."""
    if len(stream.t) == 0:
        stamps = np.zeros(0, np.int64)
    else:
        t0 = np.floor(stream.t.min())
        stamps = np.floor(stream.t - t0).astype(np.int64)
    return Stream(name=stream.name, t=stream.t, payload=stream.payload,
                  scale_stamp=stamps)


# ------------------------------------------------------------------ runner
class TaskBenchRunner:
    """Run each task against original AND simulated replay; report both
    halves of the paper's claim (speedup, output fidelity) per scenario.

    Every replay leg goes through :func:`replay_many` — the same
    MultiQueueProducer/QueueGroup transport ``Controller.run_many``
    drives — with its own wall clock, so per-scenario speedups are
    clean. Per task, ALL simulated scenarios' latency bins are then
    summarized in one fused device dispatch.
    """

    def __init__(self, datasets: Sequence[str],
                 max_ranges: Sequence[int], *, scale: float = 0.01,
                 seed: int = 0, span_s: Optional[int] = None,
                 window_s: int = REPORT_TREND_WINDOW_S,
                 queue_size: int = 256, backend: str = "auto",
                 paper_ratio: float = PAPER_SPEEDUP):
        if not datasets or not max_ranges:
            raise ValueError("need at least one dataset and one max_range")
        self.datasets = list(datasets)
        self.max_ranges = [int(r) for r in max_ranges]
        self.scale = scale
        self.seed = seed
        self.span_s = span_s
        self.window_s = window_s
        self.queue_size = queue_size
        self.backend = backend
        self.paper_ratio = paper_ratio
        self._originals: Optional[Dict[str, Stream]] = None
        self._sims: Optional[Dict[Tuple[str, int], Stream]] = None

    def _prepare(self):
        if self._originals is None:
            self._originals = {
                ds: preprocess(make_stream(ds, scale=self.scale,
                                           seed=self.seed))
                for ds in self.datasets}
            if self.span_s is not None:
                self._originals = {ds: slice_stream(s, self.span_s)
                                   for ds, s in self._originals.items()}
            self._sims = {
                (ds, mr): nsa(self._originals[ds], mr)
                for ds in self.datasets for mr in self.max_ranges}
        return self._originals, self._sims

    def _replay(self, key, stream: Stream, task) -> Tuple[Dict, float]:
        metrics, wall = replay_many({key: stream}, task, self.queue_size)
        return metrics[key], wall

    def run(self, tasks: Sequence) -> List[TaskReport]:
        originals, sims = self._prepare()
        reports: List[TaskReport] = []
        for task in tasks:
            orig_runs = {
                ds: self._replay((ds, "original"),
                                 original_replay_stream(originals[ds]),
                                 task)
                for ds in self.datasets}
            keys = list(sims)
            sim_runs = {k: self._replay(k, sims[k], task) for k in keys}
            # one fused latency dispatch across the task's whole sweep
            summaries = summarize_latencies(
                [sim_runs[k][0]["task_latency_bins"] for k in keys],
                bin_us=getattr(task, "bin_us", LATENCY_BIN_US),
                n_bins=getattr(task, "n_bins", LATENCY_BINS),
                backend=self.backend)
            for k, latency in zip(keys, summaries):
                ds, mr = k
                om, ow = orig_runs[ds]
                sm, sw = sim_runs[k]
                corr = trend_correlation_matrix(
                    [om["task_output_counts"], sm["task_output_counts"]],
                    self.window_s, backend=self.backend)
                reports.append(TaskReport(
                    task=getattr(task, "name", type(task).__name__),
                    dataset=ds, max_range=mr,
                    t_original_s=ow, t_simulated_s=sw,
                    speedup=ow / sw if sw > 0 else float("inf"),
                    paper_ratio=self.paper_ratio,
                    trend_fidelity=float(corr[0, 1]),
                    cv_original=_cv(om["task_output_counts"]),
                    cv_simulated=_cv(sm["task_output_counts"]),
                    records_original=int(om["task_records"]),
                    records_simulated=int(sm["task_records"]),
                    latency=latency.to_dict()))
        return reports
