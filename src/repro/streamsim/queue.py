"""StreamQueue — the Kafka analogue (paper §4).

The paper inserts a Kafka node between the user-side producer and the stream
processing system: an ordered, buffered pipe with backpressure. This
environment has no external broker, so the queue is in-process but preserves
the broker semantics the pipeline relies on:

- FIFO per-bucket ordering (Kafka partition-order guarantee),
- bounded buffering with producer backpressure (broker retention/quota),
- at-least-once handoff (a bucket is only dropped after the consumer
  acknowledges it by finishing the ``get``),
- poisoned-shutdown (producer can signal end-of-stream).

Thread-safe: the real-time producer emits from timer threads (paper
Algorithm 2) while the consumer drains from the main thread.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

_EOS = object()


@dataclasses.dataclass
class Bucket:
    """One simulated second of stream data (what PSDA emits per tick)."""

    scale_stamp: int
    t: np.ndarray
    payload: Dict[str, np.ndarray]
    emit_time: float  # producer clock time at emission

    def __len__(self) -> int:
        return len(self.t)

    def nbytes(self) -> int:
        return self.t.nbytes + sum(v.nbytes for v in self.payload.values())


class StreamQueue:
    def __init__(self, maxsize: int = 64):
        self._dq: collections.deque = collections.deque()
        self._maxsize = maxsize
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        # transport metrics (paper Fig. 6 reads network bytes; we count them)
        self.bytes_in = 0
        self.buckets_in = 0
        self.records_in = 0

    def put(self, bucket: Bucket, timeout: Optional[float] = None) -> None:
        with self._not_full:
            while len(self._dq) >= self._maxsize and not self._closed:
                if not self._not_full.wait(timeout):
                    raise TimeoutError("queue full (backpressure timeout)")
            if self._closed:
                raise RuntimeError("queue closed")
            self._dq.append(bucket)
            self.bytes_in += bucket.nbytes()
            self.buckets_in += 1
            self.records_in += len(bucket)
            self._not_empty.notify()

    def get(self, timeout: Optional[float] = None) -> Optional[Bucket]:
        """Pop the next bucket; None signals end-of-stream."""
        with self._not_empty:
            while not self._dq and not self._closed:
                if not self._not_empty.wait(timeout):
                    raise TimeoutError("queue empty (consumer timeout)")
            if not self._dq:
                return None  # closed and drained
            item = self._dq.popleft()
            self._not_full.notify()
            return None if item is _EOS else item

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    def __iter__(self) -> Iterator[Bucket]:
        while True:
            b = self.get()
            if b is None:
                return
            yield b

    def qsize(self) -> int:
        with self._lock:
            return len(self._dq)

    def stats(self) -> Dict[str, Any]:
        return {
            "bytes_in": self.bytes_in,
            "buckets_in": self.buckets_in,
            "records_in": self.records_in,
        }


class QueueGroup:
    """Named bounded :class:`StreamQueue` s for one batched replay — the
    Kafka multi-topic analogue.

    A multi-queue replay (:class:`repro.streamsim.producer.
    MultiQueueProducer`) interleaves S scenarios' buckets in one
    virtual-time loop; each scenario keeps its OWN bounded queue here, so
    per-scenario ordering, stats, and at-least-once semantics are exactly
    the single-queue ones. Backpressure is *shared*: the single producer
    loop blocks on whichever member queue is full, stalling every
    scenario's emission — the broker-cluster behaviour of one producer
    feeding S topics with bounded retention. Consumers must therefore
    drain their queues concurrently (one thread per scenario;
    ``Controller.run_many`` does this) — a sequential drain can deadlock
    against a full sibling queue.
    """

    def __init__(self, keys, maxsize: int = 64):
        self.queues: Dict[Any, StreamQueue] = {
            k: StreamQueue(maxsize=maxsize) for k in keys}

    def __getitem__(self, key) -> StreamQueue:
        return self.queues[key]

    def __iter__(self):
        return iter(self.queues)

    def __len__(self) -> int:
        return len(self.queues)

    def items(self):
        return self.queues.items()

    def stats(self) -> Dict[Any, Dict[str, Any]]:
        """Per-scenario transport stats, keyed like the constructor."""
        return {k: q.stats() for k, q in self.queues.items()}
