"""StreamQueue — the Kafka analogue (paper §4).

The paper inserts a Kafka node between the user-side producer and the stream
processing system: an ordered, buffered pipe with backpressure. This
environment has no external broker, so the queue is in-process but preserves
the broker semantics the pipeline relies on:

- FIFO per-bucket ordering (Kafka partition-order guarantee),
- bounded buffering with producer backpressure (broker retention/quota),
- at-least-once handoff (a bucket is only dropped after the consumer
  acknowledges it by finishing the ``get``),
- poisoned-shutdown (producer can signal end-of-stream). ``close()``
  wakes BOTH blocked consumers (``get`` returns None once drained) and
  blocked producers — a producer stuck in ``put()`` on a full queue, or
  stuck on the group byte budget, raises ``RuntimeError("queue closed")``
  immediately instead of hanging until its timeout.

:class:`ByteBudget` adds the *broker retention* dimension: a
:class:`QueueGroup` built with ``max_bytes`` shares ONE byte budget
across its member queues, with two retention policies:

- ``"block"`` — a put that would exceed the budget blocks until
  consumers drain bytes (global backpressure; a bucket larger than the
  whole budget is admitted alone once the group is empty, so it can
  never deadlock the replay);
- ``"drop_oldest"`` — the globally-oldest buffered bucket (across ALL
  member queues) is evicted to make room, Kafka's retention-eviction
  behaviour; evictions are counted per queue (``dropped_retention`` in
  ``stats()``) and on the budget.

Thread-safe: the real-time producer emits from timer threads (paper
Algorithm 2) while the consumer drains from the main thread. Lock order
is budget → queue (the budget only ever takes a queue lock while holding
its own; queues never wait on the budget while holding their own lock),
so eviction, release, and close can never deadlock each other.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

_EOS = object()

RETENTION_POLICIES = ("block", "drop_oldest")


@dataclasses.dataclass
class Bucket:
    """One simulated second of stream data (what PSDA emits per tick)."""

    scale_stamp: int
    t: np.ndarray
    payload: Dict[str, np.ndarray]
    emit_time: float  # producer clock time at emission

    def __len__(self) -> int:
        return len(self.t)

    def nbytes(self) -> int:
        return self.t.nbytes + sum(v.nbytes for v in self.payload.values())


class ByteBudget:
    """A shared byte cap across a group of queues (broker retention).

    All admission control funnels through :meth:`reserve`; bytes are
    returned either by the consumer's ``get`` (:meth:`release`) or by a
    retention eviction (``drop_oldest``). The budget is the OUTER lock of
    the queue/budget pair: it may briefly take member-queue locks (head
    inspection, eviction) while held, but a queue never waits on the
    budget while holding its own lock.
    """

    def __init__(self, max_bytes: int, policy: str = "block"):
        if max_bytes <= 0:
            raise ValueError("max_bytes must be positive")
        if policy not in RETENTION_POLICIES:
            raise ValueError(
                f"policy must be one of {RETENTION_POLICIES}, got {policy!r}")
        self.max_bytes = int(max_bytes)
        self.policy = policy
        self.used = 0
        self.dropped_retention = 0
        self._seq = 0                      # global admission order
        self._queues: list = []
        self._cond = threading.Condition(threading.Lock())

    def register(self, queue: "StreamQueue") -> None:
        with self._cond:
            self._queues.append(queue)

    # ----------------------------------------------------------- admission
    def reserve(self, n: int, queue: "StreamQueue") -> int:
        """Claim ``n`` bytes for a bucket entering ``queue``; returns the
        bucket's global admission sequence number.

        ``block``: waits until the group frees bytes (or admits alone when
        the group is empty — an oversized bucket must not deadlock).
        ``drop_oldest``: evicts globally-oldest buckets until the new one
        fits (or nothing is left to evict). Raises ``RuntimeError`` if
        ``queue`` closes while blocked — close() must wake producers.
        """
        with self._cond:
            if self.policy == "drop_oldest":
                while self.used + n > self.max_bytes:
                    victim = self._pick_victim()
                    if victim is None:
                        break              # nothing buffered: admit over cap
                    freed = victim._evict_oldest()
                    if freed is None:
                        continue           # raced with a concurrent get
                    self.used -= freed
                    self.dropped_retention += 1
            else:
                # admit alone when empty: a bucket bigger than the whole
                # budget would otherwise block forever
                while self.used > 0 and self.used + n > self.max_bytes:
                    if queue._closed:
                        raise RuntimeError("queue closed")
                    # short waits double as a missed-wakeup safety net
                    self._cond.wait(0.05)
            if queue._closed:
                raise RuntimeError("queue closed")
            self.used += n
            seq = self._seq
            self._seq += 1
            return seq

    def release(self, n: int) -> None:
        with self._cond:
            self.used -= n
            self._cond.notify_all()

    def wake(self) -> None:
        """Wake blocked reservers (called by ``StreamQueue.close``)."""
        with self._cond:
            self._cond.notify_all()

    def _pick_victim(self) -> Optional["StreamQueue"]:
        """Member queue holding the globally-oldest buffered bucket."""
        best, best_seq = None, None
        for q in self._queues:
            s = q._head_seq()
            if s is not None and (best_seq is None or s < best_seq):
                best, best_seq = q, s
        return best

    def stats(self) -> Dict[str, Any]:
        with self._cond:
            return {
                "max_bytes": self.max_bytes,
                "policy": self.policy,
                "bytes_used": self.used,
                "dropped_retention": self.dropped_retention,
            }


class StreamQueue:
    def __init__(self, maxsize: int = 64,
                 budget: Optional[ByteBudget] = None):
        self._dq: collections.deque = collections.deque()
        self._seqs: collections.deque = collections.deque()
        self._maxsize = maxsize
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self._budget = budget
        if budget is not None:
            budget.register(self)
        # transport metrics (paper Fig. 6 reads network bytes; we count them)
        self.bytes_in = 0
        self.buckets_in = 0
        self.records_in = 0
        #: buckets evicted by the group byte budget (never seen by the
        #: consumer; at-least-once stops at broker retention, like Kafka)
        self.dropped_retention = 0

    def put(self, bucket: Bucket, timeout: Optional[float] = None) -> None:
        if self._budget is None:
            self._put_admitted(bucket, None, timeout)
            return
        nbytes = bucket.nbytes()
        # budget admission happens OUTSIDE the queue lock (lock order:
        # budget → queue); raises RuntimeError if the queue closes while
        # the producer is parked on the byte budget
        seq = self._budget.reserve(nbytes, self)
        try:
            self._put_admitted(bucket, seq, timeout)
        except BaseException:
            self._budget.release(nbytes)   # reservation must not leak
            raise

    def _put_admitted(self, bucket: Bucket, seq: Optional[int],
                      timeout: Optional[float]) -> None:
        with self._not_full:
            while len(self._dq) >= self._maxsize and not self._closed:
                if not self._not_full.wait(timeout):
                    raise TimeoutError("queue full (backpressure timeout)")
            if self._closed:
                raise RuntimeError("queue closed")
            self._dq.append(bucket)
            if seq is not None:
                self._seqs.append(seq)
            self.bytes_in += bucket.nbytes()
            self.buckets_in += 1
            self.records_in += len(bucket)
            self._not_empty.notify()

    def get(self, timeout: Optional[float] = None) -> Optional[Bucket]:
        """Pop the next bucket; None signals end-of-stream."""
        with self._not_empty:
            while not self._dq and not self._closed:
                if not self._not_empty.wait(timeout):
                    raise TimeoutError("queue empty (consumer timeout)")
            if not self._dq:
                return None  # closed and drained
            item = self._dq.popleft()
            if self._budget is not None and self._seqs:
                self._seqs.popleft()
            self._not_full.notify()
        # byte release happens OUTSIDE the queue lock (lock order) so a
        # blocked reserver can immediately take the budget lock
        if self._budget is not None and item is not _EOS:
            self._budget.release(item.nbytes())
        return None if item is _EOS else item

    def close(self) -> None:
        """Mark end-of-stream and wake EVERY blocked party: consumers
        drain to None, producers blocked on a full queue or on the group
        byte budget raise ``RuntimeError("queue closed")``."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        if self._budget is not None:
            self._budget.wake()

    @property
    def closed(self) -> bool:
        return self._closed

    # ------------------------------------------------- retention internals
    def _head_seq(self) -> Optional[int]:
        """Admission seq of the oldest buffered bucket (budget use only)."""
        with self._lock:
            return self._seqs[0] if self._seqs else None

    def _evict_oldest(self) -> Optional[int]:
        """Drop the oldest buffered bucket; returns its byte size (the
        budget credits it) or None if the queue emptied concurrently."""
        with self._lock:
            if not self._dq:
                return None
            item = self._dq.popleft()
            if self._seqs:
                self._seqs.popleft()
            self.dropped_retention += 1
            self._not_full.notify()
            return item.nbytes() if item is not _EOS else 0

    def __iter__(self) -> Iterator[Bucket]:
        while True:
            b = self.get()
            if b is None:
                return
            yield b

    def qsize(self) -> int:
        with self._lock:
            return len(self._dq)

    def stats(self) -> Dict[str, Any]:
        return {
            "bytes_in": self.bytes_in,
            "buckets_in": self.buckets_in,
            "records_in": self.records_in,
            "dropped_retention": self.dropped_retention,
        }


class QueueGroup:
    """Named bounded :class:`StreamQueue` s for one batched replay — the
    Kafka multi-topic analogue.

    A multi-queue replay (:class:`repro.streamsim.producer.
    MultiQueueProducer`) interleaves S scenarios' buckets in one
    virtual-time loop; each scenario keeps its OWN bounded queue here, so
    per-scenario ordering, stats, and at-least-once semantics are exactly
    the single-queue ones. Backpressure is *shared*: the single producer
    loop blocks on whichever member queue is full, stalling every
    scenario's emission — the broker-cluster behaviour of one producer
    feeding S topics with bounded retention. Consumers must therefore
    drain their queues concurrently (one thread per scenario;
    ``Controller.run_many`` does this) — a sequential drain can deadlock
    against a full sibling queue.

    ``max_bytes`` adds a GLOBAL byte cap across the member queues (broker
    retention, per the ROADMAP): ``retention_policy="block"`` turns the
    cap into shared byte backpressure, ``"drop_oldest"`` evicts the
    globally-oldest buffered bucket instead (counted in each queue's
    ``dropped_retention`` and in :meth:`budget_stats`).
    """

    def __init__(self, keys, maxsize: int = 64,
                 max_bytes: Optional[int] = None,
                 retention_policy: str = "block"):
        self.budget = (None if max_bytes is None
                       else ByteBudget(max_bytes, retention_policy))
        self.queues: Dict[Any, StreamQueue] = {
            k: StreamQueue(maxsize=maxsize, budget=self.budget)
            for k in keys}

    def __getitem__(self, key) -> StreamQueue:
        return self.queues[key]

    def __iter__(self):
        return iter(self.queues)

    def __len__(self) -> int:
        return len(self.queues)

    def items(self):
        return self.queues.items()

    def stats(self) -> Dict[Any, Dict[str, Any]]:
        """Per-scenario transport stats, keyed like the constructor."""
        return {k: q.stats() for k, q in self.queues.items()}

    def budget_stats(self) -> Optional[Dict[str, Any]]:
        """The shared byte budget's counters (None without ``max_bytes``)."""
        return None if self.budget is None else self.budget.stats()
