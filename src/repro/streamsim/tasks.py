"""RIoTBench-style stream-task tier — the SPS workloads the paper times.

The paper's headline claim is about a *stream processing task*: replaying
the NSA-compressed stream accelerates the task >= 24x while preserving the
volatility and trends its output depends on. This module supplies the
tasks. The taxonomy follows Shukla & Simmhan's RIoTBench application
dataflows (ETL, statistical summarization, pattern/event detection) with
the detection task following Karras et al.'s threshold/CUSUM event
detectors, plus a serving workload wrapping :mod:`repro.serving`.

Every task is a drop-in replay consumer — ``task(queue) -> dict`` — so it
plugs unchanged into :func:`repro.streamsim.engine.replay_one`/
``replay_many`` and :meth:`repro.streamsim.controller.Controller.run_many`
(including the chunked multi-day path). All per-replay state lives in a
per-call state object, so ONE task instance can drain many sweep scenarios
concurrently (the engine runs one consumer thread per scenario).

Each call returns, alongside task-specific metrics:

- ``task_output_counts`` — the task's OWN output stream as per-second
  counts indexed by scale stamp, the series the taskbench correlates
  between original and simulated replays (the fidelity half of the claim);
- ``task_latency_bins`` — per-bucket processing latency quantized into
  ``bin_us``-wide integer bins. The bins are plain scale-stamp-shaped
  integers, so a whole sweep's worth feeds ONE fused
  :func:`repro.kernels.ops.stream_metrics_batched` dispatch
  (see :func:`repro.streamsim.taskbench.summarize_latencies`) from whose
  device-resident histogram rows p50/p99/p999, throughput and jitter fall
  out. Latency bins are wall-time measurements and are therefore the one
  non-deterministic output; everything else is a pure function of the
  replayed buckets.
"""

from __future__ import annotations

import heapq
import time
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.streamsim.metrics import sliding_mean
from repro.streamsim.queue import Bucket, StreamQueue

__all__ = [
    "LATENCY_BINS",
    "LATENCY_BIN_US",
    "BucketTask",
    "ETLTask",
    "EventDetectTask",
    "ServingTask",
    "StreamTask",
    "WindowedStatsTask",
    "output_series",
]

#: default latency-histogram geometry shared by the tasks and the
#: taskbench summary: bins of ``LATENCY_BIN_US`` microseconds, the last
#: bin absorbing everything past ``LATENCY_BINS * LATENCY_BIN_US``.
LATENCY_BIN_US = 5.0
LATENCY_BINS = 2048


class StreamTask:
    """Structural contract of a stream task (duck-typed, no ABC machinery):
    a named callable consuming one scenario's queue and returning a metrics
    dict that carries ``task_output_counts`` + ``task_latency_bins``."""

    #: task name, surfaced in reports and in the engine's wedged-consumer
    #: deadline errors (see :func:`repro.streamsim.engine.consumer_label`)
    name: str = "task"

    def __call__(self, queue: StreamQueue) -> Dict:
        raise NotImplementedError


def output_series(stamps, counts) -> np.ndarray:
    """Per-second output series from (scale stamp, count) pairs.

    Duplicate stamps accumulate (a duplicated bucket under a fault plan
    lands on the same simulated second, exactly like a duplicated Kafka
    record would); the array spans ``[0, max(stamp)]``.
    """
    stamps = np.asarray(stamps, np.int64).reshape(-1)
    counts = np.asarray(counts, np.int64).reshape(-1)
    if len(stamps) == 0:
        return np.zeros(0, np.int64)
    if stamps.min() < 0:
        raise ValueError("scale stamps must be non-negative")
    out = np.zeros(int(stamps.max()) + 1, np.int64)
    np.add.at(out, stamps, counts)
    return out


class BucketTask(StreamTask):
    """Shared per-bucket machinery for the host-side tasks.

    Subclasses implement ``_start() -> state``, ``_process(state, bucket)
    -> int`` (the task's output count for that bucket) and optionally
    ``_finalize(state, out) -> dict`` (extra metrics, and the place to
    flush any held-back input). The base class owns the consumer loop,
    the per-bucket latency clock, and the common metric keys.
    """

    name = "bucket-task"

    def __init__(self, *, bin_us: float = LATENCY_BIN_US,
                 n_bins: int = LATENCY_BINS):
        if bin_us <= 0:
            raise ValueError("bin_us must be positive")
        if n_bins < 2:
            raise ValueError("n_bins must be >= 2")
        self.bin_us = float(bin_us)
        self.n_bins = int(n_bins)

    # ------------------------------------------------------ subclass hooks
    def _start(self):
        raise NotImplementedError

    def _process(self, state, bucket: Bucket) -> int:
        raise NotImplementedError

    def _finalize(self, state, out: np.ndarray) -> Dict:
        return {}

    # --------------------------------------------------- consumer contract
    def __call__(self, queue: StreamQueue) -> Dict:
        state = self._start()
        stamps: List[int] = []
        emitted: List[int] = []
        lat: List[int] = []
        records = 0
        t0 = time.perf_counter()
        for bucket in queue:
            tb = time.perf_counter()
            n_out = self._process(state, bucket)
            dt_us = (time.perf_counter() - tb) * 1e6
            lat.append(min(int(dt_us / self.bin_us), self.n_bins - 1))
            records += len(bucket)
            stamps.append(int(bucket.scale_stamp))
            emitted.append(int(n_out))
        wall = time.perf_counter() - t0
        out = output_series(stamps, emitted)
        metrics = {
            "task": self.name,
            "task_buckets": len(lat),
            "task_records": records,
            "task_wall_s": wall,
            "task_throughput_rps": records / wall if wall > 0 else 0.0,
            "task_latency_bins": np.asarray(lat, np.int32),
            "task_output_counts": out,
        }
        metrics.update(self._finalize(state, out))
        return metrics


# --------------------------------------------------------------- ETL task
def _parse_column(values: np.ndarray) -> np.ndarray:
    """Parse one payload column to float64. String columns hash through
    crc32 (stable across processes, unlike ``hash``) so the parse work is
    real but reproducible."""
    v = np.asarray(values)
    if v.dtype.kind in "US":
        return np.array([zlib.crc32(str(s).encode()) % 10_000 for s in v],
                        np.float64)
    return v.astype(np.float64)


class ETLTask(BucketTask):
    """Parse / clean / annotate per bucket (the RIoTBench ETL dataflow).

    Per bucket: every payload column is parsed to float64; records with a
    non-finite or out-of-``bounds`` value in ANY column are dropped
    (clean); survivors are annotated with a per-record feature (the column
    sum) folded into a running checksum so the annotate stage cannot be
    dead-code-eliminated. Output stream = cleaned records per second.

    Parameters
    ----------
    bounds : dict, optional
        ``{column: (lo, hi)}`` inclusive validity ranges; columns absent
        from the dict are only checked for finiteness.
    """

    name = "etl"

    def __init__(self, bounds: Optional[Dict[str, Tuple[float, float]]]
                 = None, **kw):
        super().__init__(**kw)
        self.bounds = dict(bounds or {})

    def _start(self):
        return {"clean": 0, "dirty": 0, "checksum": 0}

    def _process(self, state, bucket: Bucket) -> int:
        n = len(bucket)
        keep = np.ones(n, bool)
        annot = np.zeros(n, np.float64)
        for col, values in bucket.payload.items():
            x = _parse_column(values)
            finite = np.isfinite(x)
            lo, hi = self.bounds.get(col, (-np.inf, np.inf))
            keep &= finite & (x >= lo) & (x <= hi)
            annot += np.where(finite, x, 0.0)
        kept = int(keep.sum())
        state["clean"] += kept
        state["dirty"] += n - kept
        state["checksum"] = (state["checksum"]
                             + int(np.round(annot[keep].sum()))) % (2 ** 31)
        return kept

    def _finalize(self, state, out):
        return {"etl_clean": state["clean"], "etl_dirty": state["dirty"],
                "etl_checksum": state["checksum"]}


# --------------------------------------------------------------- STATS task
class WindowedStatsTask(BucketTask):
    """Tumbling/sliding count aggregates (the RIoTBench STATS dataflow).

    Accumulates the per-second record counts keyed by scale stamp and
    aggregates at stream close: ``mode="sliding"`` reuses
    :func:`repro.streamsim.metrics.sliding_mean`'s O(n) cumulative-sum
    machinery (same zero-padded-edge convention), ``mode="tumbling"``
    means over non-overlapping ``window_s`` blocks (the trailing partial
    window divides by its true length). The task's output stream is the
    per-second count series it forwards; the aggregate rides in the
    metrics dict.
    """

    name = "windowed-stats"

    def __init__(self, window_s: int = 60, mode: str = "sliding", **kw):
        super().__init__(**kw)
        if mode not in ("sliding", "tumbling"):
            raise ValueError(f"mode must be 'sliding' or 'tumbling', "
                             f"got {mode!r}")
        if window_s < 1:
            raise ValueError("window_s must be >= 1")
        self.window_s = int(window_s)
        self.mode = mode

    def aggregate(self, q: np.ndarray) -> np.ndarray:
        """The windowed aggregate of a per-second count series (public so
        the property suite can check it against an O(n*w) oracle)."""
        q = np.asarray(q, np.float64).reshape(-1)
        if self.mode == "sliding":
            return sliding_mean(q, self.window_s)
        n, w = len(q), self.window_s
        if n == 0:
            return q
        n_win = -(-n // w)
        padded = np.zeros(n_win * w, np.float64)
        padded[:n] = q
        sums = padded.reshape(n_win, w).sum(axis=1)
        lengths = np.minimum(w, n - w * np.arange(n_win))
        return sums / lengths

    def _start(self):
        return {}

    def _process(self, state, bucket: Bucket) -> int:
        return len(bucket)

    def _finalize(self, state, out):
        agg = self.aggregate(out)
        return {"stats_mode": self.mode, "stats_window_s": self.window_s,
                "stats_aggregate": agg,
                "stats_peak": float(agg.max()) if len(agg) else 0.0,
                "stats_mean": float(agg.mean()) if len(agg) else 0.0}


# ----------------------------------------------------------- detection task
class EventDetectTask(BucketTask):
    """Threshold / CUSUM event detection (Karras et al.'s detector pair).

    Processes the per-bucket record counts as an online sample sequence:

    - ``mode="threshold"`` fires an event for every bucket whose count
      exceeds ``threshold``. Because the event is stamped with the
      triggering bucket's own scale stamp, the SET of event stamps is
      invariant under ANY arrival reorder.
    - ``mode="cusum"`` keeps a one-sided CUSUM against a Welford running
      mean: ``s = max(0, s + (x - mean - drift))``, alarming (and
      resetting) when ``s > h``. Order-sensitive by nature, so a
      ``reorder_tolerance`` is offered:

    ``reorder_tolerance=w`` holds arriving buckets in a min-heap keyed by
    (scale stamp, arrival seq) and only processes a bucket once ``w``
    newer ones have arrived — the streaming watermark idiom. A sequence
    in which every bucket is displaced at most ``w`` positions from stamp
    order is fully re-sorted by a ``w+1``-deep heap, so detection under a
    bounded fault-plan reorder (``FaultSpec.reorder_window <= w``) is
    IDENTICAL to the in-order replay.

    ``task_events`` in the metrics dict carries the event stamps;
    ``task_output_counts`` attributes each event to the bucket being
    processed when it fired (off by <= ``reorder_tolerance`` seconds from
    the triggering stamp; events flushed at close land only in
    ``task_events``).
    """

    name = "event-detect"

    def __init__(self, mode: str = "threshold",
                 threshold: Optional[float] = None, drift: float = 0.5,
                 h: float = 5.0, reorder_tolerance: int = 0, **kw):
        super().__init__(**kw)
        if mode not in ("threshold", "cusum"):
            raise ValueError(f"mode must be 'threshold' or 'cusum', "
                             f"got {mode!r}")
        if mode == "threshold" and threshold is None:
            raise ValueError("mode='threshold' requires a threshold")
        if reorder_tolerance < 0:
            raise ValueError("reorder_tolerance must be >= 0")
        self.mode = mode
        self.threshold = threshold
        self.drift = float(drift)
        self.h = float(h)
        self.reorder_tolerance = int(reorder_tolerance)

    def _start(self):
        return {"pending": [], "seq": 0, "events": [],
                "cusum": 0.0, "mean": 0.0, "n": 0}

    def _step(self, state, stamp: int, x: float) -> int:
        if self.mode == "threshold":
            if x > self.threshold:
                state["events"].append(stamp)
                return 1
            return 0
        state["n"] += 1
        state["mean"] += (x - state["mean"]) / state["n"]
        state["cusum"] = max(
            0.0, state["cusum"] + (x - state["mean"] - self.drift))
        if state["cusum"] > self.h:
            state["events"].append(stamp)
            state["cusum"] = 0.0
            return 1
        return 0

    def _process(self, state, bucket: Bucket) -> int:
        heapq.heappush(state["pending"],
                       (int(bucket.scale_stamp), state["seq"], len(bucket)))
        state["seq"] += 1
        fired = 0
        while len(state["pending"]) > self.reorder_tolerance:
            stamp, _, x = heapq.heappop(state["pending"])
            fired += self._step(state, stamp, float(x))
        return fired

    def _finalize(self, state, out):
        while state["pending"]:   # flush the watermark buffer, in order
            stamp, _, x = heapq.heappop(state["pending"])
            self._step(state, stamp, float(x))
        events = np.asarray(state["events"], np.int64)
        return {"detect_mode": self.mode, "detect_events": len(events),
                "detect_tolerance": self.reorder_tolerance,
                "task_events": events}


# -------------------------------------------------------------- serving task
class ServingTask(StreamTask):
    """Serving workload: :class:`repro.serving.engine.ServingEngine` fed by
    :func:`repro.serving.load.stream_arrivals` — the SPS-as-inference-job.

    Unlike the bucket tasks, latency bins come from the ENGINE's
    per-request latencies (arrival -> finish across ticks), so the
    device histogram summarizes request latency, not host per-bucket
    wall time. Output stream = requests admitted per simulated second
    (the arrival mix the replayed volatility shapes).

    ``reuse_engine=True`` builds ONE engine up front and resets its
    state between calls, keeping the jitted prefill/decode traces warm —
    required for speedup measurements (a fresh engine per call pays
    retracing in both runs and measures the compiler, not the stream).
    A reused engine is NOT safe for concurrent scenario consumers; leave
    the default for multi-scenario sweeps.

    The default latency bins are 1 ms wide (vs the bucket tasks' 5 us):
    request latencies span model steps plus queueing, three orders of
    magnitude above per-bucket host work.
    """

    name = "serving"

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 48,
                 eos_id: int = -1, prompt_len: int = 4,
                 max_new_tokens: int = 4, max_requests_per_bucket: int = 2,
                 reuse_engine: bool = False,
                 bin_us: float = 1000.0, n_bins: int = LATENCY_BINS):
        if bin_us <= 0:
            raise ValueError("bin_us must be positive")
        if n_bins < 2:
            raise ValueError("n_bins must be >= 2")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.max_requests_per_bucket = max_requests_per_bucket
        self.reuse_engine = reuse_engine
        self.bin_us = float(bin_us)
        self.n_bins = int(n_bins)
        self._engine = self._make_engine() if reuse_engine else None

    def _make_engine(self):
        from repro.serving.engine import ServingEngine
        return ServingEngine(self.cfg, self.params, slots=self.slots,
                             max_len=self.max_len, eos_id=self.eos_id)

    def _reset_engine(self, eng):
        from repro.models import transformer
        from repro.serving.engine import ServeMetrics
        eng.cache = transformer.init_cache(self.cfg, self.slots,
                                           self.max_len)
        eng.active = [None] * self.slots
        eng.waiting = []
        eng.metrics = ServeMetrics()
        eng._last_tokens = np.zeros((self.slots,), np.int32)

    def __call__(self, queue: StreamQueue) -> Dict:
        from repro.serving.load import stream_arrivals
        if self._engine is not None:
            eng = self._engine
            self._reset_engine(eng)
        else:
            eng = self._make_engine()
        stamps: List[int] = []
        admitted: List[int] = []
        records = buckets = 0
        t0 = time.perf_counter()
        for ss, reqs in stream_arrivals(
                queue, self.cfg.vocab_size, prompt_len=self.prompt_len,
                max_new_tokens=self.max_new_tokens,
                max_requests_per_bucket=self.max_requests_per_bucket):
            buckets += 1
            for req in reqs:
                # stream_arrivals stamps arrive_t with the bucket's
                # VIRTUAL emit time; the engine ticks on the wall clock.
                # Restamp on the engine's clock so request latency is
                # wall queueing + decode, not the clock-domain gap.
                req.arrive_t = time.perf_counter()
                eng.submit(req)
            records += len(reqs)
            eng.tick()
            stamps.append(int(ss))
            admitted.append(len(reqs))
        eng.drain()
        wall = time.perf_counter() - t0
        lat = np.asarray(
            [min(int(l * 1e6 / self.bin_us), self.n_bins - 1)
             for l in eng.metrics.latencies_s], np.int32)
        summary = eng.metrics.summary()
        return {
            "task": self.name,
            "task_buckets": buckets,
            "task_records": records,
            "task_wall_s": wall,
            "task_throughput_rps": records / wall if wall > 0 else 0.0,
            "task_latency_bins": lat,
            "task_output_counts": output_series(stamps, admitted),
            "serving_finished": summary["finished"],
            "serving_tokens_out": summary["tokens_out"],
            "serving_queue_peak": summary["queue_peak"],
        }
