"""POSD — Preprocessing Original Stream Data (paper §3.1).

Jobs, per the paper:
  1. *identify* the field that carries time information (timestamp or
     "accurate time" ``YYYY-MM-DD HH:MM:SS``),
  2. convert accurate-time strings to timestamps,
  3. unify time zones (the UserBehavior quirk),
  4. persist the result — preprocessing is a one-time job, so the cleaned
     stream goes to the store ("database").

Everything is vectorized numpy; the output is a :class:`Stream` whose
``t`` array is float64 epoch-seconds, guaranteed non-decreasing.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.streamsim.datasets import RawStream, USERBEHAVIOR_TZ_OFFSET

# Heuristic vocabulary for time-column identification.
_TIME_HINTS = ("time", "timestamp", "ts", "date")


@dataclasses.dataclass
class Stream:
    """A preprocessed bounded stream: tuples <X_i, t_i> (paper Def. 2).

    ``t``       : float64 epoch-seconds, non-decreasing (chronological order).
    ``payload`` : remaining record fields (the X_i), aligned with ``t``.
    ``scale_stamp`` : filled in by NSA (None until then).
    """

    name: str
    t: np.ndarray
    payload: Dict[str, np.ndarray]
    scale_stamp: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self.t)

    @property
    def time_range(self) -> float:
        """Original time range of the stream in seconds (paper: 86 400)."""
        if len(self.t) == 0:
            return 0.0
        return float(self.t[-1] - self.t[0])

    def nbytes(self) -> int:
        n = self.t.nbytes + sum(v.nbytes for v in self.payload.values())
        if self.scale_stamp is not None:
            n += self.scale_stamp.nbytes
        return n


def identify_time_column(columns: Dict[str, np.ndarray]) -> str:
    """Find the column carrying time information.

    Preference order: (1) name contains a time hint AND parses as time,
    (2) any column that parses as an accurate-time string, (3) any monotone
    non-decreasing numeric column spanning a plausible epoch range.
    """
    hinted = [c for c in columns if any(h in c.lower() for h in _TIME_HINTS)]
    for name in hinted + [c for c in columns if c not in hinted]:
        col = columns[name]
        if _parses_as_time(col):
            return name
    raise ValueError(
        "no time column found — the framework requires streams to carry a "
        "timestamp or accurate time (paper advantage (2): universality)")


def _parses_as_time(col: np.ndarray) -> bool:
    head = col[: min(len(col), 64)]
    if col.dtype.kind in "US":  # accurate-time strings
        try:
            np.array(np.char.replace(head.astype(str), " ", "T"),
                     dtype="datetime64[s]")
            return True
        except ValueError:
            return False
    if col.dtype.kind in "if":
        # plausible epoch seconds (year ~1990..2100) and non-decreasing head
        h = head.astype(np.float64)
        if len(h) == 0:
            return False
        in_epoch = np.all((h > 6.0e8) & (h < 4.2e9))
        return bool(in_epoch and np.all(np.diff(h) >= 0))
    return False


def to_epoch_seconds(col: np.ndarray) -> np.ndarray:
    """Convert a time column (strings or numerics) to float64 epoch seconds."""
    if col.dtype.kind in "US":
        iso = np.char.replace(col.astype(str), " ", "T")
        dt = np.array(iso, dtype="datetime64[s]")
        return dt.astype("int64").astype(np.float64)
    return col.astype(np.float64)


def unify_timezone(t: np.ndarray, *, tz_offset_s: float = 0.0) -> np.ndarray:
    """Shift timestamps recorded in a non-reference zone back to reference.

    The paper: "some stream data use timestamps in different time zones such
    as UserBehavior, which requires timestamps using different time zones to
    be converted into the ones that using the same time zone."
    """
    if tz_offset_s == 0.0:
        return t
    return t - tz_offset_s


# Known per-dataset zone offsets (would be config/metadata in production).
_TZ_OFFSETS = {"userbehavior": float(USERBEHAVIOR_TZ_OFFSET)}


def preprocess(raw: RawStream, *, tz_offset_s: Optional[float] = None,
               sort_if_needed: bool = True) -> Stream:
    """Run POSD over a raw stream: identify + parse + zone-unify (+ sort).

    Sorting is a guard: real logs are chronological by construction
    (paper Def. 1) but the framework verifies rather than trusts.
    """
    time_col = identify_time_column(raw.columns)
    t = to_epoch_seconds(raw.columns[time_col])
    if tz_offset_s is None:
        tz_offset_s = _TZ_OFFSETS.get(raw.name, 0.0)
    t = unify_timezone(t, tz_offset_s=tz_offset_s)
    payload = {k: v for k, v in raw.columns.items() if k != time_col}
    if sort_if_needed and len(t) > 1 and np.any(np.diff(t) < 0):
        order = np.argsort(t, kind="stable")
        t = t[order]
        payload = {k: v[order] for k, v in payload.items()}
    return Stream(name=raw.name, t=t, payload=payload)
