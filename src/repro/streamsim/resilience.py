"""Resilience primitives — retries, breakers, deadlines, checkpoints.

The replay layer (:func:`~repro.streamsim.engine.replay_many`) assumed a
perfect consumer: one crash failed the whole sweep, one wedged consumer
hung it forever, and a killed sweep restarted from zero. This module
provides the four primitives the engine wires in:

- :class:`RetryPolicy` — capped exponential backoff with **deterministic**
  jitter (hash of ``(seed, key, attempt)``, not wall-clock randomness),
  so a retried sweep is as reproducible as a clean one.
- :class:`Deadline` — a monotonic time budget; the engine uses it to
  bound consumer ``join()`` s so a wedged consumer surfaces as a *named
  scenario failure* instead of an indefinite hang.
- :class:`CircuitBreaker` — per-scenario consecutive-failure breaker;
  once open, further retries of that scenario are refused and the
  scenario degrades to a partial report instead of burning the backoff
  budget (and the sweep's wall clock) on a persistently-broken consumer.
- :class:`SweepCheckpoint` — per-scenario completion markers persisted
  through the :class:`~repro.streamsim.store.StreamStore` (atomic JSON
  writes), so ``Controller.run_many(checkpoint=True)`` resumes a killed
  sweep from the last completed scenario with reports equal to an
  uninterrupted run.
- :class:`Lease` / :class:`Heartbeat` — the sweep-service claim record
  (PR 9): a lease binds a queued scenario to a worker for ``ttl_s``
  seconds; a background :class:`Heartbeat` thread renews the deadline
  while the worker computes, so only a *dead or wedged* worker's lease
  ever expires and gets reaped (``docs/robustness.md`` documents the
  full queue → lease → result protocol).

All primitives are pure-host, numpy-free, and deliberately boring: the
interesting guarantees (schedule determinism, report equality across a
kill/resume) live in the tests.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "RetryPolicy",
    "Deadline",
    "CircuitBreaker",
    "BreakerOpen",
    "SweepCheckpoint",
    "Lease",
    "Heartbeat",
]


def _hash_uniform(seed: int, key: object, attempt: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, key, attempt)."""
    digest = hashlib.sha256(
        f"retry:{seed}|{key!r}|{attempt}".encode()).digest()
    return int.from_bytes(digest[:8], "big") / 2 ** 64


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff + deterministic jitter.

    ``delay(attempt, key)`` for 1-based *failed* attempt numbers:
    ``min(max_delay_s, base_delay_s * multiplier ** (attempt - 1))``
    scaled by ``1 + jitter * u`` with ``u`` the hash-uniform of
    ``(seed, key, attempt)`` — two scenarios (or two attempts) never
    share a jitter draw, yet the whole backoff sequence is reproducible
    from the policy alone.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("delays must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")

    def delay(self, attempt: int, key: object = None) -> float:
        """Backoff before retry number ``attempt`` (1-based failures)."""
        if attempt < 1:
            raise ValueError("attempt is 1-based")
        base = min(self.max_delay_s,
                   self.base_delay_s * self.multiplier ** (attempt - 1))
        return base * (1.0 + self.jitter *
                       _hash_uniform(self.seed, key, attempt))

    def delays(self, key: object = None) -> List[float]:
        """The full backoff schedule (one entry per retry)."""
        return [self.delay(a, key) for a in range(1, self.max_attempts)]


class Deadline:
    """A monotonic time budget (``None`` seconds == no deadline)."""

    def __init__(self, seconds: Optional[float],
                 clock: Callable[[], float] = time.monotonic):
        self.seconds = seconds
        self._clock = clock
        self._t0 = clock()

    def remaining(self) -> Optional[float]:
        """Seconds left (clamped to 0), or None for no deadline."""
        if self.seconds is None:
            return None
        return max(0.0, self._t0 + self.seconds - self._clock())

    @property
    def expired(self) -> bool:
        rem = self.remaining()
        return rem is not None and rem <= 0.0


class BreakerOpen(RuntimeError):
    """Raised when work is attempted through an open circuit breaker."""


class CircuitBreaker:
    """Per-scenario consecutive-failure breaker (closed → open →
    half-open).

    ``failure_threshold`` consecutive failures open the breaker; while
    open, :meth:`allow` is False. After ``recovery_s`` (monotonic
    seconds; ``None`` = never) the breaker half-opens: ONE probe attempt
    is allowed, and its outcome closes (success) or re-opens (failure)
    the breaker. A success in the closed state resets the failure count.
    """

    def __init__(self, failure_threshold: int = 3,
                 recovery_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = failure_threshold
        self.recovery_s = recovery_s
        self._clock = clock
        self.failures = 0
        self.state = "closed"          # closed | open | half-open
        self._opened_at: Optional[float] = None

    def allow(self) -> bool:
        if self.state == "closed":
            return True
        if self.state == "open":
            if (self.recovery_s is not None and
                    self._clock() - self._opened_at >= self.recovery_s):
                self.state = "half-open"
                return True
            return False
        return True                    # half-open: the single probe

    def record_success(self) -> None:
        self.failures = 0
        self.state = "closed"
        self._opened_at = None

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half-open" or \
                self.failures >= self.failure_threshold:
            self.state = "open"
            self._opened_at = self._clock()


# ------------------------------------------------------------- checkpoints
class SweepCheckpoint:
    """Per-scenario sweep completion markers in the stream store.

    Layout (see ``docs/robustness.md`` for the format contract)::

        <store root>/_markers/<sweep_id>/
            materialized__<dataset>__<max_range>.json
            report__<dataset>__<max_range>.json

    ``materialized`` markers record that a scenario's simulated stream is
    persisted (written by :meth:`~repro.streamsim.engine.
    DeviceSweepResult.materialize`); ``report`` markers carry the full
    :class:`~repro.streamsim.engine.SimulationReport` JSON (written as
    each report is assembled). On resume, report markers short-circuit
    the scenario entirely — its stream is already a store cache hit and
    its report loads from the marker — so a sweep killed after k
    scenarios redoes only the remaining ones. Marker writes are atomic
    (temp file + rename, the store's discipline), so a kill mid-write
    never yields a half-marker.

    ``sweep_id`` should identify the sweep *configuration* (grid + scale
    + seed + host slot — :attr:`~repro.streamsim.plan.SweepPlan.sweep_id`
    provides exactly that), so a restarted run with the same arguments
    finds its own markers and a different sweep never collides.
    """

    def __init__(self, store, sweep_id: str):
        self.store = store
        self.sweep_id = sweep_id

    # ------------------------------------------------------------- naming
    @staticmethod
    def _name(kind: str, scenario: Tuple[str, int]) -> str:
        d, mr = scenario
        return f"{kind}__{d}__{mr}"

    # ------------------------------------------------------------ writing
    def mark_materialized(self, scenarios) -> None:
        for sc in scenarios:
            self.store.put_marker(self.sweep_id,
                                  self._name("materialized", sc),
                                  {"dataset": sc[0], "max_range": sc[1]})

    def mark_report(self, report) -> None:
        sc = (report.dataset, report.max_range)
        self.store.put_marker(self.sweep_id, self._name("report", sc),
                              report.to_json())

    # ------------------------------------------------------------ reading
    def done_scenarios(self) -> List[Tuple[str, int]]:
        """Scenarios with a completed report marker."""
        out = []
        for name in self.store.list_markers(self.sweep_id):
            if name.startswith("report__"):
                _, d, mr = name.split("__")
                out.append((d, int(mr)))
        return out

    def load_reports(self) -> Dict[Tuple[str, int], "object"]:
        """scenario -> SimulationReport for every report marker."""
        from repro.streamsim.engine import SimulationReport
        out = {}
        for sc in self.done_scenarios():
            payload = self.store.get_marker(
                self.sweep_id, self._name("report", sc))
            out[sc] = SimulationReport.from_json(payload)
        return out

    def materialized_scenarios(self) -> List[Tuple[str, int]]:
        out = []
        for name in self.store.list_markers(self.sweep_id):
            if name.startswith("materialized__"):
                _, d, mr = name.split("__")
                out.append((d, int(mr)))
        return out

    def clear(self) -> None:
        self.store.clear_markers(self.sweep_id)


# ------------------------------------------------------------------ leases
@dataclasses.dataclass
class Lease:
    """One worker's claim on one queued sweep scenario.

    Persisted as the lease-marker payload in the service's
    ``<group>/leases/`` namespace. ``deadline`` is *wall-clock*
    (``time.time()``) because leases are judged by OTHER processes —
    possibly on other hosts — where a monotonic clock has no shared
    origin; ``beat`` is a per-renewal counter so a reaper can tell a
    renewed lease from a stale re-read even under coarse filesystem
    timestamps. ``attempts`` counts how many leases this scenario has
    ever been granted (the poison-quarantine input: each expired lease
    is one "this scenario killed a worker" strike).
    """

    worker: str
    dataset: str
    max_range: int
    ttl_s: float
    deadline: float
    attempts: int = 1
    beat: int = 0

    def expired(self, now: Optional[float] = None) -> bool:
        return (time.time() if now is None else now) > self.deadline

    def renew(self, now: Optional[float] = None) -> "Lease":
        now = time.time() if now is None else now
        return dataclasses.replace(self, deadline=now + self.ttl_s,
                                   beat=self.beat + 1)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, payload: Dict) -> "Lease":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in payload.items() if k in fields})


class Heartbeat:
    """Daemon thread that renews a batch of leases while work runs.

    Rewrites each lease marker every ``ttl_s / 3`` seconds (so a healthy
    worker gets ~3 renewal chances per TTL window before a reaper could
    act). A lease whose marker has *vanished* is dropped from the renewal
    set rather than resurrected: the marker disappearing means a reaper
    already reclaimed it (this worker overran its TTL — e.g. a long GC
    pause), and rewriting it would fight the reaper's decision. The
    worker discovers the loss via :attr:`lost` and skips publishing.

    Renewal is *wall-clock extension only* — a worker wedged inside the
    consumer keeps heartbeating, which is exactly why wedge detection is
    delegated to the engine's ``consumer_deadline_s`` (the lease protocol
    only defends against *dead* workers).
    """

    def __init__(self, store, sweep_id: str, leases: Dict[str, Lease],
                 *, interval_s: Optional[float] = None):
        self.store = store
        self.sweep_id = sweep_id
        self.leases = dict(leases)     # marker name -> Lease
        ttl = min((l.ttl_s for l in self.leases.values()), default=1.0)
        self.interval_s = interval_s if interval_s is not None else ttl / 3.0
        self.lost: List[str] = []      # marker names a reaper reclaimed
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="sweep-lease-heartbeat")

    def _renew_all(self) -> None:
        for name in list(self.leases):
            if not self.store.has_marker(self.sweep_id, name):
                self.lost.append(name)
                del self.leases[name]
                continue
            lease = self.leases[name].renew()
            self.store.put_marker(self.sweep_id, name, lease.to_json())
            self.leases[name] = lease

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._renew_all()

    def __enter__(self) -> "Heartbeat":
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10.0)
