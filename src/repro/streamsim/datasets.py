"""Synthetic IoT stream datasets, statistically matched to the paper's three.

The container is offline, so the real SogouQ / Baidu-Traffic / Taobao
UserBehavior dumps cannot be downloaded. Each generator below produces a
seeded, *statistically matched* surrogate: a non-homogeneous Poisson arrival
process over one day (86 400 s) whose diurnal intensity curve is calibrated so
the per-second Average / Variance / StdVariance land in the magnitude range of
the paper's Tables 1-3:

  ============== ============ ============= =================
  dataset        avg (rec/s)  variance      paper table
  ============== ============ ============= =================
  SogouQ         ~25.4        ~235          Table 1
  Traffic        ~21.5        ~113          Table 2
  UserBehavior   ~122         ~4 545        Table 3
  ============== ============ ============= =================

Records carry the same field structure as the originals (query logs,
map queries, user-behavior tuples) so the POSD stage has real parsing work:
SogouQ carries "accurate time" strings (YYYY-MM-DD HH:MM:SS), UserBehavior
carries timestamps offset into a different time zone (the paper calls out
exactly this quirk), Traffic carries float epoch timestamps.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

DAY = 86_400  # the paper's original time range, seconds

# UserBehavior timestamps are (per the paper) in a different time zone;
# we emulate UTC+0 storage of a UTC+8 stream.
USERBEHAVIOR_TZ_OFFSET = 8 * 3600


@dataclasses.dataclass(frozen=True)
class RawStream:
    """An unpreprocessed bounded stream B = s_1..s_n (paper Def. 2).

    ``columns`` maps field name -> 1-D np.ndarray, all of equal length, in
    arrival order. Exactly one column carries time information but it is NOT
    labelled as such — identifying it is POSD's job.
    """

    name: str
    columns: Dict[str, np.ndarray]

    def __len__(self) -> int:
        return len(next(iter(self.columns.values())))


def _smooth_noise(seconds: np.ndarray, scale_s: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Unit-variance noise correlated at timescale ``scale_s`` (linear
    interpolation of an i.i.d. grid — a cheap Ornstein-Uhlenbeck stand-in)."""
    knots = rng.standard_normal(int(DAY / scale_s) + 2)
    axis = np.arange(len(knots)) * scale_s
    x = np.interp(seconds, axis, knots)
    return (x - x.mean()) / (x.std() + 1e-9)


def _diurnal_intensity(name: str, rate: float, cv: float,
                       seconds: np.ndarray,
                       rng: np.random.Generator) -> np.ndarray:
    """Per-second expected arrival rate with a realistic diurnal shape.

    Two activity peaks (late morning, evening), a deep overnight trough, and
    bursts correlated at multiple timescales — matching the "large
    fluctuation in the day" shape of the paper's Figs. 1-3.

    The shape is standardized and rescaled so the per-second count series has
    mean ``rate`` and coefficient of variation ``cv``: the calibration knobs
    that land each dataset in its Table 1-3 magnitude range. Multi-timescale
    correlation matters: NSA's time compression averages λ over
    ``T/max_range``-second windows, so only variance at slower timescales
    survives — exactly the paper's observation that simulated volatility
    tracks the original.
    """
    t = seconds / DAY  # [0, 1)
    # Trend: overnight trough + late-morning and evening peaks.
    trend = (
        0.35
        + 0.45 * np.exp(-0.5 * ((t - 0.45) / 0.13) ** 2)  # ~10:48 peak
        + 0.65 * np.exp(-0.5 * ((t - 0.85) / 0.09) ** 2)  # ~20:24 peak
        - 0.25 * np.exp(-0.5 * ((t - 0.17) / 0.10) ** 2)  # ~4:00 trough
    )
    shape = (
        (trend - trend.mean()) / (trend.std() + 1e-9)
        + 0.55 * _smooth_noise(seconds, 1800.0, rng)  # 30-min bursts
        + 0.30 * _smooth_noise(seconds, 240.0, rng)   # 4-min bursts
    )
    z = (shape - shape.mean()) / (shape.std() + 1e-9)
    return rate * np.clip(1.0 + cv * z, 0.01, None)


def _arrival_timestamps(intensity: np.ndarray,
                        rng: np.random.Generator) -> np.ndarray:
    """Sample a non-homogeneous Poisson process: per-second counts then
    uniform sub-second placement, returned sorted (chronological order,
    paper Def. 1)."""
    counts = rng.poisson(intensity)
    sec = np.repeat(np.arange(len(intensity), dtype=np.float64), counts)
    frac = rng.random(sec.shape[0])
    ts = sec + frac
    ts.sort(kind="stable")
    return ts


def sogouq(scale: float = 1.0, seed: int = 0) -> RawStream:
    """SogouQ-like search-engine query log (paper [9]).

    Fields: accurate-time string, anonymized user id, query hash, result
    rank, click rank. Time is an "accurate time" string — POSD must parse it.
    """
    rng = np.random.default_rng(seed + 11)
    seconds = np.arange(DAY)
    lam = _diurnal_intensity("sogouq", 25.4 * scale, 0.60, seconds, rng)
    ts = _arrival_timestamps(lam, rng)
    n = len(ts)
    base = np.datetime64("2008-06-01T00:00:00")
    times = base + (ts).astype("timedelta64[s]")
    time_str = np.datetime_as_string(times, unit="s")
    time_str = np.char.replace(time_str, "T", " ")
    return RawStream(
        name="sogouq",
        columns={
            "access_time": time_str,  # 'YYYY-MM-DD HH:MM:SS'
            "user_id": rng.integers(0, 2_000_000, n, dtype=np.int64),
            "query_hash": rng.integers(0, 2**31, n, dtype=np.int64),
            "result_rank": rng.integers(1, 11, n, dtype=np.int32),
            "click_rank": rng.integers(1, 11, n, dtype=np.int32),
        },
    )


def traffic(scale: float = 1.0, seed: int = 0) -> RawStream:
    """Baidu-Map query sub-dataset surrogate (paper [10]).

    Fields: float epoch timestamp, start/dest coordinates, estimated travel
    time. Beijing bounding box for coordinates.
    """
    rng = np.random.default_rng(seed + 22)
    seconds = np.arange(DAY)
    lam = _diurnal_intensity("traffic", 21.5 * scale, 0.49, seconds, rng)
    ts = _arrival_timestamps(lam, rng)
    n = len(ts)
    epoch0 = 1_491_004_800.0  # 2017-04-01 00:00:00 UTC
    return RawStream(
        name="traffic",
        columns={
            "query_ts": epoch0 + ts,  # float epoch seconds
            "start_lat": rng.uniform(39.44, 41.06, n),
            "start_lon": rng.uniform(115.42, 117.51, n),
            "dest_lat": rng.uniform(39.44, 41.06, n),
            "dest_lon": rng.uniform(115.42, 117.51, n),
            "eta_s": rng.gamma(2.0, 900.0, n).astype(np.float32),
        },
    )


def userbehavior(scale: float = 1.0, seed: int = 0) -> RawStream:
    """Taobao UserBehavior surrogate (paper [11]).

    Fields: user/item/category ids, behavior type, integer timestamp — stored
    in a shifted time zone (the paper's preprocessing call-out): POSD must
    normalize zones.
    """
    rng = np.random.default_rng(seed + 33)
    seconds = np.arange(DAY)
    lam = _diurnal_intensity("userbehavior", 122.0 * scale, 0.55, seconds, rng)
    ts = _arrival_timestamps(lam, rng)
    n = len(ts)
    behaviors = np.array([0, 1, 2, 3], dtype=np.int32)  # pv, buy, cart, fav
    epoch0 = 1_511_539_200  # 2017-11-25 00:00:00 UTC
    return RawStream(
        name="userbehavior",
        columns={
            "user_id": rng.integers(1, 1_000_000, n, dtype=np.int64),
            "item_id": rng.integers(1, 4_000_000, n, dtype=np.int64),
            "category_id": rng.integers(1, 9_500, n, dtype=np.int64),
            "behavior_type": rng.choice(behaviors, n, p=[0.89, 0.02, 0.06, 0.03]),
            # integer epoch seconds, but shifted: stored as UTC+8 wall clock
            "timestamp": (epoch0 + ts + USERBEHAVIOR_TZ_OFFSET).astype(np.int64),
        },
    )


DATASETS: Dict[str, Callable[..., RawStream]] = {
    "sogouq": sogouq,
    "traffic": traffic,
    "userbehavior": userbehavior,
}


def make_stream(name: str, scale: float = 1.0, seed: int = 0) -> RawStream:
    """Factory over the three paper datasets.

    ``scale`` < 1 shrinks the arrival rate proportionally (used by tests so
    full pipelines run in milliseconds while keeping the diurnal shape).
    """
    try:
        return DATASETS[name](scale=scale, seed=seed)
    except KeyError:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(DATASETS)}")
