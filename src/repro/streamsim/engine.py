"""Sweep engine — executes a :class:`~repro.streamsim.plan.SweepPlan`.

The engine is the middle layer of the plan → engine → replay/report
architecture:

- **Execute** (:func:`execute_sweep`): runs every plan shard's NSA →
  metrics chain as ONE dispatch per kernel stage on that shard's device,
  producing a :class:`DeviceSweepResult` whose kept-index sets and
  per-second counts stay **device-resident** — the handle chains
  ``nsa_sweep_device`` straight into the fused metrics engine
  (``ops.stream_metrics_batched_device``) with no host round-trip, and
  only O(S) report scalars (kept totals, ``[Σq, Σq²]`` moments) cross to
  host. Cache-hit scenarios and the original streams (host data by
  construction) go through one batched host-input metrics call.
- **Materialize** (:meth:`DeviceSweepResult.materialize`): the single
  lazy host pass — kept indices gather the payload columns once and the
  simulated streams land in the store. Until it runs, no per-scenario
  per-record data touches host.
- **Replay / report** (:func:`run_sweep`, :func:`replay_one`,
  :func:`replay_many`, :func:`build_report`): the batched PSDA replay,
  per-scenario :class:`SimulationReport` assembly, and the per-sweep
  :class:`FidelityReport` matrices — consumed directly from the device
  handles. ``Controller.run``/``run_many`` are thin drivers over these
  functions; persistence (the metrics repository) stays in the
  controller.

Backend semantics
-----------------
``backend="numpy"`` (and ``"auto"`` off-TPU) runs the *host mode*: the
exact pre-plan composition — per-scenario numpy NSA, one batched
``metrics_batched`` call, f64 per-pair trend correlations — so reports
are bit-equal to the sequential path. ``backend="pallas"`` (and
``"auto"`` on TPU) runs the *device mode* above; NSA output is
bit-identical, counts are bit-exact, and moments / trend correlations
agree within the documented 1e-3 tolerance (f32 device statistics). Any
:class:`~repro.kernels.ops.PallasDomainError` during the device chain
falls back to host mode wholesale — never silently wrong output. The
fallback keeps the caller's *metrics* backend (an NSA-only domain error
does not demote in-domain pallas metrics — the pre-plan behaviour); only
``backend="numpy"`` guarantees f64 host statistics throughout.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.streamsim.faults import FaultPlan
from repro.streamsim.metrics import (StreamMetrics, Volatility,
                                     _volatility_from_moments,
                                     metrics_batched,
                                     trend_correlation_from_counts,
                                     trend_correlation_matrix)
from repro.streamsim.nsa import (ChunkedNSA, _resolve_backend,
                                 compression_factor, materialize_sweep,
                                 materialize_sweep_chunk, nsa,
                                 nsa_sweep_device)
from repro.streamsim.plan import Shard, SweepPlan
from repro.streamsim.preprocess import Stream
from repro.streamsim.producer import (ChunkFeed, MultiQueueProducer,
                                      Producer, VirtualClock)
from repro.streamsim.queue import QueueGroup, StreamQueue
from repro.streamsim.resilience import (CircuitBreaker, Deadline,
                                        RetryPolicy, SweepCheckpoint)

#: sliding-mean window of the per-report trend correlation — the single
#: source for the device chain AND its host fallback, so the two can
#: never silently diverge (the per-sweep fidelity matrices use the
#: caller's ``fidelity_window_s`` instead)
REPORT_TREND_WINDOW_S = 60


# ------------------------------------------------------------------ reports
@dataclasses.dataclass
class SimulationReport:
    dataset: str
    max_range: int
    original_rows: int
    simulated_rows: int
    compression: float
    original_volatility: Volatility
    simulated_volatility: Volatility
    trend_corr: float
    preprocess_s: float
    nsa_s: float
    produce_s: float
    consumer_metrics: Dict
    #: "ok", or "partial" when the scenario's consumer failed persistently
    #: and the sweep degraded it instead of failing (resilience layer)
    status: str = "ok"
    failure: Optional[str] = None   #: repr of the terminal consumer error
    attempts: int = 1               #: replay attempts consumed (1 = clean)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "SimulationReport":
        """Rebuild a report from its :meth:`to_json` payload (checkpoint
        markers round-trip reports through JSON on sweep resume)."""
        d = dict(d)
        for f in ("original_volatility", "simulated_volatility"):
            v = d[f]
            if isinstance(v, dict):
                d[f] = Volatility(**v)
        known = {fld.name for fld in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass
class FidelityReport:
    """One sweep's Fig.-6 fidelity artifact from a ``run_many`` sweep.

    ``trend_corr`` is the full S×S trend-correlation matrix over the
    sweep's streams — every dataset's original stream followed by every
    dataset's simulated stream at ``max_range`` — computed from ONE
    batched dispatch chain (on the pallas backend the whole counts →
    trend → correlation chain stays on device, consuming the engine's
    device-resident count rows directly). ``labels[i]`` names row/column
    ``i`` (``"<dataset>/original"`` or ``"<dataset>/sim<max_range>"``).
    In a multi-host sweep each host's artifact covers the scenarios that
    host reports (``labels`` records the subset).

    Matrix entries for empty / zero-variance streams are NaN in memory and
    serialize to ``null`` in :meth:`to_json` (bare ``NaN`` tokens are not
    valid JSON and would break non-Python consumers of the artifact).
    """

    max_range: int
    window_s: int
    labels: List[str]
    trend_corr: List[List[float]]
    #: cross-host merge provenance (PR 9): ``provenance[i]`` names the
    #: host/worker that produced row ``i``'s count data, parallel to
    #: ``labels``. None (single-host artifacts) keeps labels canonical
    #: and the JSON payload byte-identical to pre-merge artifacts.
    provenance: Optional[List[Optional[str]]] = None

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["trend_corr"] = [[None if v != v else v for v in row]
                           for row in self.trend_corr]
        if self.provenance is None:
            d.pop("provenance")
        return d


# ---------------------------------------------------------------- execution
@dataclasses.dataclass
class ShardResult:
    """One shard's device-resident NSA + metrics output.

    ``ss_kept``/``idx`` are the :func:`~repro.streamsim.nsa.
    nsa_sweep_device` handles (still on the shard's device); ``hist`` is
    the fused metrics engine's per-second count matrix, also
    device-resident. Only ``totals`` and ``mom`` — O(rows) report
    scalars — live on host.
    """

    shard: Shard
    pairs: Tuple[Tuple[str, int], ...]
    ss_kept: object          # (R, N) int32 device
    idx: object              # (R, N) int32 device
    totals: np.ndarray       # (R,) int64 host
    hist: object             # (R, max_range) int32 device
    mom: np.ndarray          # (R, 2) float64 host
    nsa_s: float


class DeviceSweepResult:
    """Executed sweep: device-resident handles + lazy materialization.

    Produced by :func:`execute_sweep`; consumed by :func:`run_sweep` /
    :func:`build_report`. ``mode`` is ``"device"`` (pallas chain) or
    ``"host"`` (the exact pre-plan numpy composition / wholesale
    fallback).
    """

    def __init__(self, plan: SweepPlan, originals: Dict[str, Stream],
                 store, backend: str, mode: str,
                 autotune: Optional[str] = None):
        self.plan = plan
        self.originals = originals
        self.store = store
        self.backend = backend
        self.mode = mode
        #: tile-tuning mode for every deferred device leg (fidelity,
        #: host-group metrics) — the winners persist under the store
        self.autotune = autotune
        self.nsa_s: Dict[Tuple[str, int], float] = {}
        self.shard_results: List[ShardResult] = []
        #: cache-hit sims (host mode: ALL sims), loaded/computed on host
        self.host_sims: Dict[Tuple[str, int], Stream] = {}
        self.sm: Dict[Tuple[str, int], StreamMetrics] = {}  # host mode only
        self._om: Dict[str, StreamMetrics] = {}
        self._cached_sm: Dict[Tuple[str, int], StreamMetrics] = {}
        self._host_group_done = False
        self._sims: Optional[Dict[Tuple[str, int], Stream]] = None
        self._persisted = False   # shard sims written to the store yet?
        self._stats: Optional[Dict] = None
        self._om_mat = None   # cached device upload of the originals' rows
        #: optional SweepCheckpoint; materialize() then persists
        #: per-scenario completion markers for crash-resume
        self.checkpoint: Optional[SweepCheckpoint] = None
        #: per-scenario EFFECTIVE simulated range (``ScenarioSpec.span_s``
        #: — equals ``max_range`` unless the plan carries a multi-day
        #: ``duration_s``); the statistics paths size count rows by it
        self.spans: Dict[Tuple[str, int], int] = {
            s.scenario: s.span_s for s in plan.scenarios}
        self._store_keys: Dict[Tuple[str, int], str] = {
            s.scenario: s.store_key for s in plan.scenarios}
        #: chunked runs set this: scenario -> kept-row count, so
        #: ``build_report`` never needs the (unbounded-memory)
        #: ``materialize()`` host pass just to count rows
        self.sim_row_counts: Optional[Dict[Tuple[str, int], int]] = None

    @property
    def om(self) -> Dict[str, StreamMetrics]:
        """Per-dataset original-stream metrics — computed lazily (the
        originals and cache-hit sims are host data by construction, so
        their ONE batched host-input metrics call runs only when report
        statistics are actually read, not on the sweep's hot path)."""
        self._ensure_host_group()
        return self._om

    def _ensure_host_group(self) -> None:
        if self._host_group_done:
            return
        self._host_group_done = True
        datasets = list(self.plan.datasets)
        cached = [s.scenario for s in self.plan.cached]
        ms = metrics_batched(
            [self.originals[d] for d in datasets] +
            [self.host_sims[sc] for sc in cached],
            [None] * len(datasets) + [mr for _, mr in cached],
            backend=self.backend, autotune=self.autotune)
        self._om = dict(zip(datasets, ms[:len(datasets)]))
        self._cached_sm = dict(zip(cached, ms[len(datasets):]))

    # ------------------------------------------------------------- topology
    @property
    def scenarios(self) -> Tuple[Tuple[str, int], ...]:
        """The scenarios THIS process reports: the full grid in a
        single-host run; cached + this host's shard scenarios otherwise
        (each host of a ``jax.distributed`` sweep reports its own slice
        into the shared metrics repository)."""
        if self.plan.n_hosts == 1:
            return tuple(s.scenario for s in self.plan.scenarios)
        local = {s.scenario for s in self.plan.local_missing} | \
            {s.scenario for s in self.plan.cached}
        return tuple(s.scenario for s in self.plan.scenarios
                     if s.scenario in local)

    def _scenario_sources(self):
        """scenario -> ("shard", shard_result, row) | ("host", None, None)"""
        src = {sc: ("host", None, None) for sc in self.host_sims}
        for sr in self.shard_results:
            for r, sc in enumerate(sr.pairs):
                src[sc] = ("shard", sr, r)
        return src

    # ---------------------------------------------------------------- stats
    def _ensure_stats(self) -> Dict:
        """Per-scenario report statistics, computed batched on first use.

        Device mode: volatilities come from the O(S) moment scalars; all
        per-pair trend correlations come from ONE fused device chain
        (:func:`repro.kernels.ops.trend_corr_pairwise`) over the
        device-resident count rows. Host mode: the f64 host statistics of
        the pre-plan path.
        """
        if self._stats is not None:
            return self._stats
        stats: Dict[Tuple[str, int], Dict] = {}
        if self.mode == "host":
            for sc in self.scenarios:
                stats[sc] = {
                    "volatility": self.sm[sc].volatility,
                    "trend_corr": trend_correlation_from_counts(
                        self.om[sc[0]].counts, self.sm[sc].counts,
                        REPORT_TREND_WINDOW_S),
                }
            self._stats = stats
            return stats

        self._ensure_host_group()
        src = self._scenario_sources()
        scenarios = list(self.scenarios)
        if not scenarios:
            self._stats = stats
            return stats
        for sc in scenarios:
            kind, sr, r = src[sc]
            if kind == "shard":
                vol = _volatility_from_moments(
                    float(sr.mom[r, 0]), float(sr.mom[r, 1]),
                    self.spans.get(sc, sc[1]))
            else:
                vol = self._cached_sm[sc].volatility
            stats[sc] = {"volatility": vol}

        corrs = self._pairwise_trend_corrs(scenarios, src)
        for sc, r in zip(scenarios, corrs):
            stats[sc]["trend_corr"] = float(r)
        self._stats = stats
        return stats

    def _sim_count_rows(self, scenarios, src, width: int):
        """Stack the scenarios' per-second count rows on device.

        Shard rows are already device-resident histograms; cache-hit rows
        (host data by construction) upload once as a group. Returns
        ``(qmat (P, width) int32 device, lengths, totals)``.
        """
        import jax
        import jax.numpy as jnp

        self._ensure_host_group()    # cache-hit rows need host metrics
        groups, order = [], []       # group arrays + scenario positions
        pos = {sc: p for p, sc in enumerate(scenarios)}
        home = jax.local_devices()[0]   # the report-reduction device
        for sr in self.shard_results:
            rows = [sc for sc in sr.pairs if sc in pos]
            if not rows:
                continue
            take = np.array([sr.pairs.index(sc) for sc in rows])
            h = jnp.take(sr.hist, jnp.asarray(take), axis=0)
            pad = width - h.shape[1]
            if pad > 0:
                h = jnp.concatenate(
                    [h, jnp.zeros((h.shape[0], pad), h.dtype)], axis=1)
            # shard rows live on their shard's device; the O(S·max_range)
            # count rows hop device-to-device (never through a
            # per-scenario host pass) for the cross-shard reduction
            groups.append(jax.device_put(h[:, :width], home))
            order.extend(pos[sc] for sc in rows)
        hosted = [sc for sc in scenarios if src[sc][0] == "host"]
        if hosted:
            hmat = np.zeros((len(hosted), width), np.int32)
            for i, sc in enumerate(hosted):
                q = self._cached_sm[sc].counts
                hmat[i, :min(len(q), width)] = q[:width]
            groups.append(jnp.asarray(hmat))
            order.extend(pos[sc] for sc in hosted)
        qmat = jnp.concatenate(groups, axis=0)
        perm = np.argsort(np.array(order), kind="stable")
        qmat = jnp.take(qmat, jnp.asarray(perm), axis=0)
        lengths = np.array([self.spans.get(sc, sc[1]) for sc in scenarios],
                           np.int64)
        totals = np.array(
            [src[sc][1].totals[src[sc][2]] if src[sc][0] == "shard"
             else int(self._cached_sm[sc].counts.sum())
             for sc in scenarios], np.int64)
        return qmat, lengths, totals

    def _orig_count_matrix(self):
        """(D, W) int32 device matrix of the originals' count rows (one
        upload for the whole sweep, cached) + per-dataset lengths/totals."""
        import jax.numpy as jnp

        if self._om_mat is not None:
            return self._om_mat
        datasets = list(self.plan.datasets)
        trs = np.array([len(self.om[d].counts) for d in datasets], np.int64)
        W = max(int(trs.max(initial=1)), 1)
        mat = np.zeros((len(datasets), W), np.int32)
        for i, d in enumerate(datasets):
            mat[i, :trs[i]] = self.om[d].counts
        totals = np.array([int(self.om[d].counts.sum())
                           for d in datasets], np.int64)
        self._om_mat = (jnp.asarray(mat), trs, totals,
                        {d: i for i, d in enumerate(datasets)})
        return self._om_mat

    def _pairwise_trend_corrs(self, scenarios, src) -> np.ndarray:
        """Every report's (original, simulated) trend correlation from one
        fused device chain; falls back to the f64 host loop on domain
        errors."""
        from repro.kernels import ops

        try:
            om_mat, om_trs, om_totals, didx = self._orig_count_matrix()
            rows = np.array([didx[sc[0]] for sc in scenarios])
            width = max(int(self.spans.get(sc, sc[1])) for sc in scenarios)
            qb, lb, sim_totals = self._sim_count_rows(scenarios, src, width)
            totals = np.concatenate([om_totals, sim_totals])
            # unique originals + a_index: each original's full-length
            # trend is computed once per sweep, not once per scenario
            return ops.trend_corr_pairwise(om_mat, om_trs, qb, lb,
                                           REPORT_TREND_WINDOW_S,
                                           totals=totals, a_index=rows)
        except ops.PallasDomainError:
            return np.array([trend_correlation_from_counts(
                self.om[sc[0]].counts, self._counts_host(sc, src),
                REPORT_TREND_WINDOW_S)
                for sc in scenarios])

    def _counts_host(self, sc, src) -> np.ndarray:
        kind, sr, r = src[sc]
        if kind == "host":
            self._ensure_host_group()
            return self._cached_sm[sc].counts
        return np.asarray(sr.hist)[r, :self.spans.get(sc, sc[1])] \
            .astype(np.int64)

    def count_rows(self, scenarios=None) -> Dict[Tuple[str, int],
                                                 np.ndarray]:
        """Per-second simulated count rows gathered to host, scenario →
        int64 array — the cross-host fidelity-merge export (PR 9). Count
        rows are exact integers, so publishing them (instead of partial
        correlation sub-matrices) lets the merging side recompute the
        FULL S×S matrix with the same numpy reduction a single-host run
        uses, making the merged artifact equal to the single-host one up
        to backend tolerance rather than approximately stitched."""
        if scenarios is None:
            scenarios = self.scenarios
        if self.mode == "host":
            self._ensure_host_group()
            return {sc: np.asarray(self.sm[sc].counts
                                   if sc in self.sm
                                   else self._cached_sm[sc].counts,
                                   dtype=np.int64)
                    for sc in scenarios}
        src = self._scenario_sources()
        return {sc: self._counts_host(sc, src) for sc in scenarios}

    # ------------------------------------------------------------- fidelity
    def fidelity(self, window_s: int = 60) -> List[FidelityReport]:
        """One S×S trend-correlation matrix per ``max_range`` sweep, over
        ``[originals..., sims@max_range...]`` — consumed straight from the
        device-resident count rows in device mode.

        In a multi-host run each host emits the SUB-matrix over the
        scenarios it reports (its originals + owned sims at that
        ``max_range``; the labels record which) — partial rows are never
        silently dropped, and the per-host artifacts in the shared
        repository jointly cover every original↔sim pair.
        """
        import jax.numpy as jnp

        from repro.kernels import ops, tuning

        datasets = list(self.plan.datasets)
        out = []
        reported = set(self.scenarios)
        src = self._scenario_sources() if self.mode == "device" else {}
        for mr in self.plan.max_ranges:
            scs = [(d, mr) for d in datasets if (d, mr) in reported]
            if not scs:
                continue
            row_ds = [d for d, _ in scs]
            labels = [f"{d}/original" for d in row_ds] + \
                [f"{d}/sim{mr}" for d in row_ds]
            if self.mode == "host":
                matrix = trend_correlation_matrix(
                    [self.om[d].counts for d in row_ds] +
                    [self.sm[(d, mr)].counts for d in row_ds],
                    window_s=window_s, backend=self.backend,
                    autotune=self.autotune)
            else:
                try:
                    om_mat, om_trs, om_totals, didx = \
                        self._orig_count_matrix()
                    sel = np.array([didx[d] for d in row_ds])
                    om_sel = jnp.take(om_mat, jnp.asarray(sel), axis=0)
                    w_sc = max(int(self.spans.get(sc2, mr))
                               for sc2 in scs)
                    qb, lb, sim_totals = self._sim_count_rows(
                        scs, src, max(int(om_sel.shape[1]), w_sc))
                    pad = qb.shape[1] - om_sel.shape[1]
                    if pad > 0:
                        om_sel = jnp.concatenate(
                            [om_sel, jnp.zeros((om_sel.shape[0], pad),
                                               om_sel.dtype)], axis=1)
                    qmat = jnp.concatenate([om_sel, qb], axis=0)
                    lengths = np.concatenate([om_trs[sel], lb])
                    totals = np.concatenate([om_totals[sel], sim_totals])
                    with tuning.tuner_context(self.autotune,
                                              store=self.store or None):
                        matrix = ops.trend_correlation_batched_device(
                            qmat, lengths, window_s, totals=totals)
                except ops.PallasDomainError:
                    matrix = trend_correlation_matrix(
                        [self.om[d].counts for d in row_ds] +
                        [self._counts_host((d, mr), src)
                         for d in row_ds],
                        window_s=window_s, backend="numpy")
            out.append(FidelityReport(mr, window_s, labels,
                                      np.asarray(matrix).tolist()))
        return out

    # ---------------------------------------------------------- materialize
    def materialize(self, store=None) -> Dict[Tuple[str, int], Stream]:
        """The single lazy host pass: gather every shard scenario's kept
        payload columns from the device handles, persist the simulated
        streams (``store`` defaults to the plan's store; pass ``False``
        to skip persistence), and return the full scenario → Stream map.
        The gather is idempotent (repeated calls reuse the cached
        streams), but persistence is tracked separately: a later call
        with a truthy/default ``store`` after an earlier
        ``store=False`` peek still writes the streams out once.
        """
        store = self.store if store is None else store
        if self._sims is None:
            sims: Dict[Tuple[str, int], Stream] = dict(self.host_sims)
            for sr in self.shard_results:
                if sr.ss_kept is None:
                    # chunked run: the per-record handles were consumed
                    # chunk by chunk and the streams are already durable —
                    # reassemble from the store's chunk files (this loads
                    # everything to host; bounded-memory callers use
                    # ``sim_row_counts`` instead of calling materialize)
                    for sc in sr.pairs:
                        sims[sc] = self.store.get(self._store_keys[sc])
                else:
                    sims.update(materialize_sweep(
                        self.originals, list(sr.pairs), sr.ss_kept, sr.idx,
                        sr.totals))
            self._sims = {sc: sims[sc] for sc in self.scenarios}
        if store and not self._persisted:
            shard_scs = [sc for sr in self.shard_results
                         for sc in sr.pairs]
            store.put_many(
                {f"{d}__sim{mr}": self._sims[(d, mr)]
                 for d, mr in shard_scs if (d, mr) in self._sims},
                {f"{d}__sim{mr}": {"max_range": mr}
                 for d, mr in shard_scs})
            self._persisted = True
            if self.checkpoint is not None:
                # resume marker: these scenarios' streams are now durable
                # (their stream is a store cache hit on the next attempt)
                self.checkpoint.mark_materialized(
                    [s.scenario for s in self.plan.local_missing])
        return self._sims


def execute_sweep(plan: SweepPlan, originals: Dict[str, Stream], store, *,
                  backend: str = "auto", multiple_mode: str = "time",
                  checkpoint: Optional[SweepCheckpoint] = None,
                  autotune: Optional[str] = None) -> DeviceSweepResult:
    """Execute a plan's NSA + metrics stages (layer 2 of the sweep).

    Device mode (resolved ``"pallas"``): each shard runs ONE
    normalize→sample→compact chain committed to its device
    (:func:`~repro.streamsim.nsa.nsa_sweep_device`) chained straight into
    ONE fused metrics dispatch
    (:func:`~repro.kernels.ops.stream_metrics_batched_device`) — the kept
    stamps never visit host. Originals and cache-hit sims (host data) go
    through one batched host-input metrics call. Any
    :class:`~repro.kernels.ops.PallasDomainError` (or an empty source
    stream) falls back to host mode wholesale.

    Host mode (resolved ``"numpy"``): the exact pre-plan composition —
    per-scenario numpy NSA + one ``metrics_batched`` call over
    ``[originals..., sims...]`` — bit-equal reports.

    Returns a :class:`DeviceSweepResult`; NSA wall time is recorded per
    scenario (the shared shard total for co-simulated scenarios, 0.0 for
    cache hits) and the simulated streams are **not** yet materialized.
    """
    resolved = _resolve_backend(backend)
    missing = list(plan.local_missing)
    device_ok = (resolved == "pallas" and
                 all(len(originals[s.dataset]) > 0 for s in missing))
    result = None
    if device_ok:
        result = _execute_device(plan, originals, store, backend,
                                 multiple_mode, autotune)
    if result is None:
        result = _execute_host(plan, originals, store, backend,
                               multiple_mode, autotune)
    result.checkpoint = checkpoint
    if checkpoint is not None and result.mode == "host" and store:
        # host mode persists its sims eagerly inside _execute_host
        checkpoint.mark_materialized(
            [s.scenario for s in plan.local_missing])
    return result


def _execute_device(plan, originals, store, backend, multiple_mode,
                    autotune=None) -> Optional[DeviceSweepResult]:
    """The pallas path; returns None when a domain error demands the
    wholesale host fallback."""
    import jax

    from repro.kernels import ops, tuning

    result = DeviceSweepResult(plan, originals, store, backend, "device",
                               autotune=autotune)
    devices = jax.local_devices()
    total_nsa = 0.0
    try:
        with tuning.tuner_context(autotune, store=store or None):
            for shard in plan.shards:
                pairs = tuple(s.scenario for s in shard.specs)
                dev = devices[shard.device_index % len(devices)]
                t0 = time.perf_counter()
                ss_kept, idx, totals, _ = nsa_sweep_device(
                    originals, pairs, multiple_mode=multiple_mode,
                    device=dev)
                # compaction packed every row's kept stamps to the front,
                # so the metrics dispatch only needs the kept-width column
                # slice (device slice — kept counts are far below the
                # padded source width after compression)
                n_kept = int(-(-max(int(totals.max(initial=1)), 1)
                               // ops.TILE) * ops.TILE)
                hist, mom = ops.stream_metrics_batched_device(
                    ss_kept[:, :min(n_kept, ss_kept.shape[1])], totals,
                    shard.max_range)
                mom_host = np.asarray(mom, np.float64)  # O(rows) scalars
                dt = time.perf_counter() - t0
                total_nsa += dt
                result.shard_results.append(ShardResult(
                    shard=shard, pairs=pairs, ss_kept=ss_kept, idx=idx,
                    totals=np.asarray(totals, np.int64), hist=hist,
                    mom=mom_host, nsa_s=dt))
    except ops.PallasDomainError:
        return None   # out-of-domain scenario: host mode, wholesale

    for spec in plan.cached:
        result.host_sims[spec.scenario] = store.get(spec.store_key)
    # originals + cache-hit sims are host data by construction; their ONE
    # batched host-input metrics call is deferred (``_ensure_host_group``)
    # until report statistics are read, keeping the sweep's hot path free
    # of it
    for sc in (s.scenario for s in plan.scenarios):
        result.nsa_s[sc] = 0.0
    for sr in result.shard_results:
        for sc in sr.pairs:
            result.nsa_s[sc] = total_nsa
    return result


def _execute_host(plan, originals, store, backend, multiple_mode,
                  autotune=None) -> DeviceSweepResult:
    """The host path — the exact pre-plan ``run_many`` composition."""
    result = DeviceSweepResult(plan, originals, store, backend, "host",
                               autotune=autotune)
    t0 = time.perf_counter()
    for spec in plan.local_missing:
        result.host_sims[spec.scenario] = nsa(
            originals[spec.dataset], spec.max_range,
            multiple_mode=multiple_mode, backend="numpy")
    t_sweep = time.perf_counter() - t0
    if store:
        for spec in plan.local_missing:
            store.put(spec.store_key, result.host_sims[spec.scenario],
                      {"max_range": spec.max_range})
    for spec in plan.cached:
        result.host_sims[spec.scenario] = store.get(spec.store_key)
    for spec in plan.scenarios:
        result.nsa_s[spec.scenario] = \
            0.0 if spec.cached else t_sweep
    scenarios = [sc for sc in (s.scenario for s in plan.scenarios)
                 if sc in result.host_sims]
    datasets = list(plan.datasets)
    ms = metrics_batched(
        [originals[d] for d in datasets] +
        [result.host_sims[sc] for sc in scenarios],
        [None] * len(datasets) + [mr for _, mr in scenarios],
        backend=backend)
    result._om = dict(zip(datasets, ms[:len(datasets)]))
    result.sm = dict(zip(scenarios, ms[len(datasets):]))
    result._host_group_done = True   # one dispatch covered everything
    result._sims = {sc: result.host_sims[sc] for sc in scenarios}
    return result


# -------------------------------------------------------------- PSDA replay
def replay_one(sim: Stream, consumer, queue_size: int, faults=None):
    """Single-scenario PSDA leg (``Controller.run``): producer thread
    fills a bounded queue, the consumer drains it on the CALLING thread
    (so ``run``'s consumer needs no thread safety). ``faults`` optionally
    attaches one scenario's :class:`~repro.streamsim.faults.
    FaultInjector` schedule to the producer."""
    queue = StreamQueue(maxsize=queue_size)
    producer = Producer(sim, queue, clock=VirtualClock(), faults=faults)
    t0 = time.perf_counter()
    status = [None]

    def _produce():
        status[0] = producer.run()

    th = threading.Thread(target=_produce, daemon=True)
    th.start()
    consumer_metrics = consumer(queue)
    th.join()
    t_prod = time.perf_counter() - t0
    if status[0] != 0:
        raise RuntimeError("producer reported fault status")
    return ({**consumer_metrics, **queue.stats(), **producer.stats()},
            t_prod)


def consumer_label(consumer) -> Optional[str]:
    """The task name a consumer advertises — ``.name`` on the task tier
    (:mod:`repro.streamsim.tasks`), ``.task_name`` or ``.__name__`` as
    fallbacks. Surfaced in the deadline errors so a wedged *task* is
    named alongside its scenario (one sweep can interleave many tasks;
    "scenario ('sogouq', 600) timed out" alone does not say WHICH task
    wedged)."""
    for attr in ("task_name", "name", "__name__"):
        label = getattr(consumer, attr, None)
        if isinstance(label, str) and label:
            return label
    return None


def _deadline_error(deadline_s, key, consumer) -> TimeoutError:
    """The wedged-consumer TimeoutError, naming scenario AND task."""
    task = consumer_label(consumer)
    tag = f" running task {task!r}" if task else ""
    return TimeoutError(
        f"consumer deadline ({deadline_s}s) exceeded for {key!r}{tag}")


def _replay_solo(key, sim: Stream, consumer, queue_size: int,
                 deadline_s: Optional[float], faults) -> Dict:
    """One scenario's retry replay (the resilience layer's unit of work):
    fresh bounded queue + producer thread, the consumer on its own
    deadline-joined thread. Returns the merged per-scenario stats or
    raises the consumer's error (``TimeoutError`` on a blown deadline).
    """
    queue = StreamQueue(maxsize=queue_size)
    producer = Producer(sim, queue, clock=VirtualClock(), faults=faults)
    status = [None]
    box: Dict = {}

    def _produce():
        status[0] = producer.run()

    def _consume():
        try:
            box["result"] = consumer(queue)
        except Exception as exc:   # keep the producer drainable
            box["error"] = exc
            for _ in queue:
                pass

    tp = threading.Thread(target=_produce, daemon=True)
    tc = threading.Thread(target=_consume, daemon=True)
    deadline = Deadline(deadline_s)
    tp.start()
    tc.start()
    tc.join(deadline.remaining())
    if tc.is_alive():
        queue.close()              # unblock a get()-parked consumer; the
        tc.join(5.0)               # producer sheds via the closed queue
        raise _deadline_error(deadline_s, key, consumer)
    tp.join()
    if "error" in box:
        raise box["error"]
    if status[0] != 0:
        raise RuntimeError("producer reported fault status")
    return {**box["result"], **queue.stats(), **producer.stats()}


def replay_many(sims: Dict, consumer, queue_size: int, *,
                fault_plan: Optional[FaultPlan] = None,
                retry_policy: Optional[RetryPolicy] = None,
                breaker_threshold: int = 3,
                consumer_deadline_s: Optional[float] = None,
                on_failure: str = "raise",
                max_bytes: Optional[int] = None,
                retention_policy: str = "block"):
    """Batched PSDA leg: ONE
    :class:`~repro.streamsim.producer.MultiQueueProducer` virtual-time
    loop interleaves every scenario's buckets; each scenario's consumer
    drains its own bounded queue in its own thread (shared backpressure
    makes concurrent drains mandatory — a full sibling queue stalls the
    whole loop). Returns ``({scenario: merged stats}, shared wall time)``
    with per-scenario stats equivalent to sequential :func:`replay_one`
    calls.

    Resilience layer (all off by default — the fault-free defaults are
    bit-identical to the pre-resilience engine):

    - ``fault_plan`` injects the seeded chaos schedule into the producer
      walk and wraps each consumer with its crash schedule.
    - ``consumer_deadline_s`` bounds the joint consumer joins: a consumer
      still running at the deadline with buckets available (or its stream
      closed) is *wedged* — its queue is closed (the producer walk sheds
      just that scenario) and it fails with a named ``TimeoutError``
      instead of hanging the sweep; *starved* consumers (empty open
      queue — victims of shared backpressure behind the wedged sibling)
      get a short post-shed grace join.
    - ``retry_policy`` retries each failed scenario solo with capped
      exponential backoff; each retry rewinds the scenario's fault
      schedule (``FaultInjector.reset``) while the crash-attempt counter
      advances, so a transient injected crash heals deterministically.
    - a per-scenario :class:`~repro.streamsim.resilience.CircuitBreaker`
      (``breaker_threshold`` consecutive failures) stops burning backoff
      budget on a persistently-broken consumer.
    - ``on_failure="degrade"`` converts terminal failures into partial
      per-scenario stats (``degraded``/``failed``/``attempts``/
      ``breaker`` + transport counters) instead of raising, so one broken
      scenario no longer fails the whole sweep.
    - ``max_bytes``/``retention_policy`` put the queue group under a
      shared byte budget (broker retention; see
      :class:`~repro.streamsim.queue.ByteBudget`).

    Raises
    ------
    RuntimeError
        With ``on_failure="raise"`` (default), if ANY scenario's consumer
        terminally fails: every failure is aggregated into one error
        naming the failed scenarios, with the scenario exceptions chained
        via ``__cause__`` (first failure outermost) so no traceback is
        swallowed. Also raised on a producer fault status.
    """
    if on_failure not in ("raise", "degrade"):
        raise ValueError(
            f"on_failure must be 'raise' or 'degrade', got {on_failure!r}")
    group = QueueGroup(sims, maxsize=queue_size, max_bytes=max_bytes,
                       retention_policy=retention_policy)
    producer = MultiQueueProducer(sims, group.queues, clock=VirtualClock(),
                                  fault_plan=fault_plan)
    wrapped = {key: (fault_plan.wrap_consumer(key, consumer)
                     if fault_plan is not None else consumer)
               for key in sims}
    status = [None]
    results: Dict = {}
    errors: Dict[object, BaseException] = {}

    def _produce():
        status[0] = producer.run()

    def _consume(key):
        try:
            results[key] = wrapped[key](group[key])
        except Exception as exc:  # keep the producer loop drainable
            errors[key] = exc
            for _ in group[key]:
                pass

    t0 = time.perf_counter()
    prod_th = threading.Thread(target=_produce, daemon=True)
    cons = {key: threading.Thread(target=_consume, args=(key,),
                                  daemon=True) for key in sims}
    prod_th.start()
    for th in cons.values():
        th.start()
    deadline = Deadline(consumer_deadline_s)
    for th in cons.values():
        th.join(deadline.remaining())    # None remaining == join forever
    for key, th in cons.items():
        if not th.is_alive():
            continue
        q = group[key]
        if q.qsize() > 0 or q.closed:
            # wedged: buckets available (or stream over) yet not
            # finishing — shed it so the walk and its siblings complete
            errors[key] = _deadline_error(consumer_deadline_s, key,
                                          wrapped[key])
            q.close()
    prod_th.join()
    # post-shed grace: starved consumers (empty queue behind the wedged
    # sibling's backpressure) finish quickly once the producer resumed;
    # already-errored (wedged) threads are abandoned, not re-joined
    grace = Deadline(5.0 if consumer_deadline_s is not None else None)
    for key, th in cons.items():
        if key in errors:
            continue
        if th.is_alive():
            th.join(grace.remaining())
        if th.is_alive():
            errors[key] = _deadline_error(consumer_deadline_s, key,
                                          wrapped[key])
            group[key].close()
    t_prod = time.perf_counter() - t0

    # ---- phase 2: solo retries with backoff, behind the breaker
    attempts = {key: 1 for key in errors}
    breaker_state = {key: "closed" for key in errors}
    # separate dict: an abandoned (wedged) consumer thread may still
    # write ``results[key]`` concurrently; retries must not race it
    solo_results: Dict = {}
    for key in [k for k in sims if k in errors]:
        breaker = CircuitBreaker(breaker_threshold)
        breaker.record_failure()            # the joint-loop failure
        breaker_state[key] = breaker.state
        if retry_policy is None:
            continue
        inj = (fault_plan.injector(key)
               if fault_plan is not None and
               not fault_plan.is_noop_for(key) else None)
        while attempts[key] < retry_policy.max_attempts and breaker.allow():
            time.sleep(retry_policy.delay(attempts[key], key))
            attempts[key] += 1
            if inj is not None:
                inj.reset()                 # same transport schedule;
            try:                            # crash attempts still advance
                merged = _replay_solo(key, sims[key], wrapped[key],
                                      queue_size, consumer_deadline_s, inj)
                merged["retries"] = attempts[key] - 1
                solo_results[key] = merged
                breaker.record_success()
                del errors[key]
                break
            except Exception as retry_exc:
                errors[key] = retry_exc
                breaker.record_failure()
        breaker_state[key] = breaker.state

    # ---- phase 3: assemble / degrade / raise
    all_metrics: Dict = {}
    for key in sims:
        if key in errors:
            continue
        if key in solo_results:             # solo stats already merged
            all_metrics[key] = solo_results[key]
        else:
            all_metrics[key] = {**results[key], **group[key].stats(),
                                **producer.stats(key)}
    if errors:
        if on_failure == "degrade":
            for key in errors:
                all_metrics[key] = {
                    "degraded": True,
                    "failed": repr(errors[key]),
                    "attempts": attempts[key],
                    "breaker": breaker_state[key],
                    **group[key].stats(),
                    **producer.stats(key),
                }
        else:
            ordered = [(key, errors[key]) for key in sims if key in errors]
            cause = None
            for _, exc in reversed(ordered):  # first failure outermost
                # a consumer exception may already carry its own
                # __cause__ chain — link the NEXT failure to that chain's
                # tail so no failure becomes unreachable
                tail, seen = exc, {id(exc)}
                while tail.__cause__ is not None and id(tail.__cause__) \
                        not in seen:
                    tail = tail.__cause__
                    seen.add(id(tail))
                if tail.__cause__ is None and tail is not cause:
                    tail.__cause__ = cause
                cause = exc
            detail = "; ".join(f"{key!r}: {exc!r}" for key, exc in ordered)
            raise RuntimeError(
                f"{len(ordered)} of {len(sims)} sweep consumer(s) failed: "
                f"{detail}") from cause
    if status[0] != 0:
        raise RuntimeError("producer reported fault status")
    return all_metrics, t_prod


# ----------------------------------------------------------- report assembly
def build_report(result: DeviceSweepResult, scenario: Tuple[str, int],
                 t_pre: float, t_prod: float,
                 consumer_metrics: Dict) -> SimulationReport:
    """Assemble one scenario's :class:`SimulationReport` from the executed
    sweep's statistics (device-mode stats never gathered more than O(S)
    scalars to build this). Degraded replay metrics (``on_failure=
    "degrade"``) yield a ``status="partial"`` report carrying the
    terminal failure instead of failing report assembly."""
    d, mr = scenario
    stats = result._ensure_stats()[scenario]
    original = result.originals[d]
    if result.sim_row_counts is not None and scenario in \
            result.sim_row_counts:
        # chunked run: the row count was accumulated per chunk — no
        # whole-stream host pass just to measure it
        simulated_rows = int(result.sim_row_counts[scenario])
    else:
        simulated_rows = len(result.materialize()[scenario])
    degraded = bool(consumer_metrics.get("degraded"))
    return SimulationReport(
        dataset=d,
        max_range=mr,
        original_rows=len(original),
        simulated_rows=simulated_rows,
        compression=compression_factor(original, mr),
        original_volatility=result.om[d].volatility,
        simulated_volatility=stats["volatility"],
        trend_corr=stats["trend_corr"],
        preprocess_s=t_pre,
        nsa_s=result.nsa_s[scenario],
        produce_s=t_prod,
        consumer_metrics=consumer_metrics,
        status="partial" if degraded else "ok",
        failure=consumer_metrics.get("failed") if degraded else None,
        attempts=int(consumer_metrics.get(
            "attempts", consumer_metrics.get("retries", 0) + 1)),
    )


def run_sweep(result: DeviceSweepResult, consumer, *,
              queue_size: int = 64, fidelity_window_s: int = 60,
              t_pre: Optional[Dict[str, float]] = None,
              fault_plan: Optional[FaultPlan] = None,
              retry_policy: Optional[RetryPolicy] = None,
              breaker_threshold: int = 3,
              consumer_deadline_s: Optional[float] = None,
              on_failure: str = "raise",
              max_bytes: Optional[int] = None,
              retention_policy: str = "block",
              checkpoint: Optional[SweepCheckpoint] = None,
              on_report=None, fidelity: bool = True
              ) -> Tuple[List[SimulationReport], List[FidelityReport]]:
    """Layer 3: fidelity matrices → materialize → batched replay → reports.

    The full report tail of ``Controller.run_many``, consuming the
    :class:`DeviceSweepResult` directly: fidelity is computed from the
    device-resident count rows BEFORE the single
    :meth:`~DeviceSweepResult.materialize` host pass, every scenario then
    replays through ONE multi-queue virtual-time loop, and one
    :class:`SimulationReport` per scenario is assembled in grid order.
    Persistence of both artifacts stays with the caller (the controller's
    metrics repository). The resilience keywords pass straight through to
    :func:`replay_many`; ``checkpoint`` persists each report's completion
    marker as soon as it is assembled, so a sweep killed after k reports
    resumes with exactly k scenarios done. ``on_report`` (PR 9 service
    publish hook) is called with each report as soon as it is assembled
    — the sweep service uses it to publish result markers per scenario,
    so a worker killed mid-batch loses only its unpublished tail.
    ``fidelity=False`` skips the local matrix entirely (service workers
    publish raw count rows instead and the merger owns the matrix).
    """
    t_pre = t_pre or {}
    fid = result.fidelity(fidelity_window_s) if fidelity else []
    result._ensure_stats()        # device stats before the host pass
    sims = result.materialize()
    all_metrics, t_prod = replay_many(
        sims, consumer, queue_size, fault_plan=fault_plan,
        retry_policy=retry_policy, breaker_threshold=breaker_threshold,
        consumer_deadline_s=consumer_deadline_s, on_failure=on_failure,
        max_bytes=max_bytes, retention_policy=retention_policy)
    reports = []
    for sc in result.scenarios:
        r = build_report(result, sc, t_pre.get(sc[0], 0.0), t_prod,
                         all_metrics[sc])
        if checkpoint is not None:
            checkpoint.mark_report(r)     # marker lands per report, so a
        if on_report is not None:
            on_report(r)
        reports.append(r)                 # kill leaves a clean prefix
    return reports, fid


# ------------------------------------------------------- chunked pipeline
class ChunkedSweepRunner:
    """Chunked, double-buffered sweep execution — the unbounded-stream form.

    Splits every scenario's simulated timeline into ``plan.chunk_s``-second
    chunks and pipelines them through the device: while chunk ``k``'s host
    leg runs (read totals → gather payload → ``StreamStore.append_chunk``
    → feed the replay), chunk ``k+1``'s NSA → metrics dispatch is already
    in flight (JAX async dispatch; the dispatch path never reads a device
    value, see :func:`~repro.kernels.ops.compact_mask_batched_device`).
    Cross-chunk state stays device-resident in a
    :class:`~repro.kernels.ops.ChunkCarry` (running histogram, Kahan
    ``[Σq, Σq²]`` state, prefix-sum tail, trend window tail), so the
    per-chunk outputs compose to the monolithic sweep's answer: counts
    bit-exact, moments within ~1e-5, trend/fidelity within 1e-3.

    Host residency is bounded by construction: per scenario at most the
    in-flight chunk plus the :class:`~repro.streamsim.producer.ChunkFeed`
    buffer (``maxsize=2``) exist on host at once — the feed's
    ``feed_hwm_chunks`` stat is the proof, surfaced in every report's
    ``consumer_metrics``.

    Resume is chunk-granular: ``append_chunk`` skips chunks already on
    disk, so a killed multi-day run recomputes device work but rewrites
    only the missing chunk files, and scenario-level resume (the PR 6
    marker machinery) still prunes completed scenarios from the plan.

    ``backend`` resolution mirrors :func:`execute_sweep`: resolved
    ``"pallas"`` runs the device pipeline above (domain errors fall back
    wholesale at CONSTRUCTION, before any chunk state exists); resolved
    ``"numpy"`` runs the host composition — whole-stream numpy NSA and
    f64 statistics (bit-equal reports to the monolithic host path) with
    the same chunked persist + chunked replay feed.
    """

    def __init__(self, plan: SweepPlan, originals: Dict[str, Stream],
                 store, *, backend: str = "auto",
                 multiple_mode: str = "time",
                 checkpoint: Optional[SweepCheckpoint] = None,
                 autotune: Optional[str] = None):
        if plan.chunk_s <= 0:
            raise ValueError(
                "plan has no chunk axis — build it with plan_sweep("
                "chunk_s=...) to use the chunked runner")
        self.plan = plan
        self.originals = originals
        self.store = store
        self.backend = backend
        self.multiple_mode = multiple_mode
        self.checkpoint = checkpoint
        self.autotune = autotune
        self.chunk_s = int(plan.chunk_s)
        self._specs = {s.scenario: s for s in plan.scenarios}
        self._shard_states: List[Dict] = []
        self._chunk_stats: Dict[str, Dict] = {}
        self.mode = "host"
        resolved = _resolve_backend(backend)
        if resolved == "pallas" and all(
                len(originals[s.dataset]) > 0 for s in plan.local_missing):
            from repro.kernels import ops
            try:
                self._prep_device()
                self.mode = "device"
            except ops.PallasDomainError:
                self._shard_states = []   # wholesale host fallback

    @property
    def scenarios(self) -> Tuple[Tuple[str, int], ...]:
        """The scenarios THIS process replays/reports (grid order) —
        mirrors :attr:`DeviceSweepResult.scenarios`."""
        if self.plan.n_hosts == 1:
            return tuple(s.scenario for s in self.plan.scenarios)
        local = {s.scenario for s in self.plan.local_missing} | \
            {s.scenario for s in self.plan.cached}
        return tuple(s.scenario for s in self.plan.scenarios
                     if s.scenario in local)

    def _prep_device(self) -> None:
        """Upload every shard's tables ONCE; domain errors surface here,
        before any chunk state exists."""
        import jax

        from repro.kernels import ops

        devices = jax.local_devices()
        for shard in self.plan.shards:
            dev = devices[shard.device_index % len(devices)]
            cn = ChunkedNSA(
                self.originals,
                [(s.dataset, s.span_s) for s in shard.specs],
                multiple_mode=self.multiple_mode, device=dev,
                autotune=self.autotune)
            self._shard_states.append({
                "shard": shard,
                "nsa": cn,
                "carry": ops.chunk_carry_init(
                    len(shard.specs), cn.width,
                    window=REPORT_TREND_WINDOW_S),
                "totals": np.zeros(len(shard.specs), np.int64),
            })

    # ------------------------------------------------------------- pipeline
    def run(self, feeds: Optional[Dict[Tuple[str, int], ChunkFeed]] = None
            ) -> DeviceSweepResult:
        """Drive the full chunk pipeline; returns the composed result.

        ``feeds`` (scenario → :class:`ChunkFeed`) receives every chunk
        stream in round order — chunk ``k`` of EVERY scenario lands
        before any scenario's chunk ``k+1`` — and each feed is closed
        after its scenario's last chunk, so the chunked replay walk
        starts as soon as chunk 0 lands. On any error every feed is
        closed before re-raising (the producer side unblocks instead of
        deadlocking).
        """
        from repro.kernels import tuning
        try:
            with tuning.tuner_context(self.autotune,
                                      store=self.store or None):
                if self.mode == "device":
                    return self._run_device(feeds)
                return self._run_host(feeds)
        except BaseException:
            if feeds:
                for f in feeds.values():
                    f.close()
            raise

    def _note_chunk(self, key: str, chunk: Stream) -> None:
        """Fold one appended chunk into the manifest stats, so
        ``finalize_chunks`` never re-reads what this process just wrote."""
        st = self._chunk_stats.setdefault(
            key, {"rows": 0, "nbytes": 0, "t_first": None, "t_last": None})
        st["rows"] += len(chunk)
        st["nbytes"] += chunk.nbytes()
        if len(chunk):
            if st["t_first"] is None:
                st["t_first"] = float(chunk.t[0])
            st["t_last"] = float(chunk.t[-1])

    def _manifest_stats(self, key: str) -> Optional[Dict]:
        st = self._chunk_stats.get(key)
        if st is None:
            return None
        return {"rows": st["rows"], "nbytes": st["nbytes"],
                "time_range_s": ((st["t_last"] - st["t_first"])
                                 if st["t_first"] is not None else 0.0)}

    def _feed_chunk(self, feeds, spec, k: int, chunk: Stream) -> None:
        if feeds is None or spec.scenario not in feeds:
            return
        feeds[spec.scenario].put(chunk)
        if k == spec.n_chunks - 1:
            feeds[spec.scenario].close()

    @staticmethod
    def _slice_stream(sim: Stream, lo: int, hi: int) -> Stream:
        """One chunk of an already-materialized sim (host data): its
        scale stamps are sorted, so the chunk is one searchsorted slice."""
        a, b = np.searchsorted(sim.scale_stamp, [lo, hi])
        return Stream(name=sim.name, t=sim.t[a:b],
                      payload={c: v[a:b] for c, v in sim.payload.items()},
                      scale_stamp=sim.scale_stamp[a:b])

    def _host_round(self, result, feeds, k: int,
                    scenarios: List) -> None:
        """Push chunk ``k`` of every HOST-materialized scenario (cache
        hits in device mode; everything in host mode) into the feeds and,
        for store-missing scenarios, append the chunk file."""
        missing = {s.scenario for s in self.plan.local_missing}
        for spec in scenarios:
            if k >= spec.n_chunks:
                continue
            sim = result.host_sims[spec.scenario]
            lo = k * self.chunk_s
            hi = min(lo + self.chunk_s, spec.span_s)
            chunk = self._slice_stream(sim, lo, hi)
            if self.store and spec.scenario in missing:
                self.store.append_chunk(spec.store_key, k, chunk)
                self._note_chunk(spec.store_key, chunk)
            self._feed_chunk(feeds, spec, k, chunk)

    def _run_device(self, feeds) -> DeviceSweepResult:
        from repro.kernels import ops

        plan = self.plan
        result = DeviceSweepResult(plan, self.originals, self.store,
                                   self.backend, "device")
        result.checkpoint = self.checkpoint
        t0 = time.perf_counter()
        for spec in plan.cached:
            result.host_sims[spec.scenario] = \
                self.store.get(spec.store_key)
        cached = [s for s in plan.scenarios
                  if s.scenario in result.host_sims]

        def _dispatch(k: int) -> List[Tuple[Dict, object]]:
            out = []
            for st in self._shard_states:
                lo = k * self.chunk_s
                hi = min(lo + self.chunk_s, st["nsa"].width)
                if lo >= hi:
                    continue          # this shard's timeline is over
                h = st["nsa"].chunk(lo, hi)
                st["carry"] = ops.stream_metrics_chunk(
                    st["carry"], h.ss_kept, h.totals, lo, hi)
                out.append((st, h))
            return out

        def _host_leg(handles, k: int) -> None:
            for st, h in handles:
                # the ONE sync per (shard, chunk) — chunk k+1's dispatch
                # is already in flight when this blocks
                totals = np.asarray(h.totals, np.int64)
                chunks = materialize_sweep_chunk(
                    self.originals, st["nsa"].pairs, h, totals)
                for r, spec in enumerate(st["shard"].specs):
                    if k >= spec.n_chunks:
                        continue
                    st["totals"][r] += int(totals[r])
                    if self.store:
                        self.store.append_chunk(spec.store_key, k,
                                                chunks[r])
                        self._note_chunk(spec.store_key, chunks[r])
                    self._feed_chunk(feeds, spec, k, chunks[r])
            self._host_round(result, feeds, k, cached)

        # the double-buffered loop: dispatch k, THEN drain k-1's host leg
        prev: Optional[Tuple[List, int]] = None
        for k in range(plan.n_chunks):
            cur = _dispatch(k)
            if prev is not None:
                _host_leg(*prev)
            prev = (cur, k)
        if prev is not None:
            _host_leg(*prev)

        # compose: fold each shard's carry into monolithic-shaped stats
        for st in self._shard_states:
            hist, mom2 = ops.chunk_carry_finalize(st["carry"])
            result.shard_results.append(ShardResult(
                shard=st["shard"],
                pairs=tuple(s.scenario for s in st["shard"].specs),
                ss_kept=None, idx=None, totals=st["totals"].copy(),
                hist=hist, mom=np.asarray(mom2, np.float64), nsa_s=0.0))
        if self.store:
            for st in self._shard_states:
                for spec in st["shard"].specs:
                    self.store.finalize_chunks(
                        spec.store_key,
                        name=self.originals[spec.dataset].name,
                        n_chunks=spec.n_chunks,
                        extra_meta={"max_range": spec.max_range},
                        stats=self._manifest_stats(spec.store_key))
            result._persisted = True
            if self.checkpoint is not None:
                self.checkpoint.mark_materialized(
                    [s.scenario for s in plan.local_missing])
        total_s = time.perf_counter() - t0
        for sc in (s.scenario for s in plan.scenarios):
            result.nsa_s[sc] = 0.0
        result.sim_row_counts = {}
        for sr in result.shard_results:
            for r, sc in enumerate(sr.pairs):
                result.nsa_s[sc] = total_s
                result.sim_row_counts[sc] = int(sr.totals[r])
        for spec in plan.cached:
            result.sim_row_counts[spec.scenario] = \
                len(result.host_sims[spec.scenario])
        return result

    def _run_host(self, feeds) -> DeviceSweepResult:
        plan = self.plan
        result = DeviceSweepResult(plan, self.originals, self.store,
                                   self.backend, "host")
        result.checkpoint = self.checkpoint
        t0 = time.perf_counter()
        for spec in plan.local_missing:
            result.host_sims[spec.scenario] = nsa(
                self.originals[spec.dataset], spec.span_s,
                multiple_mode=self.multiple_mode, backend="numpy")
        t_sweep = time.perf_counter() - t0
        for spec in plan.cached:
            result.host_sims[spec.scenario] = \
                self.store.get(spec.store_key)
        local = [s for s in plan.scenarios
                 if s.scenario in result.host_sims]
        for k in range(plan.n_chunks):
            self._host_round(result, feeds, k, local)
        if self.store:
            for spec in plan.local_missing:
                self.store.finalize_chunks(
                    spec.store_key,
                    name=result.host_sims[spec.scenario].name,
                    n_chunks=spec.n_chunks,
                    extra_meta={"max_range": spec.max_range},
                    stats=self._manifest_stats(spec.store_key))
            result._persisted = True
            if self.checkpoint is not None:
                self.checkpoint.mark_materialized(
                    [s.scenario for s in plan.local_missing])
        for spec in plan.scenarios:
            result.nsa_s[spec.scenario] = 0.0 if spec.cached else t_sweep
        scenarios = [sc for sc in (s.scenario for s in plan.scenarios)
                     if sc in result.host_sims]
        datasets = list(plan.datasets)
        ms = metrics_batched(
            [self.originals[d] for d in datasets] +
            [result.host_sims[sc] for sc in scenarios],
            [None] * len(datasets) +
            [self._specs[sc].span_s for sc in scenarios],
            backend=self.backend)
        result._om = dict(zip(datasets, ms[:len(datasets)]))
        result.sm = dict(zip(scenarios, ms[len(datasets):]))
        result._host_group_done = True
        result._sims = {sc: result.host_sims[sc] for sc in scenarios}
        result.sim_row_counts = {sc: len(result.host_sims[sc])
                                 for sc in scenarios}
        return result


def run_sweep_chunked(runner: ChunkedSweepRunner, consumer, *,
                      queue_size: int = 64, fidelity_window_s: int = 60,
                      t_pre: Optional[Dict[str, float]] = None,
                      fault_plan: Optional[FaultPlan] = None,
                      on_failure: str = "raise",
                      max_bytes: Optional[int] = None,
                      retention_policy: str = "block",
                      checkpoint: Optional[SweepCheckpoint] = None
                      ) -> Tuple[List[SimulationReport],
                                 List[FidelityReport]]:
    """Layer 3 of the chunked pipeline: compute, persist and REPLAY
    chunk-overlapped.

    The calling thread drives :meth:`ChunkedSweepRunner.run`; the
    :class:`~repro.streamsim.producer.MultiQueueProducer` (chunked walk)
    and the per-scenario consumers run on their own threads, consuming
    each scenario's :class:`~repro.streamsim.producer.ChunkFeed`
    (``maxsize=2``) — replay of chunk 0 starts while chunk 1 is still on
    device, and backpressure chains queue → feed → runner so host
    residency stays bounded end to end.

    Differences from :func:`run_sweep` (by design): no
    ``retry_policy``/``consumer_deadline_s`` — a chunked replay cannot
    rewind a scenario's stream (its chunks are consumed as produced), so
    scenario-grain solo retries are a monolithic-path feature;
    ``on_failure="degrade"`` still converts terminal consumer failures
    into partial reports. Fault injection (``fault_plan``) applies
    unchanged — the producer-side transport schedule walks the chunked
    rounds identically to the monolithic walk.
    """
    if on_failure not in ("raise", "degrade"):
        raise ValueError(
            f"on_failure must be 'raise' or 'degrade', got {on_failure!r}")
    t_pre = t_pre or {}
    scenarios = list(runner.scenarios)
    feeds = {sc: ChunkFeed(maxsize=2) for sc in scenarios}
    group = QueueGroup(feeds, maxsize=queue_size, max_bytes=max_bytes,
                       retention_policy=retention_policy)
    producer = MultiQueueProducer(feeds, group.queues,
                                  clock=VirtualClock(),
                                  fault_plan=fault_plan)
    wrapped = {sc: (fault_plan.wrap_consumer(sc, consumer)
                    if fault_plan is not None else consumer)
               for sc in scenarios}
    status = [None]
    results: Dict = {}
    errors: Dict[object, BaseException] = {}

    def _produce():
        status[0] = producer.run()

    def _consume(sc):
        try:
            results[sc] = wrapped[sc](group[sc])
        except Exception as exc:    # keep the producer walk drainable
            errors[sc] = exc
            for _ in group[sc]:
                pass

    t0 = time.perf_counter()
    prod_th = threading.Thread(target=_produce, daemon=True)
    cons = {sc: threading.Thread(target=_consume, args=(sc,), daemon=True)
            for sc in scenarios}
    prod_th.start()
    for th in cons.values():
        th.start()
    result = runner.run(feeds)       # the chunk pipeline, on THIS thread
    prod_th.join()
    for th in cons.values():
        th.join()
    t_prod = time.perf_counter() - t0
    if errors and on_failure == "raise":
        ordered = [(sc, errors[sc]) for sc in scenarios if sc in errors]
        detail = "; ".join(f"{sc!r}: {exc!r}" for sc, exc in ordered)
        raise RuntimeError(
            f"{len(ordered)} of {len(scenarios)} chunked sweep "
            f"consumer(s) failed: {detail}") from ordered[0][1]
    if status[0] != 0:
        raise RuntimeError("producer reported fault status")

    all_metrics: Dict = {}
    for sc in scenarios:
        if sc in errors:
            all_metrics[sc] = {
                "degraded": True, "failed": repr(errors[sc]),
                "attempts": 1, **group[sc].stats(), **producer.stats(sc)}
        else:
            all_metrics[sc] = {**results[sc], **group[sc].stats(),
                               **producer.stats(sc)}
    fidelity = result.fidelity(fidelity_window_s)
    result._ensure_stats()
    reports = []
    for sc in result.scenarios:
        r = build_report(result, sc, t_pre.get(sc[0], 0.0), t_prod,
                         all_metrics[sc])
        if checkpoint is not None:
            checkpoint.mark_report(r)
        reports.append(r)
    return reports, fidelity
