"""Fault injection — the chaos layer of the replay pipeline.

Real IoT transports lose, duplicate, reorder, and stall messages
(RIoTBench benchmarks stream platforms under exactly these conditions;
IOTSim models broker-mediated delivery at cloud scale). The replay path
(:class:`~repro.streamsim.producer.Producer` /
:class:`~repro.streamsim.producer.MultiQueueProducer` →
:class:`~repro.streamsim.queue.StreamQueue` →
:func:`~repro.streamsim.engine.replay_many`) is a *perfect* transport by
default; this module makes imperfection an explicit, **seeded, bit-
reproducible** axis of the scenario sweep, the same way ``max_range`` is
an axis of the simulation grid.

Design contract
---------------
- A :class:`FaultPlan` maps every scenario to a :class:`FaultSpec`
  (rates + windows for each fault kind). ``plan.injector(key)`` derives a
  per-scenario :class:`FaultInjector` whose RNG stream is keyed by
  ``sha256(seed, key)`` — NOT Python's randomized ``hash`` — so the same
  seed yields a **bit-identical fault schedule** across runs, processes,
  and hosts, regardless of how scenarios interleave in the merged
  multi-queue timeline (each scenario draws from its own stream).
- Draws happen in a FIXED order (one uniform vector per bucket, one
  integer per held bucket) so the schedule for fault kind X never shifts
  when the rate of fault kind Y changes from zero.
- A no-op spec (:attr:`FaultSpec.is_noop`) short-circuits every hook:
  a drop-free plan leaves replay stats **bit-equal** to the fault-free
  pipeline (tested).
- Every injected event is counted; the producer/queue ``stats()``
  surfaces the counters so per-scenario delivery reconciles as
  ``delivered == emitted - dropped + duplicated``.

Fault taxonomy (``docs/robustness.md`` has the full semantics):

=================  =========================================================
kind               effect at the injection point
=================  =========================================================
drop               bucket never reaches the queue (counted, not delivered)
duplicate          bucket is put twice (at-least-once delivery upper bound)
reorder            bucket held back and released within ``reorder_window``
                   later emissions (bounded out-of-order delivery)
delay              extra per-bucket emission jitter in ``[0, delay_jitter_s]``
stall              producer pauses ``stall_s`` before the bucket (broker
                   stall / GC pause on the transport)
consumer_crash     the wrapped consumer raises
                   :class:`InjectedConsumerCrash` on the scheduled
                   attempt(s) — the resilience layer's retry fodder
=================  =========================================================
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "FaultSpec",
    "FaultPlan",
    "FaultInjector",
    "EmitAction",
    "InjectedConsumerCrash",
    "NOOP_SPEC",
]


class InjectedConsumerCrash(RuntimeError):
    """Raised by a fault-wrapped consumer on a scheduled crash attempt."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-scenario fault rates and windows. All-zero == perfect transport.

    ``consumer_crash_attempts`` holds 1-based replay attempt numbers on
    which the wrapped consumer raises — ``(1,)`` models a transient
    failure healed by one retry, ``(1, 2, 3, ...)`` a persistent one that
    should trip the circuit breaker.
    ``consumer_crash_after`` is how many buckets the consumer drains
    before crashing (a mid-stream failure, not an instant one).
    """

    drop_rate: float = 0.0
    duplicate_rate: float = 0.0
    reorder_rate: float = 0.0
    reorder_window: int = 4
    delay_jitter_s: float = 0.0
    stall_rate: float = 0.0
    stall_s: float = 0.0
    consumer_crash_attempts: Tuple[int, ...] = ()
    consumer_crash_after: int = 0

    def __post_init__(self):
        for f in ("drop_rate", "duplicate_rate", "reorder_rate",
                  "stall_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        if self.reorder_window < 1:
            raise ValueError("reorder_window must be >= 1")
        if self.delay_jitter_s < 0 or self.stall_s < 0:
            raise ValueError("delay_jitter_s / stall_s must be >= 0")
        if self.consumer_crash_after < 0:
            raise ValueError("consumer_crash_after must be >= 0")
        if any(a < 1 for a in self.consumer_crash_attempts):
            raise ValueError("crash attempts are 1-based")

    @property
    def is_noop(self) -> bool:
        """True when every hook can short-circuit (perfect transport)."""
        return (self.drop_rate == 0.0 and self.duplicate_rate == 0.0 and
                self.reorder_rate == 0.0 and self.delay_jitter_s == 0.0 and
                self.stall_rate == 0.0 and
                not self.consumer_crash_attempts)


NOOP_SPEC = FaultSpec()


@dataclasses.dataclass(frozen=True)
class EmitAction:
    """One bucket's drawn fate (the producer applies it in this order)."""

    stall_s: float = 0.0    #: sleep before the bucket (producer stall)
    delay_s: float = 0.0    #: extra jitter sleep before the bucket
    drop: bool = False      #: bucket never reaches the queue
    duplicate: bool = False  #: bucket is put twice
    hold: int = 0           #: >0: hold back, release after N emissions


_PASS = EmitAction()


def _derive_key(seed: int, key: object) -> np.ndarray:
    """Stable 2-word Philox key from (seed, scenario key).

    ``sha256`` — not the per-process-randomized builtin ``hash`` — so the
    schedule is identical across runs, interpreters, and hosts.
    """
    digest = hashlib.sha256(
        f"faultplan:{seed}|{key!r}".encode()).digest()
    return np.frombuffer(digest[:16], dtype=np.uint64).copy()


class FaultInjector:
    """One scenario's deterministic fault schedule + live counters.

    The injector is consumed by the producer hot path: ``draw()`` per
    source bucket (returns the bucket's :class:`EmitAction`),
    ``hold()``/``release_due()`` for the bounded-reorder buffer, and
    ``flush()`` at end-of-stream. ``reset()`` rewinds the RNG to the
    start of the schedule — a retried replay attempt sees the *same*
    drops/duplicates/reorders, so retry stats stay reconcilable — while
    the attempt counter (used by the consumer-crash schedule) keeps
    advancing.
    """

    def __init__(self, spec: FaultSpec, seed: int, key: object):
        self.spec = spec
        self.key = key
        self._rng_key = _derive_key(seed, key)
        self.attempts = 0
        self._pending: List[Tuple[int, object]] = []  # [remaining, bucket]
        self.reset()

    # ------------------------------------------------------------ schedule
    def reset(self) -> None:
        """Rewind to the start of the fault schedule (new replay attempt)."""
        self._rng = np.random.Generator(
            np.random.Philox(key=self._rng_key))
        self._pending.clear()
        self.dropped = 0
        self.duplicated = 0
        self.reordered = 0
        self.delayed = 0
        self.stalled = 0

    def draw(self) -> EmitAction:
        """Draw the next source bucket's fate (fixed draw order)."""
        spec = self.spec
        if spec.is_noop:
            return _PASS
        # ONE uniform vector per bucket, fixed slot per fault kind
        # (incl. the reorder hold length): the schedule for kind X never
        # shifts when the rate of kind Y changes
        u = self._rng.random(6)
        stall_s = spec.stall_s if u[3] < spec.stall_rate else 0.0
        delay_s = u[4] * spec.delay_jitter_s
        if stall_s > 0.0:
            self.stalled += 1
        if delay_s > 0.0:
            self.delayed += 1
        if u[0] < spec.drop_rate:
            self.dropped += 1
            return EmitAction(stall_s=stall_s, delay_s=delay_s, drop=True)
        if u[1] < spec.duplicate_rate:
            self.duplicated += 1
            return EmitAction(stall_s=stall_s, delay_s=delay_s,
                              duplicate=True)
        if u[2] < spec.reorder_rate:
            hold = 1 + int(u[5] * spec.reorder_window)
            self.reordered += 1
            return EmitAction(stall_s=stall_s, delay_s=delay_s, hold=hold)
        return EmitAction(stall_s=stall_s, delay_s=delay_s)

    # ------------------------------------------------------ reorder buffer
    def hold(self, bucket, n: int) -> None:
        """Park a bucket; it releases after ``n`` subsequent emissions."""
        self._pending.append([n, bucket])

    def release_due(self) -> List:
        """Advance the hold counters one emission; return released buckets."""
        if not self._pending:
            return []
        due, keep = [], []
        for item in self._pending:
            item[0] -= 1
            (due if item[0] <= 0 else keep).append(item)
        self._pending = keep
        return [b for _, b in due]

    def flush(self) -> List:
        """End-of-stream: every held bucket is released (bounded loss-free
        reorder — holds never become drops)."""
        due = [b for _, b in self._pending]
        self._pending.clear()
        return due

    # ----------------------------------------------------- consumer crash
    def next_attempt(self) -> int:
        """Advance and return the 1-based replay attempt number."""
        self.attempts += 1
        return self.attempts

    def crashes_on(self, attempt: int) -> bool:
        return attempt in self.spec.consumer_crash_attempts

    # ------------------------------------------------------------ counters
    def stats(self) -> Dict[str, int]:
        return {
            "fault_dropped": self.dropped,
            "fault_duplicated": self.duplicated,
            "fault_reordered": self.reordered,
            "fault_delayed": self.delayed,
            "fault_stalled": self.stalled,
        }


class _CrashingConsumer:
    """Consumer wrapper enforcing the injector's crash schedule.

    Named class (not a closure) so replay error messages show something
    greppable; thread-safe as long as the wrapped consumer is (each
    scenario gets its OWN wrapper instance).
    """

    def __init__(self, injector: FaultInjector, consumer: Callable):
        self._injector = injector
        self._consumer = consumer
        # advertise the wrapped task's name (see engine.consumer_label)
        # so deadline errors name the task even through the crash wrapper
        label = getattr(consumer, "name", None) \
            or getattr(consumer, "__name__", None)
        if isinstance(label, str) and label:
            self.name = label

    def __call__(self, queue):
        attempt = self._injector.next_attempt()
        if self._injector.crashes_on(attempt):
            after = self._injector.spec.consumer_crash_after
            for _ in range(after):
                if queue.get() is None:
                    break
            raise InjectedConsumerCrash(
                f"injected consumer crash (scenario {self._injector.key!r},"
                f" attempt {attempt})")
        return self._consumer(queue)


class FaultPlan:
    """Seeded, composable per-scenario fault schedules.

    ``FaultPlan(seed, default=spec)`` applies ``spec`` to every scenario;
    ``overrides`` pins specific scenarios to their own spec (e.g. one
    crash-prone consumer in an otherwise lossy-but-alive sweep). Plans
    compose with the scenario axis exactly like ``max_range`` does: the
    same plan object drives a single :class:`~repro.streamsim.producer.
    Producer`, the merged :class:`~repro.streamsim.producer.
    MultiQueueProducer` walk, and the engine's
    :func:`~repro.streamsim.engine.replay_many` — with identical
    per-scenario schedules in all three, because each scenario's RNG
    stream is keyed by ``(seed, scenario key)`` alone.

    Injectors are memoized per key: the producer hooks and the consumer
    wrapper of one replay share one injector (one schedule, one counter
    set). ``fresh_injectors()`` starts a new replay generation.
    """

    def __init__(self, seed: int, default: FaultSpec = NOOP_SPEC,
                 overrides: Optional[Mapping[object, FaultSpec]] = None):
        self.seed = int(seed)
        self.default = default
        self.overrides = dict(overrides or {})
        self._injectors: Dict[object, FaultInjector] = {}

    def spec_for(self, key: object) -> FaultSpec:
        return self.overrides.get(key, self.default)

    def injector(self, key: object) -> FaultInjector:
        """The scenario's (memoized) injector — deterministic in
        ``(seed, key)`` only."""
        inj = self._injectors.get(key)
        if inj is None:
            inj = FaultInjector(self.spec_for(key), self.seed, key)
            self._injectors[key] = inj
        return inj

    def fresh_injectors(self) -> None:
        """Drop memoized injectors (a new replay generation: schedules
        restart from the top AND attempt counters restart)."""
        self._injectors.clear()

    def wrap_consumer(self, key: object, consumer: Callable) -> Callable:
        """Consumer with the scenario's crash schedule applied (identity
        pass-through when no crashes are scheduled)."""
        if not self.spec_for(key).consumer_crash_attempts:
            return consumer
        return _CrashingConsumer(self.injector(key), consumer)

    def is_noop_for(self, key: object) -> bool:
        return self.spec_for(key).is_noop

    def stats(self) -> Dict[object, Dict[str, int]]:
        """Live counters of every injector touched so far."""
        return {k: inj.stats() for k, inj in self._injectors.items()}
