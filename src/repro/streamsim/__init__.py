"""Stream-data simulation substrate (the paper's contribution).

Pipeline stages (paper Fig. 4):
  POSD  -> :mod:`repro.streamsim.preprocess`
  NSSD  -> :mod:`repro.streamsim.nsa`         (Algorithm 1)
  PSD   -> :mod:`repro.streamsim.producer`    (Algorithm 2)
  SPS   -> consumer side: repro.training / repro.serving

Supporting pieces: synthetic datasets, the stream store ("database"),
the Kafka-analogue bounded queue, volatility metrics, the controller,
and the robustness layer — seeded fault injection
(:mod:`repro.streamsim.faults`) plus retry/breaker/deadline/checkpoint
primitives (:mod:`repro.streamsim.resilience`).
"""

from repro.streamsim.datasets import (  # noqa: F401
    DATASETS,
    make_stream,
    sogouq,
    traffic,
    userbehavior,
)
from repro.streamsim.preprocess import Stream, preprocess  # noqa: F401
from repro.streamsim.nsa import (  # noqa: F401
    nsa,
    nsa_batched,
    nsa_paper,
    nsa_sweep,
    scale_stamps,
)
from repro.streamsim.metrics import (  # noqa: F401
    StreamMetrics,
    metrics_batched,
    per_second_counts,
    trend,
    trend_correlation,
    trend_correlation_matrix,
    volatility,
)
from repro.streamsim.store import StreamStore  # noqa: F401
from repro.streamsim.queue import (  # noqa: F401
    ByteBudget,
    QueueGroup,
    StreamQueue,
)
from repro.streamsim.faults import (  # noqa: F401
    FaultInjector,
    FaultPlan,
    FaultSpec,
    InjectedConsumerCrash,
)
from repro.streamsim.resilience import (  # noqa: F401
    BreakerOpen,
    CircuitBreaker,
    Deadline,
    Heartbeat,
    Lease,
    RetryPolicy,
    SweepCheckpoint,
)
from repro.streamsim.producer import (  # noqa: F401
    ChunkFeed,
    MultiQueueProducer,
    Producer,
    RealClock,
    VirtualClock,
)
from repro.streamsim.plan import (  # noqa: F401
    ScenarioSpec,
    Shard,
    SweepPlan,
    plan_sweep,
)
from repro.streamsim.engine import (  # noqa: F401
    ChunkedSweepRunner,
    DeviceSweepResult,
    FidelityReport,
    SimulationReport,
    consumer_label,
    execute_sweep,
    run_sweep,
    run_sweep_chunked,
)
from repro.streamsim.controller import Controller  # noqa: F401
from repro.streamsim.service import (  # noqa: F401
    SweepService,
    merge_fidelity,
    pack_counts,
    run_service_sweep,
    unpack_counts,
)
from repro.streamsim.tasks import (  # noqa: F401
    BucketTask,
    ETLTask,
    EventDetectTask,
    ServingTask,
    StreamTask,
    WindowedStatsTask,
)
from repro.streamsim.taskbench import (  # noqa: F401
    FIDELITY_FLOOR,
    PAPER_SPEEDUP,
    LatencySummary,
    TaskBenchRunner,
    TaskReport,
    original_replay_stream,
    slice_stream,
    summarize_latencies,
)
