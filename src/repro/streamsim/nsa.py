"""NSA — Normalizing and Sampling Stream Data (paper Algorithm 1).

Semantics
---------
Given a bounded stream ``B`` with timestamps ``t`` spanning ``T`` seconds and
a user time range ``max`` (the paper's symbol; here ``max_range``):

1. **Normalize** (Min-Max, paper formula (1), ``min = 0``)::

       scale_stamp_i = floor( (t_i - t_min) / (t_max - t_min) * max_range )

   Min-Max is the only normalization preserving record order and relative
   spacing, which the paper requires ("so that the data is dependent on the
   time series").

2. **Sample** (systematic, per scale-stamp bucket): compression multiplies
   the per-second arrival rate by ``multiple = T / max_range``; sampling
   divides it back. Each bucket keeps ``len(bucket) / multiple`` records,
   chosen every-``multiple``-th ("setting a second as the distance"), so the
   simulated per-second rate matches the *original* per-second rate and
   Tables 1-3 volatility statistics are preserved.

   .. note:: the paper's pseudocode computes ``multiple = Len(B)/max``. With
      ``Len(B)`` = record count, the kept rate would be ``rate/avg_rate`` ≈ 1
      rec/s — contradicting Tables 1-3 where the simulated average equals the
      original per-second average (~25/s for SogouQ). ``Len(B)`` must denote
      the stream's *time length* (the tables' note: "original time range of
      stream data set is 86400s"), i.e. ``multiple = T / max`` — the
      "normalization multiple" of §3.2. We implement that reading; the
      pseudocode-literal reading is available as ``multiple_mode='records'``
      for comparison.

Implementations
---------------
- :func:`nsa_paper` — faithful per-record Python loop, the paper-written
  algorithm (the §Perf baseline; O(n) interpreted).
- :func:`nsa` — vectorized numpy (beyond-paper; same output bit-for-bit).
- :func:`nsa` with ``backend="pallas"`` — the device-resident fast path:
  normalize + keep mask (``ops.stream_sample``) and mask compaction
  (``ops.compact_mask``) run on device; only the O(max_range) per-bucket
  tables and the final column gather touch the host. Bit-identical to the
  numpy path (the kernel snaps its f32 buckets to exact f64 tables).
- :func:`nsa_batched` — S streams in ONE kernel dispatch
  (``ops.stream_sample_batched``) instead of S sequential ones.
- :func:`nsa_sweep` — the full (stream × max_range) scenario grid in ONE
  kernel dispatch: per-scenario bucket tables are padded to the sweep's
  maximum bucket count (masked tail buckets with zero keep budget) and
  every scenario's keep mask compacts through one batched scan, so the
  whole Tables 1-3 sweep costs one normalize→sample→mask→compact→gather
  chain instead of one per ``max_range``.

Backend selection rules
-----------------------
``backend`` on :func:`nsa` / :func:`nsa_batched` (and the passthrough knob
on ``Controller.simulate``/``Controller.run``) accepts:

- ``"auto"``  — the device path when JAX reports a TPU backend, else numpy.
  Off-TPU the Pallas kernels would run in ``interpret`` mode, which is
  correct but slower than vectorized numpy — so auto never picks it on CPU.
- ``"pallas"`` — force the device path (interpret mode off-TPU; this is what
  tests and CPU benchmarks use).
- ``"numpy"`` — force the host path.

Every backend produces bit-identical output for the same arguments.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.streamsim.preprocess import Stream

BACKENDS = ("auto", "numpy", "pallas")


def _resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "auto":
        from repro.kernels.ops import on_accelerator
        return "pallas" if on_accelerator() else "numpy"
    return backend


def scale_stamps(t: np.ndarray, max_range: int) -> np.ndarray:
    """Min-Max normalize timestamps into integer buckets [0, max_range).

    Paper formula (1) with min=0, floored to the containing simulated second.
    """
    t = np.asarray(t, dtype=np.float64)
    if len(t) == 0:
        return np.zeros(0, dtype=np.int64)
    t_min, t_max = float(t[0]), float(t[-1])
    span = t_max - t_min
    if span <= 0.0:
        return np.zeros(len(t), dtype=np.int64)
    ss = np.floor((t - t_min) / span * max_range).astype(np.int64)
    # the record at t_max lands exactly on max_range -> clamp into last bucket
    np.clip(ss, 0, max_range - 1, out=ss)
    return ss


def _multiple(stream_len_records: int, time_range_s: float, max_range: int,
              mode: str) -> float:
    if mode == "time":       # the reading consistent with Tables 1-3
        return max(time_range_s / max_range, 1.0)
    elif mode == "records":  # pseudocode-literal reading, kept for comparison
        return max(stream_len_records / max_range, 1.0)
    raise ValueError(f"multiple_mode must be 'time'|'records', got {mode!r}")


def systematic_keep_mask(ss: np.ndarray, max_range: int, multiple: float,
                         *, keep: str = "systematic") -> np.ndarray:
    """Per-record boolean keep mask implementing the per-bucket sampling.

    ``ss`` must be non-decreasing (it is, since Min-Max is monotone and the
    stream is chronological). Within bucket ``b`` with ``c`` records, keep
    ``k = round(c / multiple)`` records (>=1 if the bucket is non-empty):

    - ``keep='systematic'`` — Bresenham-even selection: record with in-bucket
      rank ``r`` survives iff ``(r*k) % c < k``; exactly ``k`` survive, evenly
      spaced (the paper text's systematic sampling).
    - ``keep='first'``      — keep ranks ``< k`` (the paper pseudocode's
      ``if i > rs then remove`` reading).
    """
    n = len(ss)
    if n == 0:
        return np.zeros(0, dtype=bool)
    counts = np.bincount(ss, minlength=max_range).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(n, dtype=np.int64) - starts[ss]
    c = counts[ss]
    k = np.rint(c / multiple).astype(np.int64)
    k = np.clip(k, 1, None)  # non-empty buckets keep at least one record
    if keep == "systematic":
        return (rank * k) % np.maximum(c, 1) < k
    elif keep == "first":
        return rank < k
    raise ValueError(f"keep must be 'systematic'|'first', got {keep!r}")


def nsa(stream: Stream, max_range: int, *, keep: str = "systematic",
        multiple_mode: str = "time", backend: str = "numpy",
        autotune: Optional[str] = None) -> Stream:
    """Vectorized NSA (Algorithm 1): normalize + sample -> simulated stream Ds.

    Parameters
    ----------
    stream : Stream
        Preprocessed (chronological) original stream.
    max_range : int
        Target simulated time range in seconds (the paper's ``max``); must
        be positive.
    keep : {"systematic", "first"}
        In-bucket sampling rule — Bresenham-even systematic selection (the
        paper text) or keep-first-k (the pseudocode-literal reading). The
        device kernel only implements ``"systematic"``; ``"first"`` always
        takes the numpy path.
    multiple_mode : {"time", "records"}
        How the compression multiple is derived (see the module
        docstring's note on the paper's ``Len(B)`` ambiguity).
    backend : {"numpy", "pallas", "auto"}
        ``"pallas"`` runs normalize → keep-mask → compaction → gather
        device-resident (two fused Pallas dispatches + one XLA scatter);
        ``"auto"`` picks pallas on any real accelerator (TPU or GPU),
        numpy otherwise.
    autotune : {"off", "cached", "force"}, optional
        Tile-tuning mode for the device dispatches
        (:mod:`repro.kernels.tuning`); ``None``/``"off"`` keeps the
        bit-for-bit heuristic defaults. Winners here stay in-memory —
        persistence needs a store (the engine/controller layers').

    Returns
    -------
    Stream
        The simulated stream: ``scale_stamp`` filled, records the
        systematic sample; per-second volatility statistics match the
        original's (paper §5.2). **Bit-identical across backends** — the
        kernel snaps its f32 buckets to exact f64 host tables.

    Raises
    ------
    ValueError
        If ``max_range <= 0`` or ``keep``/``multiple_mode`` is unknown.

    Notes
    -----
    Streams outside the device kernels' exactness domain (int32 keep-rule
    overflow, ``max_range`` past the ±1-snap guarantee) raise
    :class:`repro.kernels.ops.PallasDomainError` inside the ops layer;
    this function catches it and silently falls back to the numpy path, so
    the bit-identity contract survives out-of-domain inputs.
    """
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    m = _multiple(len(stream), stream.time_range, max_range, multiple_mode)
    if (_resolve_backend(backend) == "pallas" and keep == "systematic"
            and len(stream) > 0):
        from repro.kernels import tuning
        from repro.kernels.ops import PallasDomainError
        try:
            with tuning.tuner_context(autotune):
                return _nsa_pallas(stream, max_range, m)
        except PallasDomainError:
            pass  # stream outside the kernel's exactness domain
    ss = scale_stamps(stream.t, max_range)
    mask = systematic_keep_mask(ss, max_range, m, keep=keep)
    return Stream(
        name=stream.name,
        t=stream.t[mask],
        payload={k: v[mask] for k, v in stream.payload.items()},
        scale_stamp=ss[mask],
    )


def _nsa_pallas(stream: Stream, max_range: int, multiple: float) -> Stream:
    """Device-resident NSA: normalize -> mask -> compact -> gather.

    The per-record work (bucketing, keep mask, prefix-sum compaction, index
    scatter) runs in two fused Pallas dispatches plus one XLA scatter; the
    host only builds the O(max_range) exact tables and fancy-indexes the
    payload columns (which may be float64/strings — not device-representable
    without loss) by the device-computed kept indices.
    """
    from repro.kernels import ops

    ss_dev, keep_dev = ops.stream_sample(stream.t, max_range, multiple)
    return _compact_gather(stream, ss_dev, keep_dev)


def _compact_gather(stream: Stream, ss_dev, keep_dev) -> Stream:
    """Shared tail of the device path: compact the keep mask to indices on
    device, gather scale stamps there (delivered as host int64 — the numpy
    path's dtype), and fancy-index the host columns once."""
    import jax.numpy as jnp
    from repro.kernels import ops

    idx_dev, total = ops.compact_mask(keep_dev)
    ss_kept = np.asarray(
        jnp.take(ss_dev, idx_dev[:total], mode="clip")).astype(np.int64)
    idx = np.asarray(idx_dev[:total])
    return Stream(
        name=stream.name,
        t=stream.t[idx],
        payload={k: v[idx] for k, v in stream.payload.items()},
        scale_stamp=ss_kept,
    )


def nsa_batched(streams: Dict[str, Stream], max_range: int, *,
                multiple_mode: str = "time", backend: str = "auto",
                autotune: Optional[str] = None) -> Dict[str, Stream]:
    """NSA over many concurrent device streams — the IoT-realistic shape.

    Parameters
    ----------
    streams : dict of str -> Stream
        Named streams to compress together.
    max_range : int
        Shared simulated time range (positive).
    multiple_mode : {"time", "records"}
        As in :func:`nsa`.
    backend : {"auto", "numpy", "pallas"}
        On ``"pallas"`` all S keep masks come from ONE batched kernel
        dispatch (2-D grid over streams × record tiles) instead of S
        sequential ones; each stream is then compacted and gathered as in
        :func:`nsa`. Off-TPU ``"auto"`` falls back to per-stream numpy.

    Returns
    -------
    dict of str -> Stream
        **Bit-identical** to ``{k: nsa(s, max_range)}`` for every backend.

    Raises
    ------
    ValueError
        If ``max_range <= 0``.

    Notes
    -----
    Batches containing an empty stream, and batches where any member falls
    outside the device kernels' domain
    (:class:`repro.kernels.ops.PallasDomainError`), fall back to the
    per-stream numpy path wholesale — never silently wrong output.
    """
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    resolved = _resolve_backend(backend)
    if resolved != "pallas" or not streams or \
            any(len(s) == 0 for s in streams.values()):
        return {name: nsa(s, max_range, multiple_mode=multiple_mode,
                          backend="numpy")
                for name, s in streams.items()}
    from repro.kernels import ops, tuning

    names = list(streams)
    ts = [streams[n].t for n in names]
    mults = [_multiple(len(streams[n]), streams[n].time_range, max_range,
                       multiple_mode) for n in names]
    try:
        with tuning.tuner_context(autotune):
            ss_b, keep_b, lengths = ops.stream_sample_batched(
                ts, max_range, mults)
            return {name: _compact_gather(streams[name], ss_b[s],
                                          keep_b[s, :lengths[s]])
                    for s, name in enumerate(names)}
    except ops.PallasDomainError:
        # some stream falls outside the kernel's exactness domain
        return {name: nsa(s, max_range, multiple_mode=multiple_mode,
                          backend="numpy")
                for name, s in streams.items()}


def nsa_sweep(streams: Dict[str, Stream], max_ranges: Sequence[int], *,
              pairs: Optional[Sequence[Tuple[str, int]]] = None,
              multiple_mode: str = "time", backend: str = "auto",
              autotune: Optional[str] = None
              ) -> Dict[Tuple[str, int], Stream]:
    """NSA over the full (stream × max_range) scenario grid — ONE dispatch.

    The Tables 1-3 sweep shape: every ``(name, max_range)`` scenario becomes
    one ROW of a single range-padded kernel launch. Rows simulated at a
    smaller ``max_range`` than the sweep's maximum get their bucket tables
    padded to the maximum with masked tail buckets (``counts = 0``, zero
    keep budget), and each row normalizes into its own bucket count carried
    as a kernel scalar — so mixing ``max_range = 1`` with ``max_range =
    3600`` in one launch is exact. All rows' keep masks then compact
    through ONE batched prefix-sum dispatch plus one XLA scatter
    (:func:`repro.kernels.ops.compact_mask_batched`).

    Parameters
    ----------
    streams : dict of str -> Stream
        Named source streams.
    max_ranges : sequence of int
        Simulated time ranges; with ``pairs=None`` the scenario grid is the
        cross product ``streams × max_ranges``.
    pairs : sequence of (str, int), optional
        Explicit scenario subset (e.g. only store-missing scenarios) —
        each entry names a stream and its ``max_range``. Overrides the
        cross product; ``max_ranges`` is ignored when given.
    multiple_mode : {"time", "records"}
        As in :func:`nsa`.
    backend : {"auto", "numpy", "pallas"}
        On ``"pallas"`` the whole grid is ONE ``stream_sample`` dispatch
        plus ONE batched compaction; ``"numpy"``/off-TPU ``"auto"`` run the
        per-scenario host path.

    Returns
    -------
    dict of (str, int) -> Stream
        One simulated stream per scenario, **bit-identical** to
        ``nsa(streams[name], max_range)`` — and therefore to the per-range
        :func:`nsa_batched` path — for every backend.

    Raises
    ------
    ValueError
        If any ``max_range`` is not positive.

    Notes
    -----
    Sweeps containing an empty stream, and sweeps where any scenario falls
    outside the device kernels' domain
    (:class:`repro.kernels.ops.PallasDomainError`), fall back to the
    per-scenario numpy path wholesale — never silently wrong output.
    """
    if pairs is None:
        pairs = [(name, mr) for name in streams for mr in max_ranges]
    pairs = [(name, int(mr)) for name, mr in pairs]
    if any(mr <= 0 for _, mr in pairs):
        raise ValueError("max_range must be positive")

    def _host() -> Dict[Tuple[str, int], Stream]:
        return {(name, mr): nsa(streams[name], mr,
                                multiple_mode=multiple_mode,
                                backend="numpy")
                for name, mr in pairs}

    resolved = _resolve_backend(backend)
    if resolved != "pallas" or not pairs or \
            any(len(streams[name]) == 0 for name, _ in pairs):
        return _host()
    from repro.kernels import ops
    try:
        ss_kept, idx_b, totals, _ = nsa_sweep_device(
            streams, pairs, multiple_mode=multiple_mode, autotune=autotune)
    except ops.PallasDomainError:
        # some scenario falls outside the kernel's exactness domain
        return _host()
    return materialize_sweep(streams, pairs, ss_kept, idx_b, totals)


def nsa_sweep_device(streams: Dict[str, Stream],
                     pairs: Sequence[Tuple[str, int]], *,
                     multiple_mode: str = "time", device=None,
                     autotune: Optional[str] = None):
    """The device leg of the range-padded sweep — NO host gather.

    Runs ONE ``stream_sample`` dispatch plus ONE batched compaction for
    the given scenario rows and returns device-resident handles, so a
    caller (the sweep engine) can chain the kept scale stamps straight
    into the fused metrics engine without a host round-trip; the payload
    gather is deferred to :func:`materialize_sweep`.

    Parameters
    ----------
    streams, pairs, multiple_mode :
        As in :func:`nsa_sweep` (``pairs`` is required here — this is the
        plan-driven entry point). Streams must be non-empty.
    device : optional
        jax device the whole chain is committed to (one plan shard per
        device).

    Returns
    -------
    (ss_kept, idx, totals, lengths)
        ``ss_kept`` int32 ``(R, N)`` device — row ``r``'s first
        ``totals[r]`` entries are the kept scale stamps (tail entries are
        clipped-gather garbage; mask by ``totals``). ``idx`` int32
        ``(R, N)`` device — kept-record indices, sentinel ``N`` past each
        row's total. ``totals`` int64 ``(R,)`` host (the O(R) scalars);
        ``lengths`` int64 ``(R,)`` host source lengths.

    Raises
    ------
    PallasDomainError
        When any scenario falls outside the kernels' exactness domain —
        callers fall back to the numpy path wholesale.
    """
    import jax.numpy as jnp
    from repro.kernels import ops, tuning

    ts = [streams[name].t for name, _ in pairs]
    mults = [_multiple(len(streams[name]), streams[name].time_range, mr,
                       multiple_mode) for name, mr in pairs]
    with tuning.tuner_context(autotune):
        ss_b, keep_b, lengths = ops.stream_sample_batched(
            ts, [mr for _, mr in pairs], mults, device=device)
        idx_b, totals = ops.compact_mask_batched(keep_b)
    N = idx_b.shape[1]
    ss_kept = jnp.take_along_axis(ss_b, jnp.clip(idx_b, 0, max(N - 1, 0)),
                                  axis=1)
    return ss_kept, idx_b, totals, lengths


def materialize_sweep(streams: Dict[str, Stream],
                      pairs: Sequence[Tuple[str, int]],
                      ss_kept, idx_b, totals) -> Dict[Tuple[str, int],
                                                      Stream]:
    """The single host pass of the device sweep: gather payload columns.

    Takes the handles of :func:`nsa_sweep_device`, moves the kept stamp /
    index matrices to host ONCE, and fancy-indexes each scenario's
    timestamp and payload columns (which may be float64/strings — not
    device-representable without loss). This is the only place a sweep's
    per-record data crosses to host.
    """
    ss_host = np.asarray(ss_kept).astype(np.int64)
    idx_host = np.asarray(idx_b)
    out = {}
    for r, (name, mr) in enumerate(pairs):
        src, total = streams[name], int(totals[r])
        idx = idx_host[r, :total]
        out[(name, mr)] = Stream(
            name=src.name,
            t=src.t[idx],
            payload={k: v[idx] for k, v in src.payload.items()},
            scale_stamp=ss_host[r, :total],
        )
    return out


@dataclasses.dataclass
class ChunkHandles:
    """Device handles for ONE chunk of a chunked sweep (see ChunkedNSA).

    ``ss_kept``/``idx``/``totals`` are device arrays — reading any of them
    forces a sync, which the pipeline defers until the NEXT chunk's
    dispatch is in flight. ``idx`` entries are LOCAL to the chunk's record
    slice; add ``rec_off[r]`` (host int64) to recover absolute record
    indices into the source stream.
    """
    ss_kept: object          # (R, Nc) int32 device — kept scale stamps
    idx: object              # (R, Nc) int32 device — local kept indices
    totals: object           # (R,)    int32 device — kept counts
    rec_off: np.ndarray      # (R,)    int64 host   — record slice offsets
    lo: int                  # chunk bucket range [lo, hi)
    hi: int


class ChunkedNSA:
    """Per-chunk device NSA over a scenario grid — the unbounded-stream form.

    Uploads each row's full-width bucket tables and (rebased f32)
    timestamps to the device ONCE, then serves the timeline chunk by
    chunk: ``chunk(lo, hi)`` runs the range-padded ``stream_sample``
    kernel on just the record slice whose scale stamps land in
    ``[lo, hi)`` and compacts its keep mask — all device-resident, no
    host sync (totals stay on device; see
    :func:`repro.kernels.ops.compact_mask_batched_device`).

    Bit-exactness with the monolithic sweep: a chunk's records are a
    CONTIGUOUS slice ``[starts[lo], starts[hi])`` of the sorted stream
    (records never split a bucket), and the kernel is launched with the
    full-width tables rebased by the slice offset — so each record sees
    the same f32 timestamp, the same snapped bucket and the same
    in-bucket rank as in the monolithic launch, and the keep bits are
    bit-identical. Concatenating the chunks reproduces
    :func:`nsa_sweep_device` exactly.

    Parameters
    ----------
    streams : dict of str -> Stream
        Source streams (non-empty).
    pairs : sequence of (name, eff_range)
        Scenario rows; ``eff_range`` is the row's EFFECTIVE simulated
        range (``ScenarioSpec.span_s`` — ``max_range`` per simulated day).
    multiple_mode : {"time", "records"}
        As in :func:`nsa`.
    device : optional
        jax device everything is committed to.

    Raises
    ------
    PallasDomainError
        At construction, when any row falls outside the kernels'
        exactness domain — callers fall back to the host path before any
        chunk state exists.
    """

    def __init__(self, streams: Dict[str, Stream],
                 pairs: Sequence[Tuple[str, int]], *,
                 multiple_mode: str = "time", device=None,
                 autotune: Optional[str] = None):
        import jax
        import jax.numpy as jnp
        from repro.kernels import ops

        self.autotune = autotune

        self.pairs = [(name, int(rng)) for name, rng in pairs]
        if not self.pairs:
            raise ValueError("need at least one scenario row")
        if any(rng <= 0 for _, rng in self.pairs):
            raise ValueError("ranges must be positive")
        ts = [np.asarray(streams[name].t, np.float64)
              for name, _ in self.pairs]
        if any(len(t) == 0 for t in ts):
            raise ValueError("chunked path requires non-empty streams")
        self.lengths = np.array([len(t) for t in ts], np.int64)
        self.width = max(rng for _, rng in self.pairs)
        R = len(self.pairs)
        self.N = max(int(-(-self.lengths.max() // ops.TILE) * ops.TILE),
                     ops.TILE)
        ops._check_metrics_domain(self.N)  # any chunk's kept width <= N
        mults = [_multiple(len(streams[name]), streams[name].time_range,
                           rng, multiple_mode)
                 for name, rng in self.pairs]
        t_b = np.empty((R, self.N), np.float32)
        starts_b = np.empty((R, self.width), np.int32)
        counts_b = np.empty((R, self.width), np.int32)
        k_b = np.empty((R, self.width), np.int32)
        scal_b = np.empty((R, 3), np.float32)
        for r, t64 in enumerate(ts):
            t32, starts, counts, ktab, scalars = ops._nsa_tables(
                t64, self.pairs[r][1], float(mults[r]), self.width)
            t_b[r, :len(t32)] = t32
            t_b[r, len(t32):] = t32[-1]      # pad into the last bucket
            starts_b[r], counts_b[r], k_b[r] = starts, counts, ktab
            scal_b[r] = scalars
        # host copy for slicing: col lo gives the first record of bucket
        # lo (tail buckets carry starts = n, so rows whose range ends
        # before the sweep's maximum contribute empty slices for free)
        self._starts_np = starts_b.astype(np.int64)

        def _dev(x):
            return jax.device_put(x, device) if device is not None \
                else jnp.asarray(x)

        self._dev = _dev
        self._t = _dev(t_b)
        self._starts = _dev(starts_b)
        self._counts = _dev(counts_b)
        self._ktab = _dev(k_b)
        self._scal = _dev(scal_b)

    def n_chunks(self, chunk_s: int) -> int:
        return -(-self.width // int(chunk_s))

    def chunk(self, lo: int, hi: int) -> ChunkHandles:
        """Dispatch NSA for absolute buckets ``[lo, hi)`` — async, no sync.

        The returned handles stay on device; the host reads them via
        :func:`materialize_sweep_chunk` one pipeline step later.
        """
        import jax.numpy as jnp
        from repro.kernels import ops, tuning

        lo, hi = int(lo), int(hi)
        if not 0 <= lo < hi <= self.width:
            raise ValueError(f"bad chunk range [{lo}, {hi}) for width "
                             f"{self.width}")
        a = self._starts_np[:, lo]
        b = self.lengths if hi >= self.width else self._starts_np[:, hi]
        m = b - a
        with tuning.tuner_context(self.autotune):
            cfg = tuning.config_for("stream_sample", s=len(self.pairs),
                                    n=max(int(m.max()), 1), r=self.width)
            tile = cfg.record_tile
            Nc = max(int(-(-max(int(m.max()), 1) // tile) * tile), tile)
            a_dev = self._dev(a.astype(np.int32))
            j = jnp.arange(Nc, dtype=jnp.int32)[None, :]
            gidx = jnp.clip(a_dev[:, None] + j, 0, self.N - 1)
            t_slice = jnp.take_along_axis(self._t, gidx, axis=1)
            # rebase the bucket tables by the slice offset: local rank ==
            # global rank, so the keep bits match the monolithic launch
            starts_reb = self._starts - a_dev[:, None]
            ss, keep = ops.stream_sample_pallas(
                t_slice, starts_reb, self._counts, self._ktab, self._scal,
                self.width, interpret=not ops.on_accelerator(), config=cfg)
            keep = keep.astype(bool) & \
                (j < self._dev(m.astype(np.int32))[:, None])
            idx, totals = ops.compact_mask_batched_device(keep)
        ss_kept = jnp.take_along_axis(ss, jnp.clip(idx, 0, max(Nc - 1, 0)),
                                      axis=1)
        return ChunkHandles(ss_kept=ss_kept, idx=idx, totals=totals,
                            rec_off=a, lo=lo, hi=hi)


def materialize_sweep_chunk(streams: Dict[str, Stream],
                            pairs: Sequence[Tuple[str, int]],
                            handles: ChunkHandles,
                            totals: np.ndarray) -> List[Stream]:
    """Host gather for ONE chunk — the pipeline's only sync point.

    ``totals`` is the host copy of ``handles.totals`` (the caller reads
    it first so the device sync happens exactly once per chunk, after the
    next chunk's dispatch is already in flight). Returns one Stream per
    scenario row, in ``pairs`` order.
    """
    ss_host = np.asarray(handles.ss_kept).astype(np.int64)
    idx_host = np.asarray(handles.idx)
    out = []
    for r, (name, _) in enumerate(pairs):
        src, total = streams[name], int(totals[r])
        gi = idx_host[r, :total].astype(np.int64) + int(handles.rec_off[r])
        out.append(Stream(
            name=src.name,
            t=src.t[gi],
            payload={k: v[gi] for k, v in src.payload.items()},
            scale_stamp=ss_host[r, :total],
        ))
    return out


def nsa_paper(stream: Stream, max_range: int, *, keep: str = "systematic",
              multiple_mode: str = "time") -> Stream:
    """Paper-faithful per-record NSA: literal loops mirroring Algorithm 1.

    Bit-identical output to :func:`nsa`; kept as the §Perf baseline and as
    executable documentation of the paper's pseudocode.
    """
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    n = len(stream)
    t = stream.t
    if n == 0:
        return Stream(stream.name, t[:0],
                      {k: v[:0] for k, v in stream.payload.items()},
                      np.zeros(0, dtype=np.int64))
    t_min, t_max = float(t[0]), float(t[-1])
    span = t_max - t_min
    # --- "Normalizing original stream data." (per-record loop) ---
    ss = np.empty(n, dtype=np.int64)
    for i in range(n):  # For s_i in B do
        if span <= 0.0:
            ss[i] = 0
        else:
            v = (t[i] - t_min) / span * max_range  # formula (1), min=0
            ss[i] = min(int(v), max_range - 1)
    # --- "Sampling normalized stream data." (per-bucket loop) ---
    m = _multiple(n, span, max_range, multiple_mode)
    keep_idx = []
    lo = 0
    for b in range(max_range):  # For i <- 0 to max do
        hi = lo
        while hi < n and ss[hi] == b:
            hi += 1
        c = hi - lo  # block = B[scale_stamp == i]
        if c > 0:
            k = max(int(round(c / m)), 1)  # rs = Len(block)/multiple
            for r in range(c):  # For s_i in block do
                if keep == "systematic":
                    if (r * k) % c < k:
                        keep_idx.append(lo + r)
                elif keep == "first":
                    if r < k:  # paper: "If i > rs then remove"
                        keep_idx.append(lo + r)
                else:
                    raise ValueError(f"bad keep {keep!r}")
        lo = hi
    idx = np.asarray(keep_idx, dtype=np.int64)
    return Stream(
        name=stream.name,
        t=t[idx],
        payload={k: v[idx] for k, v in stream.payload.items()},
        scale_stamp=ss[idx],
    )


def compression_factor(stream: Stream, max_range: int) -> float:
    """The task speedup the simulation buys: original range / simulated range.

    The paper's headline: one day into <=1 h  =>  >= 24x (§6).
    """
    return stream.time_range / float(max_range)


def expected_kept(stream: Stream, max_range: int) -> int:
    """Rough expected record count after NSA (for capacity planning)."""
    m = _multiple(len(stream), stream.time_range, max_range, "time")
    return int(math.ceil(len(stream) / m))
