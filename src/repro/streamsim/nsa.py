"""NSA — Normalizing and Sampling Stream Data (paper Algorithm 1).

Semantics
---------
Given a bounded stream ``B`` with timestamps ``t`` spanning ``T`` seconds and
a user time range ``max`` (the paper's symbol; here ``max_range``):

1. **Normalize** (Min-Max, paper formula (1), ``min = 0``)::

       scale_stamp_i = floor( (t_i - t_min) / (t_max - t_min) * max_range )

   Min-Max is the only normalization preserving record order and relative
   spacing, which the paper requires ("so that the data is dependent on the
   time series").

2. **Sample** (systematic, per scale-stamp bucket): compression multiplies
   the per-second arrival rate by ``multiple = T / max_range``; sampling
   divides it back. Each bucket keeps ``len(bucket) / multiple`` records,
   chosen every-``multiple``-th ("setting a second as the distance"), so the
   simulated per-second rate matches the *original* per-second rate and
   Tables 1-3 volatility statistics are preserved.

   .. note:: the paper's pseudocode computes ``multiple = Len(B)/max``. With
      ``Len(B)`` = record count, the kept rate would be ``rate/avg_rate`` ≈ 1
      rec/s — contradicting Tables 1-3 where the simulated average equals the
      original per-second average (~25/s for SogouQ). ``Len(B)`` must denote
      the stream's *time length* (the tables' note: "original time range of
      stream data set is 86400s"), i.e. ``multiple = T / max`` — the
      "normalization multiple" of §3.2. We implement that reading; the
      pseudocode-literal reading is available as ``multiple_mode='records'``
      for comparison.

Implementations
---------------
- :func:`nsa_paper` — faithful per-record Python loop, the paper-written
  algorithm (the §Perf baseline; O(n) interpreted).
- :func:`nsa` — vectorized numpy (beyond-paper; same output bit-for-bit).
- ``repro.kernels.ops.stream_sample`` — Pallas TPU kernel of the fused
  bucket+mask hot loop (validated against :func:`nsa` outputs).
"""

from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.streamsim.preprocess import Stream


def scale_stamps(t: np.ndarray, max_range: int) -> np.ndarray:
    """Min-Max normalize timestamps into integer buckets [0, max_range).

    Paper formula (1) with min=0, floored to the containing simulated second.
    """
    t = np.asarray(t, dtype=np.float64)
    if len(t) == 0:
        return np.zeros(0, dtype=np.int64)
    t_min, t_max = float(t[0]), float(t[-1])
    span = t_max - t_min
    if span <= 0.0:
        return np.zeros(len(t), dtype=np.int64)
    ss = np.floor((t - t_min) / span * max_range).astype(np.int64)
    # the record at t_max lands exactly on max_range -> clamp into last bucket
    np.clip(ss, 0, max_range - 1, out=ss)
    return ss


def _multiple(stream_len_records: int, time_range_s: float, max_range: int,
              mode: str) -> float:
    if mode == "time":       # the reading consistent with Tables 1-3
        return max(time_range_s / max_range, 1.0)
    elif mode == "records":  # pseudocode-literal reading, kept for comparison
        return max(stream_len_records / max_range, 1.0)
    raise ValueError(f"multiple_mode must be 'time'|'records', got {mode!r}")


def systematic_keep_mask(ss: np.ndarray, max_range: int, multiple: float,
                         *, keep: str = "systematic") -> np.ndarray:
    """Per-record boolean keep mask implementing the per-bucket sampling.

    ``ss`` must be non-decreasing (it is, since Min-Max is monotone and the
    stream is chronological). Within bucket ``b`` with ``c`` records, keep
    ``k = round(c / multiple)`` records (>=1 if the bucket is non-empty):

    - ``keep='systematic'`` — Bresenham-even selection: record with in-bucket
      rank ``r`` survives iff ``(r*k) % c < k``; exactly ``k`` survive, evenly
      spaced (the paper text's systematic sampling).
    - ``keep='first'``      — keep ranks ``< k`` (the paper pseudocode's
      ``if i > rs then remove`` reading).
    """
    n = len(ss)
    if n == 0:
        return np.zeros(0, dtype=bool)
    counts = np.bincount(ss, minlength=max_range).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(n, dtype=np.int64) - starts[ss]
    c = counts[ss]
    k = np.rint(c / multiple).astype(np.int64)
    k = np.clip(k, 1, None)  # non-empty buckets keep at least one record
    if keep == "systematic":
        return (rank * k) % np.maximum(c, 1) < k
    elif keep == "first":
        return rank < k
    raise ValueError(f"keep must be 'systematic'|'first', got {keep!r}")


def nsa(stream: Stream, max_range: int, *, keep: str = "systematic",
        multiple_mode: str = "time") -> Stream:
    """Vectorized NSA (Algorithm 1): normalize + sample -> simulated stream Ds.

    Returns a new :class:`Stream` whose ``scale_stamp`` is filled and whose
    records are the systematic sample; per-second volatility statistics match
    the original stream's (paper §5.2).
    """
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    ss = scale_stamps(stream.t, max_range)
    m = _multiple(len(stream), stream.time_range, max_range, multiple_mode)
    mask = systematic_keep_mask(ss, max_range, m, keep=keep)
    return Stream(
        name=stream.name,
        t=stream.t[mask],
        payload={k: v[mask] for k, v in stream.payload.items()},
        scale_stamp=ss[mask],
    )


def nsa_paper(stream: Stream, max_range: int, *, keep: str = "systematic",
              multiple_mode: str = "time") -> Stream:
    """Paper-faithful per-record NSA: literal loops mirroring Algorithm 1.

    Bit-identical output to :func:`nsa`; kept as the §Perf baseline and as
    executable documentation of the paper's pseudocode.
    """
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    n = len(stream)
    t = stream.t
    if n == 0:
        return Stream(stream.name, t[:0],
                      {k: v[:0] for k, v in stream.payload.items()},
                      np.zeros(0, dtype=np.int64))
    t_min, t_max = float(t[0]), float(t[-1])
    span = t_max - t_min
    # --- "Normalizing original stream data." (per-record loop) ---
    ss = np.empty(n, dtype=np.int64)
    for i in range(n):  # For s_i in B do
        if span <= 0.0:
            ss[i] = 0
        else:
            v = (t[i] - t_min) / span * max_range  # formula (1), min=0
            ss[i] = min(int(v), max_range - 1)
    # --- "Sampling normalized stream data." (per-bucket loop) ---
    m = _multiple(n, span, max_range, multiple_mode)
    keep_idx = []
    lo = 0
    for b in range(max_range):  # For i <- 0 to max do
        hi = lo
        while hi < n and ss[hi] == b:
            hi += 1
        c = hi - lo  # block = B[scale_stamp == i]
        if c > 0:
            k = max(int(round(c / m)), 1)  # rs = Len(block)/multiple
            for r in range(c):  # For s_i in block do
                if keep == "systematic":
                    if (r * k) % c < k:
                        keep_idx.append(lo + r)
                elif keep == "first":
                    if r < k:  # paper: "If i > rs then remove"
                        keep_idx.append(lo + r)
                else:
                    raise ValueError(f"bad keep {keep!r}")
        lo = hi
    idx = np.asarray(keep_idx, dtype=np.int64)
    return Stream(
        name=stream.name,
        t=t[idx],
        payload={k: v[idx] for k, v in stream.payload.items()},
        scale_stamp=ss[idx],
    )


def compression_factor(stream: Stream, max_range: int) -> float:
    """The task speedup the simulation buys: original range / simulated range.

    The paper's headline: one day into <=1 h  =>  >= 24x (§6).
    """
    return stream.time_range / float(max_range)


def expected_kept(stream: Stream, max_range: int) -> int:
    """Rough expected record count after NSA (for capacity planning)."""
    m = _multiple(len(stream), stream.time_range, max_range, "time")
    return int(math.ceil(len(stream) / m))
