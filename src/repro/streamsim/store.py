"""StreamStore — the framework's "database" (paper advantages (1)+(3)).

The paper persists both the original and the simulated stream so that
(1) the framework depends on nothing but a database, and (3) exceptions are
traceable and processed data is reusable ("repeated normalizing and sampling
operations are not performed").

Here: an on-disk column store. Each stream is a directory holding one
``columns.npz`` plus a ``manifest.json``; writes go through a temp file +
``os.replace`` so a crash mid-write never corrupts a stream (atomicity is
what makes checkpoint-restart of the *pipeline* safe, mirroring the training
checkpointing discipline in ``repro.training.checkpoint``).

The store also holds **sweep markers** (``put_marker`` / ``get_marker`` /
``list_markers`` / ``clear_markers``): small JSON completion records under
``<root>/_markers/<sweep_id>/`` that the resilience layer's
:class:`~repro.streamsim.resilience.SweepCheckpoint` uses to resume a
killed sweep from the last completed scenario. Marker writes use the same
temp-file + ``os.replace`` discipline, so a kill mid-write never yields a
half-marker; the ``_markers`` tree is invisible to the stream namespace
(``list()`` only reports directories carrying a stream manifest).

Marker namespaces nest (``<sweep_id>/queue``, ``<sweep_id>/leases``, …)
and three filesystem-atomic primitives turn them into the distributed
sweep service's arbitration substrate (:mod:`repro.streamsim.service`):

- ``put_marker(..., exclusive=True)`` — create-if-absent (``os.link``
  onto the temp file): exactly ONE of N concurrent writers wins, the
  store-arbitrated "who publishes the work queue" election;
- ``claim_marker`` — ``os.replace`` of one marker file into another
  namespace: exactly ONE of N concurrent claimants moves
  ``queue/<item>`` to ``leases/<item>`` (the loser's rename finds no
  source), which is what makes a work-item lease a single atomic step;
- ``clear_markers`` — rename-then-delete: the namespace directory is
  atomically renamed to an invisible ``.trash-*`` sibling BEFORE any
  file is unlinked, so a concurrent host observes the old sweep either
  fully present or fully gone — never a half-cleared namespace that
  looks like a fresh sweep with most scenarios "done".
"""

from __future__ import annotations

import json
import os
import tempfile
import time
import uuid
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.streamsim.preprocess import Stream

_MANIFEST = "manifest.json"
_COLUMNS = "columns.npz"


class StreamStore:
    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ keys
    def _dir(self, key: str) -> Path:
        if "/" in key or key.startswith("."):
            raise ValueError(f"bad stream key {key!r}")
        return self.root / key

    def list(self) -> List[str]:
        return sorted(p.name for p in self.root.iterdir()
                      if (p / _MANIFEST).exists())

    def exists(self, key: str) -> bool:
        return (self._dir(key) / _MANIFEST).exists()

    # ------------------------------------------------------------------- put
    def put(self, key: str, stream: Stream,
            extra_meta: Optional[Dict] = None) -> None:
        d = self._dir(key)
        d.mkdir(parents=True, exist_ok=True)
        arrays: Dict[str, np.ndarray] = {"__t__": stream.t}
        if stream.scale_stamp is not None:
            arrays["__scale_stamp__"] = stream.scale_stamp
        for k, v in stream.payload.items():
            arrays[f"c:{k}"] = v
        # atomic write: tmp file in the same dir, then rename
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, d / _COLUMNS)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        manifest = {
            "name": stream.name,
            "rows": len(stream),
            "has_scale_stamp": stream.scale_stamp is not None,
            "time_range_s": stream.time_range,
            "nbytes": stream.nbytes(),
            "written_at": time.time(),
            "extra": extra_meta or {},
        }
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(manifest, f, indent=2)
            os.replace(tmp, d / _MANIFEST)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def put_many(self, items: Dict[str, Stream],
                 extra_meta: Optional[Dict[str, Dict]] = None) -> None:
        """Persist several streams in one pass (the sweep engine's
        ``materialize()`` uses this so a whole sweep's store round-trip is
        one call, not one per scenario). ``extra_meta`` optionally maps
        each key to its manifest extras. Atomicity stays per stream —
        a crash mid-batch leaves every already-written stream intact."""
        extra_meta = extra_meta or {}
        for key, stream in items.items():
            self.put(key, stream, extra_meta.get(key))

    # ----------------------------------------------------------- chunk put
    # PR 7: the chunked pipeline persists one time chunk at a time so a
    # multi-day run never holds (or rewrites) the whole stream on host.
    # Each chunk is its own atomically-renamed ``columns.00042.npz``;
    # the manifest (written LAST, by ``finalize_chunks``) is what makes
    # the key visible to ``exists()``/``get()``, so a kill mid-run leaves
    # a resumable pile of chunk files, never a half-stream. ``get`` then
    # concatenates transparently — callers can't tell a chunked stream
    # from a monolithic one.

    @staticmethod
    def _chunk_file(d: Path, chunk_idx: int) -> Path:
        if chunk_idx < 0:
            raise ValueError(f"bad chunk index {chunk_idx}")
        return d / f"columns.{chunk_idx:05d}.npz"

    def append_chunk(self, key: str, chunk_idx: int, stream: Stream,
                     overwrite: bool = False) -> bool:
        """Persist one time chunk of ``key`` (atomic per chunk).

        Returns False (and writes nothing) when the chunk file already
        exists and ``overwrite`` is unset — the chunk-granular resume
        path: a restarted run calls ``append_chunk`` for every chunk and
        only the missing tail actually hits the disk.
        """
        d = self._dir(key)
        target = self._chunk_file(d, chunk_idx)
        if target.exists() and not overwrite:
            return False
        d.mkdir(parents=True, exist_ok=True)
        arrays: Dict[str, np.ndarray] = {"__t__": stream.t}
        if stream.scale_stamp is not None:
            arrays["__scale_stamp__"] = stream.scale_stamp
        for k, v in stream.payload.items():
            arrays[f"c:{k}"] = v
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, target)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return True

    def has_chunk(self, key: str, chunk_idx: int) -> bool:
        return self._chunk_file(self._dir(key), chunk_idx).exists()

    def list_chunks(self, key: str) -> List[int]:
        d = self._dir(key)
        if not d.exists():
            return []
        out = []
        for p in d.iterdir():
            name = p.name
            if (name.startswith("columns.") and name.endswith(".npz")
                    and name != _COLUMNS):
                mid = name[len("columns."):-len(".npz")]
                if mid.isdigit():
                    out.append(int(mid))
        return sorted(out)

    def finalize_chunks(self, key: str, *, name: str, n_chunks: int,
                        extra_meta: Optional[Dict] = None,
                        stats: Optional[Dict] = None) -> None:
        """Write the manifest that turns ``n_chunks`` appended chunk files
        into one visible stream. Verifies the chunk set is complete
        (missing chunk ⇒ ValueError, key stays invisible).

        ``stats`` (keys ``rows``, ``nbytes``, ``time_range_s``) lets a
        writer that held every chunk in memory skip the re-read this
        method otherwise does to assemble the manifest — the chunked
        sweep runner's hot path. Without it, the chunk files are read
        back (the standalone / recovery path).
        """
        d = self._dir(key)
        have = set(self.list_chunks(key))
        missing = [i for i in range(n_chunks) if i not in have]
        if missing:
            raise ValueError(
                f"cannot finalize {key!r}: missing chunk(s) {missing[:8]}")
        if stats is not None:
            rows = int(stats["rows"])
            nbytes = int(stats["nbytes"])
            time_range_s = float(stats["time_range_s"])
        else:
            rows = 0
            nbytes = 0
            t_first = t_last = None
            for i in range(n_chunks):
                with np.load(self._chunk_file(d, i),
                             allow_pickle=False) as z:
                    t = z["__t__"]
                    rows += len(t)
                    nbytes += sum(int(z[k].nbytes) for k in z.files)
                    if len(t):
                        if t_first is None:
                            t_first = float(t[0])
                        t_last = float(t[-1])
            time_range_s = ((t_last - t_first)
                            if t_first is not None else 0.0)
        manifest = {
            "name": name,
            "rows": rows,
            "has_scale_stamp": True,
            "time_range_s": time_range_s,
            "nbytes": nbytes,
            "written_at": time.time(),
            "chunks": n_chunks,
            "extra": extra_meta or {},
        }
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(manifest, f, indent=2)
            os.replace(tmp, d / _MANIFEST)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # ------------------------------------------------------------------- get
    def get(self, key: str) -> Stream:
        d = self._dir(key)
        man = self.manifest(key)
        n_chunks = int(man.get("chunks", 0))
        if n_chunks:
            ts, sss, payloads = [], [], []
            for i in range(n_chunks):
                with np.load(self._chunk_file(d, i),
                             allow_pickle=False) as z:
                    ts.append(z["__t__"])
                    if "__scale_stamp__" in z.files:
                        sss.append(z["__scale_stamp__"])
                    payloads.append({k[2:]: z[k] for k in z.files
                                     if k.startswith("c:")})
            t = np.concatenate(ts) if ts else np.empty(0)
            ss = np.concatenate(sss) if len(sss) == n_chunks else None
            cols = payloads[0].keys() if payloads else ()
            payload = {c: np.concatenate([p[c] for p in payloads])
                       for c in cols}
            return Stream(name=man["name"], t=t, payload=payload,
                          scale_stamp=ss)
        with np.load(d / _COLUMNS, allow_pickle=False) as z:
            t = z["__t__"]
            ss = z["__scale_stamp__"] if "__scale_stamp__" in z.files else None
            payload = {k[2:]: z[k] for k in z.files if k.startswith("c:")}
        return Stream(name=man["name"], t=t, payload=payload, scale_stamp=ss)

    def manifest(self, key: str) -> Dict:
        with open(self._dir(key) / _MANIFEST) as f:
            return json.load(f)

    def delete(self, key: str) -> None:
        d = self._dir(key)
        targets = [d / _COLUMNS, d / _MANIFEST]
        if d.exists():
            targets += [self._chunk_file(d, i) for i in self.list_chunks(key)]
        for p in targets:
            if p.exists():
                p.unlink()
        if d.exists() and not any(d.iterdir()):
            d.rmdir()

    # --------------------------------------------------------------- markers
    def _marker_dir(self, sweep_id: str) -> Path:
        """Marker namespace directory. ``sweep_id`` may nest
        (``"<sweep>/queue"``): each ``/``-separated segment must be
        non-empty and not dot-prefixed (dot-prefixed names are reserved
        for :meth:`clear_markers`'s invisible trash directories)."""
        segments = str(sweep_id).split("/")
        if not sweep_id or any(not s or s.startswith(".") or s == ".."
                               for s in segments):
            raise ValueError(f"bad sweep id {sweep_id!r}")
        return self.root.joinpath("_markers", *segments)

    @staticmethod
    def _marker_file(d: Path, name: str) -> Path:
        if not name or "/" in name or name.startswith("."):
            raise ValueError(f"bad marker name {name!r}")
        return d / f"{name}.json"

    def put_marker(self, sweep_id: str, name: str, payload: Dict, *,
                   exclusive: bool = False) -> bool:
        """Atomically persist one sweep completion marker (crash-safe:
        temp file + ``os.replace``, the stream-write discipline).

        ``exclusive=True`` switches to create-if-absent semantics
        (``os.link`` of the temp file onto the target — atomic on POSIX):
        when the marker already exists, nothing is written and False is
        returned. Exactly one of N concurrent exclusive writers wins,
        which is how the sweep service elects its work-queue publisher
        without a coordinator. Returns True when this call wrote the
        marker."""
        d = self._marker_dir(sweep_id)
        d.mkdir(parents=True, exist_ok=True)
        target = self._marker_file(d, name)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                # dumps-then-write, not json.dump: the streaming dump
                # path bypasses the C encoder and is ~10x slower on the
                # sweep service's large count-row payloads
                f.write(json.dumps(payload))
            if exclusive:
                try:
                    os.link(tmp, target)
                except FileExistsError:
                    return False
            else:
                os.replace(tmp, target)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return True

    def get_marker(self, sweep_id: str, name: str) -> Dict:
        d = self._marker_dir(sweep_id)
        with open(self._marker_file(d, name)) as f:
            return json.load(f)

    def has_marker(self, sweep_id: str, name: str) -> bool:
        return self._marker_file(self._marker_dir(sweep_id), name).exists()

    def claim_marker(self, src_sweep_id: str, src_name: str,
                     dst_sweep_id: str, dst_name: str) -> bool:
        """Atomically MOVE a marker between namespaces (``os.replace``).

        The sweep service's lease primitive: renaming
        ``queue/<item>`` to ``leases/<item>`` both removes the item from
        the queue and records the claim in one filesystem-atomic step, so
        of N racing claimants exactly one succeeds — the others find the
        source gone and get False. The payload travels with the file;
        the winner typically rewrites it (e.g. with lease metadata)
        immediately after.
        """
        src = self._marker_file(self._marker_dir(src_sweep_id), src_name)
        d = self._marker_dir(dst_sweep_id)
        d.mkdir(parents=True, exist_ok=True)
        try:
            os.replace(src, self._marker_file(d, dst_name))
        except FileNotFoundError:
            return False
        return True

    def remove_marker(self, sweep_id: str, name: str) -> bool:
        """Delete one marker; False if it was already gone (losing this
        race is normal — e.g. a reaper removing a lease whose worker
        finished concurrently)."""
        try:
            self._marker_file(self._marker_dir(sweep_id), name).unlink()
        except FileNotFoundError:
            return False
        return True

    def marker_mtime(self, sweep_id: str, name: str) -> Optional[float]:
        """Last-modified wall time of a marker file, or None if missing
        (the reaper's fallback freshness signal for a lease claimed by a
        worker that died before writing its lease payload)."""
        try:
            return self._marker_file(self._marker_dir(sweep_id),
                                     name).stat().st_mtime
        except FileNotFoundError:
            return None

    def list_markers(self, sweep_id: str) -> List[str]:
        d = self._marker_dir(sweep_id)
        if not d.exists():
            return []
        return sorted(p.stem for p in d.iterdir()
                      if p.suffix == ".json")

    def clear_markers(self, sweep_id: str) -> None:
        """Remove the WHOLE ``_markers/<sweep_id>/`` namespace (including
        nested sub-namespaces) atomically: the directory is first renamed
        to an invisible dot-prefixed trash sibling (one ``os.rename``),
        then deleted. A concurrent host therefore observes the namespace
        either fully present or fully absent — never a half-cleared sweep
        whose surviving markers misread as "mostly fresh". Concurrent
        clears are safe: the losing rename finds the source gone and
        returns. A crash after the rename leaves only an invisible trash
        directory (``_marker_dir`` rejects dot-prefixed segments, and
        ``list_markers`` ignores non-``.json`` entries), swept by the
        next successful clear."""
        import shutil

        d = self._marker_dir(sweep_id)
        trash = d.parent / f".trash-{d.name}-{uuid.uuid4().hex[:8]}"
        try:
            os.rename(d, trash)
        except FileNotFoundError:
            pass
        else:
            shutil.rmtree(trash, ignore_errors=True)
        # opportunistic sweep of trash left by a crashed earlier clear
        if d.parent.exists():
            for p in d.parent.iterdir():
                if p.name.startswith(".trash-") and p.is_dir():
                    shutil.rmtree(p, ignore_errors=True)
