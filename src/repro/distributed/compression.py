"""Gradient compression for the DP all-reduce: int8 quantization with
error feedback (1-bit-Adam-family trick, int8 variant).

Where it sits: under pure jit+SPMD the gradient reduction is implicit, so
compression needs the explicit collective — we wrap the data-parallel
gradient exchange in ``shard_map`` and reduce quantized tensors. Error
feedback carries the quantization residual into the next step, which keeps
convergence (tested in tests/test_training.py on the 100M example).

Wire format per leaf: int8 payload + per-leaf f32 scale (amax / 127).
Reduction: psum of int32-upcast payloads (no overflow below 2^23 shards),
then dequantize by the max scale — a 4x wire-byte reduction vs f32.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis: str, ef: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: all-reduce-mean of g with int8 wire format.

    ef: error-feedback residual from the previous step (same shape as g).
    Returns (mean gradient, new residual)."""
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    gc = g.astype(jnp.float32) + ef
    q, scale = quantize(gc)
    sent = dequantize(q, scale)
    new_ef = gc - sent
    # shared scale: use the max over shards so the int32 sum is consistent
    smax = jax.lax.pmax(scale, axis)
    q_rescaled = jnp.clip(jnp.round(sent / smax), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q_rescaled, axis)
    return total.astype(jnp.float32) * smax / n, new_ef


def make_compressed_dp_grad(loss_fn, mesh: Mesh, axis: str = "data"):
    """Build grad_fn(params, batch, ef) -> (loss, grads, new_ef) where the
    per-shard gradients reduce over `axis` in int8."""

    def local_grad(params, batch, ef):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        red, new_e = [], []
        for g, e in zip(flat_g, flat_e):
            r, ne = compressed_psum(g, axis, e)
            red.append(r.astype(g.dtype))
            new_e.append(ne)
        return (loss, jax.tree.unflatten(tdef, red),
                jax.tree.unflatten(tdef, new_e))

    pspec = P()              # params replicated across DP
    bspec = P(axis, None)    # batch sharded
    in_specs = (pspec, {"inputs": bspec, "labels": bspec}, pspec)
    out_specs = (P(), pspec, pspec)
    fn = shard_map(local_grad, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def ef_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
