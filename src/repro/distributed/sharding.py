"""Sharding rules: params / batch / cache PartitionSpec trees per policy.

Mesh axes: ``pod`` (cross-pod DP), ``data`` (DP + FSDP), ``model`` (TP + EP).

Policies
--------
- ``tp``      : tensor-parallel params over 'model'; replicated over data
                (small models — no per-layer FSDP gathers).
- ``fsdp_tp`` : 'tp' + parameters and optimizer state additionally sharded
                over 'data' (ZeRO-3); XLA inserts per-layer all-gather /
                reduce-scatter inside the layer scan, which overlaps with
                compute. Required for >=100B models to fit HBM.

Rules are *name-based*: each param leaf resolves by its dict key and rank.
Leaves under ``runs`` carry a leading stacked-layer axis (never sharded).
Axes that don't divide the mesh axis size (e.g. kv_heads=8 on model=16)
fall back to replication — the standard GQA-TP compromise.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig

DP_AXES = ("pod", "data")  # batch shards over both


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _dp(mesh: Mesh):
    return tuple(a for a in DP_AXES if a in mesh.axis_names) or None


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return axis is not None and dim % _axis_size(mesh, axis) == 0


def _maybe(dim: int, mesh: Mesh, axis):
    return axis if _fits(dim, mesh, axis) else None


# --------------------------------------------------------------- rule table
def _param_spec(cfg: ModelConfig, mesh: Mesh, policy: str, name: str,
                shape: tuple) -> P:
    """Spec for an *unstacked* param leaf by name/rank."""
    fsdp = "data" if policy == "fsdp_tp" and "data" in mesh.axis_names else None
    m = "model"

    def f(dim):  # fsdp only if divisible
        return _maybe(dim, mesh, fsdp)

    def t(dim):  # tensor axis only if divisible
        return _maybe(dim, mesh, m)

    r = len(shape)
    if name == "embed":
        # vocab-parallel table; d stays unsharded — sharding d over 'data'
        # puts the FSDP axis on the lookup's gather dim and the unembed's
        # contraction, inducing (B,S,V)-sized all-reduces (measured in
        # EXPERIMENTS.md §Perf llama3 iteration 3)
        return P(t(shape[0]), None)
    if name == "lm_head":
        # vocab-sharded head: logits shard over 'model'; softmax reductions
        # cross shards as tiny (B,C) collectives instead of logits-sized
        return P(None, t(shape[1]))
    if name in ("wq",):
        return P(f(shape[0]), t(shape[1]), None)
    if name in ("wk", "wv"):
        return P(f(shape[0]), t(shape[1]), None)
    if name == "wo" and r == 3:
        return P(t(shape[0]), None, f(shape[2]))
    if name in ("gate", "up") and r == 2:       # swiglu
        return P(f(shape[0]), t(shape[1]))
    if name == "down" and r == 2:
        return P(t(shape[0]), f(shape[1]))
    if name in ("gate", "up") and r == 3:       # experts (E, d, f)
        return P(t(shape[0]), f(shape[1]), None)
    if name == "down" and r == 3:               # experts (E, f, d)
        return P(t(shape[0]), None, f(shape[2]))
    if name == "router":
        return P(None, None)
    # --- MLA ---
    if name == "w_dq":
        return P(f(shape[0]), None)
    if name == "w_uq":
        return P(None, t(shape[1]), None)
    if name == "w_dkv":
        return P(f(shape[0]), None)
    if name == "w_ukv":
        return P(None, t(shape[1]), None)
    # --- RG-LRU ---
    if name in ("in_gelu", "in_rnn"):
        return P(f(shape[0]), t(shape[1]))
    if name == "out":
        return P(t(shape[0]), f(shape[1]))
    if name == "conv_w":
        return P(None, t(shape[1]))
    if name in ("conv_b", "lambda"):
        return P(t(shape[0]))
    if name in ("gate_a", "gate_x"):
        return P(None, None, None)
    # --- RWKV ---
    if name in ("wr", "wk_r", "wv_r", "wg", "cm_r"):
        return P(f(shape[0]), t(shape[1]))
    if name == "cm_k":
        return P(f(shape[0]), t(shape[1]))
    if name == "cm_v":
        return P(t(shape[0]), f(shape[1]))
    if name == "w_lora_a":
        return P(f(shape[0]), None)
    if name == "w_lora_b":
        return P(None, f(shape[1]))
    if name == "proj":  # mtp
        return P(f(shape[0]), None)
    if r == 2 and name in ("wo",):              # rwkv wo (d, d)
        return P(t(shape[0]), f(shape[1]))
    # norms, biases, mus, u, small tables -> replicated
    return P(*([None] * r))


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                 policy: str = "fsdp_tp") -> Any:
    """PartitionSpec tree matching a params (shape) pytree."""

    def walk(path, leaf):
        keys = [getattr(p_, "key", getattr(p_, "idx", None))
                for p_ in path]
        name = keys[-1]
        stacked = "runs" in keys
        shape = tuple(leaf.shape)
        # rwkv wk/wv collide with attention names but are rank-2
        if name in ("wk", "wv") and len(shape) - int(stacked) == 2:
            name = name + "_r"
        core = shape[1:] if stacked else shape
        spec = _param_spec(cfg, mesh, policy, name, core)
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(walk, params_shape)


# ----------------------------------------------------------------- batches
def batch_pspec(mesh: Mesh) -> Dict[str, P]:
    dp = _dp(mesh)
    return {
        "tokens": P(dp, None),
        "embeds": P(dp, None, None),
        "labels": P(dp, None),
        "mask": P(dp, None),
    }


# ------------------------------------------------------------------- cache
def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shape: Any,
                 *, shard_seq: bool = True) -> Any:
    """Decode-cache specs: batch over DP; the long seq axis over 'model'
    (distributed flash-decode); recurrent state heads over 'model'."""
    dp_all = _dp(mesh)
    m = "model"

    def walk(path, leaf):
        name = getattr(path[-1], "key", None)
        shape = tuple(leaf.shape)
        # batch axis shards over DP only when divisible (long_500k has B=1)
        bdim = shape[0] if name == "pos" else (shape[1] if len(shape) > 1
                                               else 1)
        dp = dp_all if (dp_all and bdim % _axis_size(mesh, dp_all) == 0) \
            else None
        if name in ("k", "v"):      # (R, B, S, Kh, Dh)
            seq = _maybe(shape[2], mesh, m) if shard_seq else None
            return P(None, dp, seq, None, None)
        if name in ("ckv", "kr"):   # (R, B, S, X)
            seq = _maybe(shape[2], mesh, m) if shard_seq else None
            return P(None, dp, seq, None)
        if name == "h":             # rglru (R, B, W)
            return P(None, dp, _maybe(shape[2], mesh, m))
        if name == "conv":          # (R, B, K-1, W)
            return P(None, dp, None, _maybe(shape[3], mesh, m))
        if name == "s":             # rwkv (R, B, nh, hd, hd)
            return P(None, dp, _maybe(shape[2], mesh, m), None, None)
        if name in ("tm_prev", "cm_prev"):
            return P(None, dp, None)
        if name == "pos":
            return P(dp)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(walk, cache_shape)


# ------------------------------------------------------- activation rules
def activation_rules(mesh: Mesh, *, shard_seq: bool = False) -> Dict:
    """Logical-axis rules for repro.distributed.api.constrain."""
    dp = _dp(mesh)
    return {
        "batch": dp,
        "seq": "model" if shard_seq else None,
        "embed": None,
        "heads": "model",
        "kv": None,
        "ff": "model",
        "expert": "model",
        "cap": None,
        "vocab": "model",
        "kvseq": "model",
    }


RULESETS = {
    "tp": dict(policy="tp"),
    "fsdp_tp": dict(policy="fsdp_tp"),
}
