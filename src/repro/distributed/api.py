"""Logical-axis sharding constraints (the MaxText-style indirection, flaxless).

Model code annotates activations with *logical* axis names::

    x = constrain(x, "batch", "seq", "embed")

Outside a mesh context this is the identity, so models stay runnable on a
laptop. Inside :func:`sharding_rules` the names map to mesh axes and the
call becomes ``jax.lax.with_sharding_constraint`` — which is how the §Perf
loop re-shards activations without touching model code.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "sharding_rules", default=None)


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, rules: Dict[str, Optional[object]]):
    """Activate logical->mesh axis rules, e.g.
    {'batch': ('pod', 'data'), 'embed': None, 'heads': 'model'}."""
    token = _RULES.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _RULES.reset(token)


def active_rules() -> Optional[Tuple[Mesh, Dict]]:
    return _RULES.get()


def process_topology() -> Tuple[int, int, int]:
    """``(host_index, n_hosts, n_local_devices)`` of THIS process.

    The sweep planner's default partition geometry
    (:func:`repro.streamsim.plan.plan_sweep`): in a single-process run
    this is ``(0, 1, local_device_count)``; under
    ``jax.distributed.initialize`` every host sees its own index within
    the job, so all hosts can build the SAME plan and each executes only
    its strided slice of the scenario grid.
    """
    import jax

    return jax.process_index(), jax.process_count(), \
        jax.local_device_count()


def constrain(x, *logical_axes: Optional[str]):
    """Annotate array x (rank == len(logical_axes)) with the active rules."""
    ctx = _RULES.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(logical_axes):
        return x  # shape changed under vmap/scan; skip rather than mis-pin
    spec = P(*[rules.get(a) if a is not None else None
               for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
