"""Distribution: mesh construction, logical-axis sharding rules, and the
constraint API the model code calls (no-op outside an active mesh context).
"""

from repro.distributed.api import (  # noqa: F401
    constrain,
    process_topology,
    sharding_rules,
)
from repro.distributed.sharding import (  # noqa: F401
    RULESETS,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
)
