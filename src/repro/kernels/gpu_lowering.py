"""Pallas GPU (Triton) lowerings of the scan/accumulate stream kernels.

The TPU kernels lean on two TPU-only guarantees: a SEQUENTIAL grid (later
grid steps observe earlier steps' writes to the same output block — the
histogram / Gram accumulators whose index maps ignore the tile index, and
the SMEM scan carries) and ``pltpu`` scratch memory. Triton launches grid
steps CONCURRENTLY, so compiling those kernels on GPU would race. These
lowerings restructure each op as a ROW-PARALLEL kernel instead: the grid
ranges over stream rows only, every instance owns one whole row, and all
cross-tile state collapses into in-kernel ``cumsum`` / ``fori_loop`` state
that never leaves the instance.

Contracts match the TPU kernels':

- compact / trend scan: int32 prefix sums, bit-exact (integer arithmetic
  has no reassociation error, so a row-wise ``cumsum`` equals the TPU
  tile-walk exactly).
- metrics: bit-exact int32 histograms; f32 moments folded with the SAME
  per-bucket-block Kahan order as the TPU kernel, so the chunked-carry
  composition keeps its ~1e-5 agreement.
- pair stats: one whole-axis f32 matmul per instance (vs. the TPU
  tile-accumulated MXU walk) — inside the documented 1e-3 tolerance.

``stream_sample`` needs no lowering: its grid steps are independent (each
reads and writes only its own record tile), so the TPU kernel compiles
unchanged on GPU and :mod:`repro.kernels.ops` dispatches it directly.

Off-GPU these kernels still run under ``interpret=True`` — that is how the
CPU test tier validates the lowering logic without the hardware
(``tests/test_gpu_lowering.py``); the compiled path is exercised by the
same tests when a CUDA/ROCm device is present.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_GPU_BACKENDS = ("gpu", "cuda", "rocm")


def _interp() -> bool:
    """interpret=True everywhere except a real GPU backend."""
    return jax.default_backend() not in _GPU_BACKENDS


# ----------------------------------------------------------------- compact
def _compact_kernel(m_ref, pos_ref, tot_ref):
    m = m_ref[0]                                  # (N,) int32 row
    inc = jnp.cumsum(m, dtype=jnp.int32)
    pos_ref[0] = inc - m                          # exclusive prefix sum
    tot_ref[0, 0] = inc[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact_positions_batched_gpu(mask: jnp.ndarray, *,
                                  interpret: bool = None):
    """Row-parallel batched compaction scan: (R, N) 0/1 mask ->
    (pos int32 (R, N) exclusive prefix sums, totals int32 (R, 1)) — the
    :func:`repro.kernels.compact.compact_positions_batched_pallas`
    contract, shapes included."""
    if interpret is None:
        interpret = _interp()
    R, n = mask.shape
    pos, tot = pl.pallas_call(
        _compact_kernel,
        grid=(R,),
        in_specs=[pl.BlockSpec((1, n), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((1, n), lambda r: (r, 0)),
            pl.BlockSpec((1, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, n), jnp.int32),
            jax.ShapeDtypeStruct((R, 1), jnp.int32),
        ],
        interpret=interpret,
    )(mask.astype(jnp.int32))
    return pos, tot


@functools.partial(jax.jit, static_argnames=("interpret",))
def compact_positions_gpu(mask: jnp.ndarray, *, interpret: bool = None):
    """Single-stream form: (n,) mask -> (pos (n,), total (1,)) — the
    :func:`repro.kernels.compact.compact_positions_pallas` contract."""
    pos, tot = compact_positions_batched_gpu(mask[None, :],
                                             interpret=interpret)
    return pos[0], tot[0]


# ----------------------------------------------------------------- metrics
def _hist_blocks(ss, hist_ref, *, buckets: int, bucket_block: int):
    """Bucket-blocked one-hot histogram of one row's stamps; padding ids
    (>= buckets) match no bucket and count nowhere — same trick as the
    TPU kernel, minus the data-adaptive lo/hi clip (one instance owns the
    whole row, so every block must be written anyway)."""

    def body(blk, carry):
        base = blk * bucket_block
        ids = base + jax.lax.broadcasted_iota(
            jnp.int32, (1, bucket_block), 1)
        one = (ss[:, None] == ids).astype(jnp.int32)  # (N, bucket_block)
        hist_ref[0, pl.ds(base, bucket_block)] = jnp.sum(one, axis=0)
        return carry

    jax.lax.fori_loop(0, buckets // bucket_block, body, 0)


def _kahan_fold(hist_ref, init, *, buckets: int, bucket_block: int):
    """The TPU kernels' exact per-block Kahan recurrence over the finished
    histogram — same block order, same compensated-add formula."""

    def kahan(blk, carry):
        s1, c1, s2, c2 = carry
        q = hist_ref[0, pl.ds(blk * bucket_block, bucket_block)] \
            .astype(jnp.float32)
        y1 = jnp.sum(q) - c1
        t1 = s1 + y1
        y2 = jnp.sum(q * q) - c2
        t2 = s2 + y2
        return t1, (t1 - s1) - y1, t2, (t2 - s2) - y2

    return jax.lax.fori_loop(0, buckets // bucket_block, kahan, init)


def _metrics_kernel(ss_ref, hist_ref, mom_ref, *, buckets: int,
                    bucket_block: int):
    ss = ss_ref[0]                                # (N,) int32 row
    _hist_blocks(ss, hist_ref, buckets=buckets, bucket_block=bucket_block)
    zero = jnp.float32(0.0)
    s1, _, s2, _ = _kahan_fold(hist_ref, (zero, zero, zero, zero),
                               buckets=buckets, bucket_block=bucket_block)
    mom_ref[0, 0] = s1
    mom_ref[0, 1] = s2


def _metrics_carry_kernel(ss_ref, mcar_ref, hist_ref, mom_ref, *,
                          buckets: int, bucket_block: int):
    ss = ss_ref[0]
    _hist_blocks(ss, hist_ref, buckets=buckets, bucket_block=bucket_block)
    s1, c1, s2, c2 = _kahan_fold(
        hist_ref,
        (mcar_ref[0, 0], mcar_ref[0, 1], mcar_ref[0, 2], mcar_ref[0, 3]),
        buckets=buckets, bucket_block=bucket_block)
    mom_ref[0, 0] = s1
    mom_ref[0, 1] = c1
    mom_ref[0, 2] = s2
    mom_ref[0, 3] = c2


@functools.partial(jax.jit,
                   static_argnames=("buckets", "bucket_block", "interpret"))
def stream_metrics_gpu(ss: jnp.ndarray, buckets: int, *,
                       bucket_block: int = 512, interpret: bool = None):
    """Row-parallel fused metrics: (S, N) stamps -> (hist int32
    (S, buckets), moments f32 (S, 2)) — the
    :func:`repro.kernels.metrics_fused.stream_metrics_pallas` contract."""
    if interpret is None:
        interpret = _interp()
    assert buckets % bucket_block == 0
    S, n = ss.shape
    return pl.pallas_call(
        functools.partial(_metrics_kernel, buckets=buckets,
                          bucket_block=bucket_block),
        grid=(S,),
        in_specs=[pl.BlockSpec((1, n), lambda s: (s, 0))],
        out_specs=[
            pl.BlockSpec((1, buckets), lambda s: (s, 0)),
            pl.BlockSpec((1, 2), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, buckets), jnp.int32),
            jax.ShapeDtypeStruct((S, 2), jnp.float32),
        ],
        interpret=interpret,
    )(ss.astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("buckets", "bucket_block", "interpret"))
def stream_metrics_carry_gpu(ss: jnp.ndarray, mcar: jnp.ndarray,
                             buckets: int, *, bucket_block: int = 512,
                             interpret: bool = None):
    """Carry form: (S, 4) Kahan state in, chunk-local hist + updated
    (S, 4) state out — the ``stream_metrics_carry_pallas`` contract."""
    if interpret is None:
        interpret = _interp()
    assert buckets % bucket_block == 0
    S, n = ss.shape
    return pl.pallas_call(
        functools.partial(_metrics_carry_kernel, buckets=buckets,
                          bucket_block=bucket_block),
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, n), lambda s: (s, 0)),
            pl.BlockSpec((1, 4), lambda s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, buckets), lambda s: (s, 0)),
            pl.BlockSpec((1, 4), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, buckets), jnp.int32),
            jax.ShapeDtypeStruct((S, 4), jnp.float32),
        ],
        interpret=interpret,
    )(ss.astype(jnp.int32), mcar.astype(jnp.float32))


# -------------------------------------------------------------- trend scan
def _scan_kernel(q_ref, psum_ref):
    psum_ref[0] = jnp.cumsum(q_ref[0].astype(jnp.int32), dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def trend_scan_gpu(q: jnp.ndarray, *, interpret: bool = None):
    """Row-parallel inclusive prefix sum: (S, N) int32 -> (S, N) int32."""
    if interpret is None:
        interpret = _interp()
    S, n = q.shape
    return pl.pallas_call(
        _scan_kernel,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, n), lambda s: (s, 0))],
        out_specs=pl.BlockSpec((1, n), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, n), jnp.int32),
        interpret=interpret,
    )(q.astype(jnp.int32))


def _scan_carry_kernel(init_ref, q_ref, psum_ref, tail_ref):
    inc = init_ref[0, 0] + jnp.cumsum(q_ref[0].astype(jnp.int32),
                                      dtype=jnp.int32)
    psum_ref[0] = inc
    tail_ref[0, 0] = inc[-1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def trend_scan_carry_gpu(q: jnp.ndarray, init: jnp.ndarray, *,
                         interpret: bool = None):
    """Carry form: per-row carry-in seeds the scan; returns
    (psum (S, N), tail (S,)) — the ``trend_scan_carry_pallas`` contract."""
    if interpret is None:
        interpret = _interp()
    S, n = q.shape
    psum, tail = pl.pallas_call(
        _scan_carry_kernel,
        grid=(S,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
            pl.BlockSpec((1, n), lambda s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda s: (s, 0)),
            pl.BlockSpec((1, 1), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, n), jnp.int32),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
        ],
        interpret=interpret,
    )(init.reshape(S, 1).astype(jnp.int32), q.astype(jnp.int32))
    return psum, tail.reshape(S)


# -------------------------------------------------------------- pair stats
def _pair_kernel(x_ref, sums_ref, gram_ref):
    x = x_ref[...]                                # (S, K) f32
    sums_ref[...] = jnp.sum(x, axis=1, keepdims=True)
    gram_ref[...] = jnp.dot(x, x.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_stats_gpu(x: jnp.ndarray, *, interpret: bool = None):
    """One whole-axis Gram matmul: (S, K) f32 -> (sums (S, 1),
    gram (S, S)) — the ``pair_stats_pallas`` contract."""
    if interpret is None:
        interpret = _interp()
    S, k = x.shape
    return pl.pallas_call(
        _pair_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((S, k), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((S, 1), lambda i: (0, 0)),
            pl.BlockSpec((S, S), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, S), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32))


__all__ = [
    "compact_positions_batched_gpu", "compact_positions_gpu",
    "pair_stats_gpu", "stream_metrics_carry_gpu", "stream_metrics_gpu",
    "trend_scan_carry_gpu", "trend_scan_gpu",
]
