"""Pallas TPU kernel: scale-stamp histogram via the one-hot-matmul idiom.

TPUs have no fast scatter-add; the native way to histogram is to turn each
tile of bucket ids into a one-hot matrix and let the MXU sum it:

    partial[b] = sum_i onehot(ss_i)[b]   ==   ones(1, T) @ onehot(T, B)

The grid walks record tiles sequentially (TPU grid order), accumulating the
per-tile partial histogram into the single output block — the standard
Pallas reduction pattern (initialize at step 0, accumulate after).

Bucket axis is padded to a LANE multiple by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE


def _kernel(ss_ref, hist_ref, *, buckets: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    ss = ss_ref[...].reshape(TILE)                       # (TILE,) int32
    onehot = (ss[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (TILE, buckets), 1)).astype(jnp.float32)
    partial = jnp.sum(onehot, axis=0, dtype=jnp.float32)  # MXU-sum per tile
    hist_ref[...] += partial.reshape(1, buckets)


@functools.partial(jax.jit, static_argnames=("buckets", "interpret"))
def bucket_hist_pallas(ss: jnp.ndarray, buckets: int, *,
                       interpret: bool = False) -> jnp.ndarray:
    """ss: (n,) int32 scale stamps, n % TILE == 0, padded entries must carry
    bucket id >= buckets (the wrapper pads with ``buckets`` and the one-hot
    simply never matches). Returns (buckets,) int32 counts."""
    n = ss.shape[0]
    assert n % TILE == 0, f"pad records to a multiple of {TILE}"
    assert buckets % LANE == 0, f"pad buckets to a multiple of {LANE}"
    rows = n // LANE
    ss2 = ss.reshape(rows, LANE)
    grid = (rows // SUBLANE,)
    hist = pl.pallas_call(
        functools.partial(_kernel, buckets=buckets),
        grid=grid,
        in_specs=[pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, buckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, buckets), jnp.float32),
        interpret=interpret,
    )(ss2)
    return hist.reshape(buckets).astype(jnp.int32)
