"""Pallas TPU kernel: fused NSA inner loop (normalize -> bucket -> keep mask).

One HBM pass over the timestamp column produces both the scale stamp and the
systematic-sampling keep mask. The per-bucket offset/size tables (starts,
counts; ``max_range`` <= 3600 entries, <= 14 KiB each) ride along in VMEM for
every tile, so the in-bucket rank needs no second pass and no host round-trip
— this is the kernel-level fusion of Algorithm 1's two loops.

Layout: the wrapper pads the record axis to a multiple of the tile and
reshapes to (rows, 128) so the lane dimension is hardware-native; each grid
step processes an (8, 128)-record tile from VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE  # records per grid step


def _kernel(t_ref, starts_ref, counts_ref, scalar_ref, ss_ref, keep_ref,
            *, max_range: int):
    i = pl.program_id(0)
    t = t_ref[...].astype(jnp.float32)          # (SUBLANE, LANE)
    t_min = scalar_ref[0]
    inv_span = scalar_ref[1]                     # 1/span, precomputed
    multiple = scalar_ref[2]

    # --- normalize: paper formula (1), floored to the simulated second ---
    ss = jnp.floor((t - t_min) * inv_span * max_range).astype(jnp.int32)
    ss = jnp.clip(ss, 0, max_range - 1)

    # --- in-bucket rank via VMEM table gather ---
    starts = starts_ref[...]                     # (max_range,) int32
    counts = counts_ref[...]
    start = jnp.take(starts, ss, axis=0)
    c = jnp.take(counts, ss, axis=0)

    base = i * TILE
    row = jax.lax.broadcasted_iota(jnp.int32, (SUBLANE, LANE), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (SUBLANE, LANE), 1)
    gidx = base + row * LANE + col               # global record index
    rank = gidx - start

    # --- systematic keep: k of c survive, Bresenham-even ---
    k = jnp.clip(jnp.rint(c.astype(jnp.float32) / multiple), 1, None)
    k = k.astype(jnp.int32)
    keep = (rank * k) % jnp.maximum(c, 1) < k

    ss_ref[...] = ss
    keep_ref[...] = keep.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_range", "interpret"))
def stream_sample_pallas(t: jnp.ndarray, starts: jnp.ndarray,
                         counts: jnp.ndarray, t_min: jnp.ndarray,
                         span: jnp.ndarray, multiple: jnp.ndarray,
                         max_range: int, *, interpret: bool = False):
    """t: (n,) float32 sorted timestamps (pre-padded to TILE multiple with
    +inf -> clipped to last bucket, mask discarded by wrapper).
    Returns (scale_stamp int32 (n,), keep int32 (n,))."""
    n = t.shape[0]
    assert n % TILE == 0, f"pad records to a multiple of {TILE}"
    rows = n // LANE
    t2 = t.reshape(rows, LANE)
    scalars = jnp.stack([
        t_min.astype(jnp.float32),
        (1.0 / span).astype(jnp.float32),
        multiple.astype(jnp.float32),
    ])
    grid = (rows // SUBLANE,)
    ss, keep = pl.pallas_call(
        functools.partial(_kernel, max_range=max_range),
        grid=grid,
        in_specs=[
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),   # timestamps
            pl.BlockSpec((max_range,), lambda i: (0,)),        # starts (whole)
            pl.BlockSpec((max_range,), lambda i: (0,)),        # counts (whole)
            pl.BlockSpec((3,), lambda i: (0,)),                # scalars
        ],
        out_specs=[
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        ],
        interpret=interpret,
    )(t2, starts, counts, scalars)
    return ss.reshape(n), keep.reshape(n)
