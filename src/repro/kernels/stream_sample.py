"""Pallas TPU kernel: fused, batched NSA inner loop (normalize -> bucket ->
keep mask) over ``(S, N)`` stacked device streams.

One ``pallas_call`` with a 2-D grid ``(stream, record-tile)`` replaces S
sequential dispatches: grid step ``(s, i)`` normalizes an (8, 128)-record
tile of stream ``s`` while that stream's per-bucket tables (starts, counts,
per-bucket keep budget ``k``; ``max_range`` <= 3600 entries, <= 14 KiB each)
and scalars (t_min, 1/span, n_buckets) ride along in VMEM. The
single-stream path is just S == 1.

Range-padded batching: each row carries its OWN bucket count ``n_buckets``
in its scalar triple, so one dispatch can mix rows simulated at different
``max_range`` values — the whole (dataset × max_range) sweep of the paper's
Tables 1-3 collapses to a single kernel launch. The table axis is padded to
the sweep's maximum bucket count; tail buckets past a row's ``n_buckets``
never influence that row (the normalize clamps to ``n_buckets - 1`` and the
wrapper pads tails with ``starts = n``, ``counts = 0``, zero keep budget).
``n_buckets`` is shipped as float32, which represents every admissible
bucket count exactly (``MAX_RANGE_LIMIT = 2**20 < 2**24``), and the f32
normalize multiply is bit-identical to the static-``max_range`` form the
per-range dispatch used.

Exactness: the float32 normalize can land a record one bucket off the
float64 host answer near an edge, so the kernel *snaps*: the wrapper ships
per-bucket ``starts``/``counts`` tables computed with the host's exact
float64 formula, and the kernel corrects its f32 bucket guess by +-1 so that
``starts[b] <= gidx < starts[b] + counts[b]`` — because the stream is
sorted, the tables fully determine the true bucket, and the f32 guess is
provably within one bucket of it for ``max_range < 2**20``. Result: the
kernel's scale stamps and keep mask are bit-identical to the numpy NSA, not
just allclose.

The per-bucket keep budget ``k = clip(round(count / multiple), 1)`` is also
precomputed host-side in float64 (an O(max_range) table), removing both the
per-record division and any f32 rounding drift from the kernel.

Domain: the keep rule's ``rank * k`` product is int32 (the TPU-native
width), exact only while ``(count - 1) * k < 2**31`` per bucket; the ops
wrapper raises :class:`repro.kernels.ops.KeepRuleOverflow` outside that
domain and ``nsa(backend="pallas")`` falls back to numpy.

Layout: the wrapper pads the record axis to a multiple of the tile and
reshapes to (S, rows, 128) so the lane dimension is hardware-native.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tuning import DEFAULT_CONFIG, TileConfig

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE  # records per grid step with the default TileConfig

# the +-1 snap correction is only guaranteed while the f32 normalize error
# stays under one bucket: ~4 * max_range * 2^-24 < 1
MAX_RANGE_LIMIT = 1 << 20


def _kernel(t_ref, starts_ref, counts_ref, k_ref, scalar_ref, ss_ref,
            keep_ref, *, max_range: int, sublane: int):
    del max_range  # table width only; each row carries its own bucket count
    tile = sublane * LANE
    i = pl.program_id(1)
    t = t_ref[0].astype(jnp.float32)             # (sublane, LANE)
    t_min = scalar_ref[0, 0]
    inv_span = scalar_ref[0, 1]                  # 1/span, precomputed
    nb_f = scalar_ref[0, 2]                      # this row's bucket count
    nb = nb_f.astype(jnp.int32)                  # f32-exact below 2**24
    starts = starts_ref[0]                       # (max_range,) int32
    counts = counts_ref[0]
    ktab = k_ref[0]

    # --- normalize: paper formula (1), floored to the simulated second ---
    g = jnp.floor((t - t_min) * inv_span * nb_f).astype(jnp.int32)
    g = jnp.clip(g, 0, nb - 1)

    base = i * tile
    row = jax.lax.broadcasted_iota(jnp.int32, (sublane, LANE), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (sublane, LANE), 1)
    gidx = base + row * LANE + col               # per-stream record index

    # --- snap the f32 guess to the bucket that actually contains gidx ---
    s_g = jnp.take(starts, g, axis=0)
    c_g = jnp.take(counts, g, axis=0)
    g = g + (gidx >= s_g + c_g).astype(jnp.int32) \
          - (gidx < s_g).astype(jnp.int32)
    ss = jnp.clip(g, 0, nb - 1)

    # --- systematic keep: k of c survive, Bresenham-even ---
    start = jnp.take(starts, ss, axis=0)
    c = jnp.take(counts, ss, axis=0)
    k = jnp.take(ktab, ss, axis=0)
    rank = gidx - start
    keep = (rank * k) % jnp.maximum(c, 1) < k

    ss_ref[0] = ss
    keep_ref[0] = keep.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("max_range", "interpret", "config"))
def stream_sample_pallas(t: jnp.ndarray, starts: jnp.ndarray,
                         counts: jnp.ndarray, ktab: jnp.ndarray,
                         scalars: jnp.ndarray, max_range: int, *,
                         interpret: bool = False,
                         config: Optional[TileConfig] = None):
    """Batched fused NSA inner loop (range-padded rows).

    t       : (S, N) float32 per-stream rebased timestamps, sorted along the
              record axis, N % record_tile == 0 (pad tails with any finite
              value — padded keep bits are garbage; the wrapper masks by
              length). ``config`` picks the record tile
              (:class:`repro.kernels.tuning.TileConfig`; ``None`` = the
              default 1024-record tile — bit-identical to the pre-tuner
              kernel).
    starts  : (S, max_range) int32 exact per-bucket start offsets; tail
              entries past a row's ``n_buckets`` must be the record count.
    counts  : (S, max_range) int32 exact per-bucket sizes (0 past
              ``n_buckets``).
    ktab    : (S, max_range) int32 per-bucket keep budgets (0 past
              ``n_buckets`` — the masked tail keeps nothing).
    scalars : (S, 3) float32 rows of (t_min, 1/span, n_buckets) with
              ``n_buckets <= max_range`` the row's own bucket count.

    ``max_range`` is only the padded TABLE width; per-row compute uses the
    ``n_buckets`` scalar, so rows at different time ranges batch into one
    dispatch. Returns (scale_stamp int32 (S, N), keep int32 (S, N)).
    """
    cfg = DEFAULT_CONFIG if config is None else config
    sublane = cfg.sublane
    S, n = t.shape
    assert n % cfg.record_tile == 0, \
        f"pad records to a multiple of {cfg.record_tile}"
    assert max_range <= MAX_RANGE_LIMIT, \
        f"max_range {max_range} too large for the +-1 bucket snap"
    rows = n // LANE
    t3 = t.reshape(S, rows, LANE)
    grid = (S, rows // sublane)
    ss, keep = pl.pallas_call(
        functools.partial(_kernel, max_range=max_range, sublane=sublane),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, max_range), lambda s, i: (s, 0)),
            pl.BlockSpec((1, max_range), lambda s, i: (s, 0)),
            pl.BlockSpec((1, max_range), lambda s, i: (s, 0)),
            pl.BlockSpec((1, 3), lambda s, i: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((S, rows, LANE), jnp.int32),
        ],
        interpret=interpret,
    )(t3, starts, counts, ktab, scalars)
    return ss.reshape(S, n), keep.reshape(S, n)
