"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def scale_stamp_ref(t: jnp.ndarray, t_min: jnp.ndarray, span: jnp.ndarray,
                    max_range: int) -> jnp.ndarray:
    """Min-Max normalize timestamps to integer buckets (paper formula (1))."""
    ss = jnp.floor((t - t_min) / span * max_range).astype(jnp.int32)
    return jnp.clip(ss, 0, max_range - 1)


def stream_sample_ref(t: jnp.ndarray, starts: jnp.ndarray,
                      counts: jnp.ndarray, ktab: jnp.ndarray,
                      scalars: jnp.ndarray,
                      max_range: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched fused NSA inner loop: (scale_stamp, systematic keep mask).

    Same contract as ``stream_sample_pallas``: t (S, N) f32 sorted per-stream
    timestamps; ``starts``/``counts``/``ktab`` the exact (S, max_range)
    per-bucket tables (``max_range`` is the padded table width); ``scalars``
    (S, 3) rows of (t_min, 1/span, n_buckets) — each row normalizes into its
    OWN ``n_buckets`` bucket count, so rows at different time ranges batch
    together. The f32 bucket guess is snapped by +-1 to the bucket containing
    the record index (the tables are exact, so the snapped stamp matches the
    f64 host path). Keep rule (Bresenham-even, k of c records survive):
        keep(rank) = (rank * k) mod c < k
    """
    del max_range  # table width only; rows carry their own bucket count
    S, n = t.shape
    t_min = scalars[:, 0:1]
    inv_span = scalars[:, 1:2]
    nb_f = scalars[:, 2:3]
    nb = nb_f.astype(jnp.int32)
    g = jnp.floor((t - t_min) * inv_span * nb_f).astype(jnp.int32)
    g = jnp.clip(g, 0, nb - 1)
    gidx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (S, n))
    s_g = jnp.take_along_axis(starts, g, axis=1)
    c_g = jnp.take_along_axis(counts, g, axis=1)
    g = g + (gidx >= s_g + c_g).astype(jnp.int32) \
          - (gidx < s_g).astype(jnp.int32)
    ss = jnp.clip(g, 0, nb - 1)
    start = jnp.take_along_axis(starts, ss, axis=1)
    c = jnp.take_along_axis(counts, ss, axis=1)
    k = jnp.take_along_axis(ktab, ss, axis=1)
    rank = gidx - start
    keep = (rank * k) % jnp.maximum(c, 1) < k
    return ss, keep.astype(jnp.int32)


def compact_ref(mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mask-compaction oracle: exclusive prefix sum + total kept count.

    mask: (n,) int32 0/1. Returns (pos int32 (n,), total int32 (1,)) with
    ``pos[i]`` = number of set entries strictly before ``i``.
    """
    m = mask.astype(jnp.int32)
    incl = jnp.cumsum(m)
    return (incl - m).astype(jnp.int32), incl[-1:].astype(jnp.int32)


def bucket_hist_ref(ss: jnp.ndarray, max_range: int) -> jnp.ndarray:
    """Histogram of scale stamps: counts[b] = |{i : ss_i == b}|."""
    return jnp.zeros(max_range, jnp.int32).at[ss].add(1)


def stream_metrics_ref(ss: jnp.ndarray,
                       buckets: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused-metrics-engine oracle: batched histogram + count moments.

    Same contract as ``stream_metrics_pallas``: ss (S, N) int32 with padding
    entries >= ``buckets`` (dropped). Returns (hist int32 (S, buckets),
    moments f32 (S, 2)) with moments[s] = [Σq, Σq²] over hist[s].
    """
    hist = jax.vmap(
        lambda row: jnp.zeros(buckets, jnp.int32).at[row].add(1, mode="drop")
    )(ss)
    q = hist.astype(jnp.float32)
    mom = jnp.stack([q.sum(axis=1), (q * q).sum(axis=1)], axis=1)
    return hist, mom


def trend_scan_ref(q: jnp.ndarray) -> jnp.ndarray:
    """Trend-scan oracle: batched inclusive prefix sum over the time axis.

    Same contract as ``trend_scan_pallas``: q (S, N) int32 count series
    (zero-padded time tails). Returns ``psum int32 (S, N)`` with
    ``psum[s, i] = Σ_{j <= i} q[s, j]``.
    """
    return jnp.cumsum(q.astype(jnp.int32), axis=1).astype(jnp.int32)


def pair_stats_ref(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pair-statistics oracle: per-stream sums + S×S Gram matrix.

    Same contract as ``pair_stats_pallas``: x (S, K) f32 stacked trend
    series (zero-padded time tails). Returns ``(sums f32 (S, 1),
    gram f32 (S, S))`` with ``gram[a, b] = Σ_t x[a, t]·x[b, t]`` — the
    sufficient statistics ``[Σx, Σy, Σxy, Σx², Σy²]`` for every pair.
    """
    xf = x.astype(jnp.float32)
    return xf.sum(axis=1, keepdims=True), xf @ xf.T


def volatility_ref(q: jnp.ndarray) -> jnp.ndarray:
    """Fused first two moments of the per-second count series.

    Returns [sum, sum_sq] (float32); avg/var/std derive on the host side.
    """
    qf = q.astype(jnp.float32)
    return jnp.stack([qf.sum(), (qf * qf).sum()])


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray) -> jnp.ndarray:
    """GQA decode attention oracle.

    q: (B, H, D) one query per sequence (the new token)
    k: (B, S, Kh, D), v: (B, S, Kh, D) KV cache, H = Kh * G
    lengths: (B,) valid cache lengths; positions >= length are masked.
    Returns (B, H, D) in q's dtype; accumulation in f32.
    """
    B, H, D = q.shape
    S, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.reshape(B, Kh, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(D).astype(jnp.float32)
    pos = jnp.arange(S)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(B, H, D).astype(q.dtype)
