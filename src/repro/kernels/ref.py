"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def scale_stamp_ref(t: jnp.ndarray, t_min: jnp.ndarray, span: jnp.ndarray,
                    max_range: int) -> jnp.ndarray:
    """Min-Max normalize timestamps to integer buckets (paper formula (1))."""
    ss = jnp.floor((t - t_min) / span * max_range).astype(jnp.int32)
    return jnp.clip(ss, 0, max_range - 1)


def stream_sample_ref(t: jnp.ndarray, starts: jnp.ndarray,
                      counts: jnp.ndarray, t_min: jnp.ndarray,
                      span: jnp.ndarray, multiple: jnp.ndarray,
                      max_range: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused NSA inner loop: (scale_stamp, systematic keep mask).

    ``starts``/``counts`` are the per-bucket offsets/sizes of the (sorted)
    timestamp array. Keep rule (Bresenham-even, k of c records survive):
        k = clip(round(c / multiple), 1)
        keep(rank) = (rank * k) mod c < k
    """
    n = t.shape[0]
    ss = scale_stamp_ref(t, t_min, span, max_range)
    start = starts[ss]
    c = counts[ss]
    rank = jnp.arange(n, dtype=jnp.int32) - start
    k = jnp.clip(jnp.rint(c.astype(jnp.float32) / multiple), 1, None)
    k = k.astype(jnp.int32)
    keep = (rank * k) % jnp.maximum(c, 1) < k
    return ss, keep.astype(jnp.int32)


def bucket_hist_ref(ss: jnp.ndarray, max_range: int) -> jnp.ndarray:
    """Histogram of scale stamps: counts[b] = |{i : ss_i == b}|."""
    return jnp.zeros(max_range, jnp.int32).at[ss].add(1)


def volatility_ref(q: jnp.ndarray) -> jnp.ndarray:
    """Fused first two moments of the per-second count series.

    Returns [sum, sum_sq] (float32); avg/var/std derive on the host side.
    """
    qf = q.astype(jnp.float32)
    return jnp.stack([qf.sum(), (qf * qf).sum()])


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray) -> jnp.ndarray:
    """GQA decode attention oracle.

    q: (B, H, D) one query per sequence (the new token)
    k: (B, S, Kh, D), v: (B, S, Kh, D) KV cache, H = Kh * G
    lengths: (B,) valid cache lengths; positions >= length are masked.
    Returns (B, H, D) in q's dtype; accumulation in f32.
    """
    B, H, D = q.shape
    S, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.reshape(B, Kh, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(D).astype(jnp.float32)
    pos = jnp.arange(S)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(B, H, D).astype(q.dtype)
