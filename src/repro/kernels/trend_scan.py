"""Pallas TPU kernels: device-resident trend scan + S×S pair statistics.

Closes the host-side gap in the Fig.-6 validation path. After PR 2 the
fused metrics engine (:mod:`repro.kernels.metrics_fused`) produces each
stream's per-second counts ``q`` on device, but the *trend* (windowed
sliding mean of ``q``) and the *trend correlation* (Pearson r between two
streams' trends) still ran on host over a ``np.cumsum``. The two kernels
here keep the whole chain — counts → prefix sums → trend → S×S correlation
sufficient statistics — device-resident:

``trend_scan_pallas``
    Batched inclusive prefix sum over the time axis of ``(S, N)`` stacked
    count series — the same single-pass scan-with-carry pattern as
    :mod:`repro.kernels.compact`, lifted to a 2-D ``(stream, time-tile)``
    grid: each grid step computes the tile-local cumsum (lane-wise
    ``cumsum`` + row offsets) and adds the running carry held in SMEM
    scratch, resetting the carry at each stream's first tile. Counts
    accumulate in int32, so prefix sums are *exact* while a stream's total
    record count stays below 2³¹ (enforced by the ops wrapper). The caller
    turns prefix sums into the windowed sliding mean with two clamped
    gathers and one divide (:func:`repro.kernels.ops.trend_scan`) — pure
    XLA, no host round-trip, mirroring how ``compact`` pairs its scan with
    one XLA scatter.

``pair_stats_pallas``
    Scan-with-carry accumulation of the Pearson sufficient statistics for
    ALL S×S stream pairs in one dispatch: the grid walks time tiles of the
    ``(S, K)`` trend matrix while the per-stream sums ``Σx`` and the Gram
    matrix ``G[a, b] = Σ_t x_a[t]·x_b[t]`` stay VMEM-resident (their output
    index maps ignore the tile index — the same residency trick as the
    metrics engine's histogram). From ``(sums, G)`` every pair's five
    sufficient statistics follow: ``Σx = sums[a]``, ``Σy = sums[b]``,
    ``Σxy = G[a, b]``, ``Σx² = G[a, a]``, ``Σy² = G[b, b]``. The per-tile
    update is one ``x_tile @ x_tileᵀ`` MXU matmul, so S×S cost rides the
    systolic array instead of an S²-pair host loop.

Numerical contract: the ops layer feeds ``pair_stats_pallas`` *centered*
trends (mean removed on device), so the correlation reduces to
``G[a,b] / √(G[a,a]·G[b,b])`` with no catastrophic ``K·Σxy − Σx·Σy``
cancellation; f32 accumulation then lands within the metrics layer's 1e-3
tolerance of the float64 host path. Zero padding (time tails, centered
series) contributes exactly 0 to every statistic.

Layout mirrors the other kernels: the time axis is padded to a multiple of
the (8, 128) record tile (``trend_scan``) or of ``PAIR_TILE`` lanes
(``pair_stats``); padded entries must be 0.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tuning import DEFAULT_CONFIG, TileConfig

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE   # time steps per grid step (default TileConfig)
PAIR_TILE = 4 * LANE    # time steps per pair-stats step (default config)


def _scan_kernel(q_ref, psum_ref, carry_ref):
    s = pl.program_id(0)
    i = pl.program_id(1)
    del s  # the carry reset below only needs the tile index

    @pl.when(i == 0)
    def _reset():
        carry_ref[0] = 0

    q = q_ref[0].astype(jnp.int32)                   # (SUBLANE, LANE)
    # tile-local inclusive cumsum in row-major time order: lane-wise
    # inclusive scan, then per-row offsets from the row totals
    row_incl = jnp.cumsum(q, axis=1)
    row_tot = row_incl[:, -1:]
    row_off = jnp.cumsum(row_tot, axis=0) - row_tot  # exclusive over rows

    carry = carry_ref[0]
    psum_ref[0] = carry + row_off + row_incl
    carry_ref[0] = carry + jnp.sum(q)


@functools.partial(jax.jit, static_argnames=("interpret", "config"))
def trend_scan_pallas(q: jnp.ndarray, *, interpret: bool = False,
                      config: Optional[TileConfig] = None):
    """Batched inclusive prefix sum over stacked per-second count series.

    q : (S, N) int32, N % TILE == 0 (pad time tails with 0).

    Returns ``psum int32 (S, N)`` with
    ``psum[s, i] = Σ_{j <= i} q[s, j]`` — exact while each stream's total
    stays below 2³¹ (the ops wrapper guards this).
    """
    cfg = DEFAULT_CONFIG if config is None else config
    sublane = cfg.sublane
    S, n = q.shape
    assert n % cfg.record_tile == 0, \
        f"pad time steps to a multiple of {cfg.record_tile}"
    rows = n // LANE
    q3 = q.reshape(S, rows, LANE)
    grid = (S, rows // sublane)
    psum = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0))],
        out_specs=pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, rows, LANE), jnp.int32),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(q3)
    return psum.reshape(S, n)


def _scan_kernel_carry(init_ref, q_ref, psum_ref, tail_ref, carry_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _seed():                                     # carry-IN, not a reset
        carry_ref[0] = init_ref[0, 0]

    q = q_ref[0].astype(jnp.int32)                   # (SUBLANE, LANE)
    row_incl = jnp.cumsum(q, axis=1)
    row_tot = row_incl[:, -1:]
    row_off = jnp.cumsum(row_tot, axis=0) - row_tot  # exclusive over rows

    carry = carry_ref[0]
    psum_ref[0] = carry + row_off + row_incl
    carry_ref[0] = carry + jnp.sum(q)

    @pl.when(i == pl.num_programs(1) - 1)
    def _tail():
        tail_ref[0, 0] = carry_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret", "config"))
def trend_scan_carry_pallas(q: jnp.ndarray, init: jnp.ndarray, *,
                            interpret: bool = False,
                            config: Optional[TileConfig] = None):
    """Chunked form of :func:`trend_scan_pallas`: the SMEM running carry is
    *seeded* from a per-row carry-in instead of reset to zero, so prefix
    sums over consecutive time chunks compose exactly.

    q    : (S, N) int32 — one time chunk per row, N % TILE == 0 (pad time
           tails with 0).
    init : (S,) int32 — each row's inclusive prefix total through the last
           bucket of the PREVIOUS chunk (zeros for the first chunk, which
           makes this bit-identical to :func:`trend_scan_pallas`).

    Returns ``(psum int32 (S, N), tail int32 (S,))`` where
    ``psum[s, i] = init[s] + Σ_{j <= i} q[s, j]`` and ``tail[s]`` is the
    row's new running total — the ``init`` to feed the next chunk. Exact
    while the cumulative total stays below 2³¹ (ops-wrapper guarded).
    """
    cfg = DEFAULT_CONFIG if config is None else config
    sublane = cfg.sublane
    S, n = q.shape
    assert n % cfg.record_tile == 0, \
        f"pad time steps to a multiple of {cfg.record_tile}"
    rows = n // LANE
    q3 = q.reshape(S, rows, LANE)
    grid = (S, rows // sublane)
    psum, tail = pl.pallas_call(
        _scan_kernel_carry,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, i: (s, 0)),
            pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, 1), lambda s, i: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(init.reshape(S, 1).astype(jnp.int32), q3)
    return psum.reshape(S, n), tail.reshape(S)


def _pair_kernel(x_ref, sums_ref, gram_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        gram_ref[...] = jnp.zeros_like(gram_ref)

    x = x_ref[...]                                   # (S, PAIR_TILE) f32
    sums_ref[...] += jnp.sum(x, axis=1, keepdims=True)
    gram_ref[...] += jnp.dot(x, x.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "config"))
def pair_stats_pallas(x: jnp.ndarray, *, interpret: bool = False,
                      config: Optional[TileConfig] = None):
    """All-pairs Pearson sufficient statistics over stacked trend series.

    x : (S, K) float32, K % PAIR_TILE == 0 (pad time tails with 0.0 —
        zeros contribute nothing to any statistic).

    Returns ``(sums f32 (S, 1), gram f32 (S, S))`` where
    ``sums[s] = Σ_t x[s, t]`` and ``gram[a, b] = Σ_t x[a, t]·x[b, t]`` —
    together the ``[Σx, Σy, Σxy, Σx², Σy²]`` bundle for every stream pair,
    accumulated tile-by-tile with the (sums, gram) outputs VMEM-resident
    across the time grid.
    """
    cfg = DEFAULT_CONFIG if config is None else config
    pair_tile = cfg.bucket_block      # the pair-stats time tile knob
    S, k = x.shape
    assert k % pair_tile == 0, \
        f"pad time steps to a multiple of {pair_tile}"
    grid = (k // pair_tile,)
    sums, gram = pl.pallas_call(
        _pair_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((S, pair_tile), lambda i: (0, i))],
        out_specs=[
            pl.BlockSpec((S, 1), lambda i: (0, 0)),
            pl.BlockSpec((S, S), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
            jax.ShapeDtypeStruct((S, S), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return sums, gram
