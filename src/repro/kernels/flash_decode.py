"""Pallas TPU kernel: blocked online-softmax GQA decode attention.

The serving hot-spot under the paper's load-testing scenario: one new token
per sequence attends to a long KV cache. The op is purely memory-bound
(arithmetic intensity ~2 flops/byte), so the kernel's job is to stream K/V
through VMEM exactly once at full HBM bandwidth with no (B, S)-sized
intermediates — the online-softmax recurrence keeps only (H,)-sized running
max/denominator and an (H, D) accumulator in VMEM scratch across the
sequential seq-block grid axis.

GQA layout: H = Kh * G query heads share Kh KV heads; scores are computed as
a Kh-batched (G, D) x (D, Sb) matmul so the MXU sees dense tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, kh: int, g: int):
    b, j = pl.program_id(0), pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32).reshape(kh, g, d)     # (Kh, G, D)
    k = k_ref[0].astype(jnp.float32)                       # (Sb, Kh, D)
    v = v_ref[0].astype(jnp.float32)

    # (Kh, G, Sb) batched matmul over the shared-KV head groups
    scores = jax.lax.dot_general(
        q, jnp.swapaxes(k, 0, 1),
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
    ) / jnp.sqrt(jnp.float32(d))

    # mask cache positions beyond the valid length
    length = len_ref[0, 0]
    pos = j * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 2)
    scores = jnp.where(pos < length, scores, NEG_INF)

    m_prev = m_ref[...].reshape(kh, g)                     # (Kh, G)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)                         # (Kh, G)
    p = jnp.exp(scores - m_new[..., None])                 # (Kh, G, Sb)

    l_prev = l_ref[...].reshape(kh, g)
    l_new = l_prev * corr + p.sum(axis=-1)

    # (Kh, G, D) contribution via Kh-batched (G, Sb) x (Sb, D)
    pv = jax.lax.dot_general(
        p, jnp.swapaxes(v, 0, 1),
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
    )
    acc_prev = acc_ref[...].reshape(kh, g, d)
    acc_new = acc_prev * corr[..., None] + pv

    m_ref[...] = m_new.reshape(m_ref.shape)
    l_ref[...] = l_new.reshape(l_ref.shape)
    acc_ref[...] = acc_new.reshape(acc_ref.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...].reshape(kh, g), 1e-30)
        out = acc_ref[...].reshape(kh, g, d) / denom[..., None]
        o_ref[...] = out.reshape(1, kh * g, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        lengths: jnp.ndarray, *, block_s: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, D); k, v: (B, S, Kh, D); lengths: (B,) int32.
    S must be a multiple of ``block_s``. Returns (B, H, D) in q's dtype."""
    b_sz, h, d = q.shape
    s, kh = k.shape[1], k.shape[2]
    assert h % kh == 0, "query heads must be a multiple of KV heads"
    assert s % block_s == 0, f"cache length {s} % block_s {block_s} != 0"
    g = h // kh
    grid = (b_sz, s // block_s)
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, kh=kh, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, j: (b, 0, 0)),          # q
            pl.BlockSpec((1, block_s, kh, d), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_s, kh, d), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),                # length
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b_sz, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, h), jnp.float32),       # running max
            pltpu.VMEM((1, h), jnp.float32),       # running denominator
            pltpu.VMEM((h, d), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(q, k, v, lengths.reshape(b_sz, 1).astype(jnp.int32))
