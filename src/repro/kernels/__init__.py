"""Pallas TPU kernels for the framework's compute hot-spots.

Paper hot-spots (bandwidth-bound scans over millions of records):
- :mod:`repro.kernels.stream_sample` — fused NSA inner loop, batched over
  S stacked streams in one 2-D-grid dispatch: Min-Max normalize ->
  scale-stamp -> systematic keep mask (one HBM pass).
- :mod:`repro.kernels.compact`       — mask compaction: tiled exclusive
  prefix sum with an SMEM carry -> per-record write positions + total, so
  kept-record indices materialize on device (no host round-trip).
- :mod:`repro.kernels.metrics_fused` — fused batched metrics engine: the
  per-scale-stamp histogram (int32-exact, bucket axis block-tiled so a full
  86 400-second day fits VMEM) AND its count moments [Σq, Σq²] from ONE
  pass over the record tiles of S stacked streams (subsumes the seed's
  separate one-hot histogram and moment kernels).
- :mod:`repro.kernels.trend_scan`  — device-resident trend & correlation:
  a batched prefix-sum scan-with-carry over per-second counts (the trend's
  sliding-mean window sums) plus an all-pairs sufficient-statistics
  accumulator (per-stream sums + S×S Gram matrix, VMEM-resident across the
  time grid), so the whole Fig.-6 path — counts -> trend -> S×S Pearson
  matrix — runs without a host cumsum.

Serving hot-spot under the paper's load-testing scenario:
- :mod:`repro.kernels.flash_decode`  — blocked online-softmax GQA decode
  attention (one new token vs. a long KV cache).

Each kernel ships a pure-jnp oracle in :mod:`repro.kernels.ref` and a jit'd
public wrapper in :mod:`repro.kernels.ops` that selects ``interpret=True``
automatically off-TPU (this container is CPU-only; TPU is the target).
"""

from repro.kernels import ops, ref  # noqa: F401
