"""Pallas TPU kernel: mask compaction via a tiled exclusive prefix sum.

Turns a 0/1 keep mask into the write position of every record (``pos[i] =
number of kept records before i``) plus the total kept count, in ONE
sequential HBM pass: the TPU grid walks record tiles in order, each step
computing the tile-local exclusive cumsum (lane-wise ``cumsum`` + row
offsets) and adding the running carry held in SMEM scratch — the classic
single-pass scan-with-carry, no second kernel launch and no host round-trip.

The caller turns positions into gathered kept-record *indices* with one XLA
scatter (``zeros.at[pos[kept]].set(iota)``, see :func:`repro.kernels.ops.
compact_mask`) — TPUs have no fast in-kernel scatter, but a dense
length-``n`` scatter with device-computed positions is a single additional
bandwidth pass and keeps the whole NSA chain on device.

Layout mirrors the other kernels: records padded to a multiple of the
(8, 128) tile; padded entries must carry mask ``0``.

``compact_positions_batched_pallas`` lifts the same scan to a 2-D
``(row, record-tile)`` grid — the carry resets at each row's first tile
(the :mod:`repro.kernels.trend_scan` pattern), so R rows' keep masks
compact in ONE dispatch with per-row totals. This is the compaction leg of
the range-padded NSA sweep: every (dataset × max_range) scenario is a row.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tuning import DEFAULT_CONFIG, TileConfig

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE  # records per grid step with the default TileConfig


def _kernel(mask_ref, pos_ref, total_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[0] = 0

    m = mask_ref[...].astype(jnp.int32)              # (SUBLANE, LANE) 0/1
    # tile-local exclusive cumsum in row-major record order:
    # lane-wise inclusive scan, then per-row offsets from the row totals
    row_incl = jnp.cumsum(m, axis=1)
    row_tot = row_incl[:, -1:]
    row_off = jnp.cumsum(row_tot, axis=0) - row_tot  # exclusive over rows
    excl = row_incl - m + row_off

    carry = carry_ref[0]
    pos_ref[...] = carry + excl
    carry_ref[0] = carry + jnp.sum(m)
    total_ref[0] = carry_ref[0]                      # last grid step wins


@functools.partial(jax.jit, static_argnames=("interpret", "config"))
def compact_positions_pallas(mask: jnp.ndarray, *, interpret: bool = False,
                             config: Optional[TileConfig] = None):
    """mask: (n,) int32 0/1, n % TILE == 0 (pad with 0).

    Returns ``(pos int32 (n,), total int32 (1,))`` where ``pos[i]`` is the
    exclusive prefix sum of the mask (the output slot of record ``i`` if it
    is kept) and ``total`` the number of set mask entries.
    """
    cfg = DEFAULT_CONFIG if config is None else config
    sublane = cfg.sublane
    n = mask.shape[0]
    assert n % cfg.record_tile == 0, \
        f"pad records to a multiple of {cfg.record_tile}"
    rows = n // LANE
    m2 = mask.reshape(rows, LANE)
    grid = (rows // sublane,)
    pos, total = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((sublane, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((sublane, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(m2)
    return pos.reshape(n), total


def _kernel_batched(mask_ref, pos_ref, total_ref, carry_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _reset():                                    # new row: fresh carry
        carry_ref[0] = 0

    m = mask_ref[0].astype(jnp.int32)                # (SUBLANE, LANE) 0/1
    row_incl = jnp.cumsum(m, axis=1)
    row_tot = row_incl[:, -1:]
    row_off = jnp.cumsum(row_tot, axis=0) - row_tot  # exclusive over rows

    carry = carry_ref[0]
    pos_ref[0] = carry + row_incl - m + row_off
    carry_ref[0] = carry + jnp.sum(m)
    total_ref[0, 0] = carry_ref[0]                   # row's last tile wins


@functools.partial(jax.jit, static_argnames=("interpret", "config"))
def compact_positions_batched_pallas(mask: jnp.ndarray, *,
                                     interpret: bool = False,
                                     config: Optional[TileConfig] = None):
    """Batched mask compaction: R rows' scans in ONE 2-D-grid dispatch.

    mask: (R, N) int32 0/1, N % TILE == 0 (pad record tails with 0).

    Returns ``(pos int32 (R, N), totals int32 (R, 1))`` — per row the same
    contract as :func:`compact_positions_pallas`: ``pos[r, i]`` is the
    exclusive prefix sum of row ``r``'s mask and ``totals[r]`` its set-entry
    count. The SMEM carry resets at each row's first record tile, so rows
    are independent (bit-identical to R sequential single-row dispatches).
    """
    cfg = DEFAULT_CONFIG if config is None else config
    sublane = cfg.sublane
    R, n = mask.shape
    assert n % cfg.record_tile == 0, \
        f"pad records to a multiple of {cfg.record_tile}"
    rows = n // LANE
    m3 = mask.reshape(R, rows, LANE)
    grid = (R, rows // sublane)
    pos, totals = pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[pl.BlockSpec((1, sublane, LANE), lambda r, i: (r, i, 0))],
        out_specs=[
            pl.BlockSpec((1, sublane, LANE), lambda r, i: (r, i, 0)),
            pl.BlockSpec((1, 1), lambda r, i: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, rows, LANE), jnp.int32),
            jax.ShapeDtypeStruct((R, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(m3)
    return pos.reshape(R, n), totals
