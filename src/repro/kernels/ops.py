"""Public jit'd wrappers over the Pallas kernels.

Each op handles padding/layout, dispatches to the Pallas kernel (TPU) or its
``interpret=True`` execution (CPU — this container), and exposes exactly the
semantics the pure-jnp oracles in :mod:`repro.kernels.ref` define. Tests
sweep shapes/dtypes asserting allclose against the oracles.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.compact import compact_positions_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.metrics_fused import (BUCKET_BLOCK, TILE,
                                         stream_metrics_pallas)
from repro.kernels.stream_sample import stream_sample_pallas


def on_tpu() -> bool:
    """Single source of truth for the device-selection predicate."""
    return jax.default_backend() == "tpu"


_on_tpu = on_tpu


class PallasDomainError(ValueError):
    """The inputs fall outside the Pallas kernels' exactness domain.

    Raised by the ops wrappers *before* dispatch; ``nsa(backend="pallas")``
    catches it and falls back to the numpy path, so callers only see it
    when invoking the ops layer directly.
    """


class KeepRuleOverflow(PallasDomainError):
    """The systematic keep rule ``(rank * k) % c`` would overflow int32.

    The kernel (and its oracle) compute the Bresenham product in int32 —
    the TPU-native width — which is exact only while ``(c - 1) * k < 2**31``
    for every bucket. Streams with enormous single buckets and weak
    compression (e.g. 100k identical timestamps at multiple ~3) violate
    this; the wrappers refuse them rather than silently diverge from the
    int64 numpy path, and ``nsa(backend="pallas")`` falls back to numpy.
    """


def _pad_to(x: jnp.ndarray, mult: int, value) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])
    return x, n


# --------------------------------------------------------------------- NSA
def _nsa_tables(t64: np.ndarray, max_range: int, multiple: float):
    """Exact per-bucket tables + kernel inputs for one sorted stream.

    Computes (rebased f32 timestamps, starts, counts, ktab, (t_min, 1/span))
    where the tables come from the *float64 host formula* — the identical
    expression ``(t - t_min) / span * max_range`` that
    :func:`repro.streamsim.nsa.scale_stamps` floors — so the kernel's
    +-1-snapped scale stamps are bit-identical to the numpy path. O(n)
    vectorized host work for ``v`` plus O(max_range log n) searchsorted;
    everything per-record then runs on device.
    """
    from repro.kernels.stream_sample import MAX_RANGE_LIMIT
    if max_range > MAX_RANGE_LIMIT:
        raise PallasDomainError(
            f"max_range {max_range} exceeds {MAX_RANGE_LIMIT}: the +-1 "
            "bucket snap no longer bounds the f32 normalize error; use the "
            "numpy NSA path")
    n = len(t64)
    t_min, t_max = float(t64[0]), float(t64[-1])
    span = t_max - t_min
    if span <= 0.0:
        # degenerate stream (all timestamps equal): everything is bucket 0,
        # so bucket 0 spans [0, n) and every later bucket starts at n
        starts = np.full(max_range, n, np.int32)
        starts[0] = 0
        inv_span = 0.0
    else:
        v = (t64 - t_min) / span * max_range
        starts = np.searchsorted(v, np.arange(max_range)).astype(np.int32)
        inv_span = 1.0 / span
    counts = np.diff(np.append(starts, n)).astype(np.int32)
    ktab = np.clip(np.rint(counts / multiple), 1, None).astype(np.int32)
    prod = (counts.astype(np.int64) - 1).clip(0) * ktab.astype(np.int64)
    if prod.max(initial=0) >= 2 ** 31:
        raise KeepRuleOverflow(
            f"bucket with count={counts[prod.argmax()]} and "
            f"k={ktab[prod.argmax()]} overflows the int32 keep rule; "
            "use the numpy NSA path for this stream")
    t32 = (t64 - t_min).astype(np.float32)
    return t32, starts, counts, ktab, (0.0, inv_span)


def stream_sample(t: jnp.ndarray, max_range: int,
                  multiple: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused NSA inner loop on device (single stream == batch of one).

    t must be sorted ascending. Returns (scale_stamp int32, keep bool), both
    length n. Mirrors repro.streamsim.nsa semantics exactly (keep =
    'systematic', multiple precomputed by the caller).

    Epoch-second timestamps (~1.5e9) quantize to ~128 s in float32, so the
    wrapper re-bases to relative time in float64 *before* the cast. The
    per-bucket tables are computed with the exact float64 host formula and
    the kernel snaps its f32 bucket guess to them, so the outputs are
    bit-identical to the numpy NSA path — not merely allclose.
    """
    t64 = np.asarray(t, np.float64)
    n = len(t64)
    if n == 0:
        return jnp.zeros(0, jnp.int32), jnp.zeros(0, bool)
    t32, starts, counts, ktab, scalars = _nsa_tables(t64, max_range, multiple)
    tp, n0 = _pad_to(jnp.asarray(t32), TILE, t32[-1])
    ss, keep = stream_sample_pallas(
        tp[None, :], jnp.asarray(starts)[None, :],
        jnp.asarray(counts)[None, :], jnp.asarray(ktab)[None, :],
        jnp.asarray(scalars, jnp.float32)[None, :], max_range,
        interpret=not _on_tpu())
    return ss[0, :n0], keep[0, :n0].astype(bool)


def stream_sample_ref(t: jnp.ndarray, max_range: int, multiple: float):
    """Oracle with the same padding-free public signature."""
    t64 = np.asarray(t, np.float64)
    if len(t64) == 0:
        return jnp.zeros(0, jnp.int32), jnp.zeros(0, bool)
    t32, starts, counts, ktab, scalars = _nsa_tables(t64, max_range, multiple)
    ss, keep = ref.stream_sample_ref(
        jnp.asarray(t32)[None, :], jnp.asarray(starts)[None, :],
        jnp.asarray(counts)[None, :], jnp.asarray(ktab)[None, :],
        jnp.asarray(scalars, jnp.float32)[None, :], max_range)
    return ss[0], keep[0].astype(bool)


def stream_sample_batched(ts, max_range: int, multiples):
    """Batched fused NSA inner loop: S streams, ONE kernel dispatch.

    ts        : sequence of S sorted 1-D float64 timestamp arrays (ragged
                lengths allowed) or an (S, N) array.
    multiples : per-stream multiple (scalar broadcasts).

    Pads every stream to the common TILE-aligned length and runs the 2-D-grid
    kernel once — replacing S sequential :func:`stream_sample` dispatches.
    Returns (scale_stamp int32 (S, N), keep bool (S, N), lengths int (S,));
    padded tail entries have keep == False.
    """
    ts = [np.asarray(t, np.float64) for t in ts]
    S = len(ts)
    if S == 0:
        raise ValueError("need at least one stream")
    lengths = np.array([len(t) for t in ts])
    if np.any(lengths == 0):
        raise ValueError("batched path requires non-empty streams")
    mults = np.broadcast_to(np.asarray(multiples, np.float64), (S,))
    N = int(-(-lengths.max() // TILE) * TILE)
    t_b = np.empty((S, N), np.float32)
    starts_b = np.empty((S, max_range), np.int32)
    counts_b = np.empty((S, max_range), np.int32)
    k_b = np.empty((S, max_range), np.int32)
    scal_b = np.empty((S, 2), np.float32)
    for s, t64 in enumerate(ts):
        t32, starts, counts, ktab, scalars = _nsa_tables(
            t64, max_range, float(mults[s]))
        t_b[s, :len(t32)] = t32
        t_b[s, len(t32):] = t32[-1]          # pad into the last bucket
        starts_b[s], counts_b[s], k_b[s] = starts, counts, ktab
        scal_b[s] = scalars
    ss, keep = stream_sample_pallas(
        jnp.asarray(t_b), jnp.asarray(starts_b), jnp.asarray(counts_b),
        jnp.asarray(k_b), jnp.asarray(scal_b), max_range,
        interpret=not _on_tpu())
    valid = jnp.arange(N)[None, :] < jnp.asarray(lengths)[:, None]
    return ss, keep.astype(bool) & valid, lengths


# -------------------------------------------------------------- compaction
def compact_mask(mask: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Kept-record indices from a boolean keep mask, on device.

    Chains the Pallas scan-with-carry kernel (exclusive prefix sum over the
    mask -> per-record write position + total) with one XLA scatter that
    lands each kept record's index in its slot — no host round-trip over the
    record axis.

    Returns ``(idx int32 (n,), total int)``: ``idx[:total]`` are the indices
    of the set entries in ascending order; ``idx[total:]`` are ``n``.
    """
    mask = jnp.asarray(mask)
    n = mask.shape[0]
    if n == 0:
        return jnp.zeros(0, jnp.int32), 0
    mp, _ = _pad_to(mask.astype(jnp.int32), TILE, 0)
    pos, total = compact_positions_pallas(mp, interpret=not _on_tpu())
    tgt = jnp.where(mask.astype(bool), pos[:n], n)
    idx = jnp.full((n,), n, jnp.int32).at[tgt].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    return idx, int(total[0])


# -------------------------------------------------------- metrics engine
# int32 histogram accumulation: exact while every bucket count < 2**31
# (the seed's f32 one-hot kernel silently rounded past 2**24)
_HIST_COUNT_LIMIT = 2 ** 31 - 1


def _check_metrics_domain(n_records: int) -> None:
    """A bucket count can at most reach the record count; refuse streams
    whose counts could wrap the int32 accumulator rather than round."""
    if n_records > _HIST_COUNT_LIMIT:
        raise PallasDomainError(
            f"{n_records} records could overflow the int32 histogram "
            f"accumulator (limit {_HIST_COUNT_LIMIT}); use the numpy "
            "metrics path")


def _metrics_padded(ss_list, max_range: int):
    """Stack ragged scale-stamp streams into the kernel's (S, N) layout."""
    S = len(ss_list)
    lengths = np.array([len(s) for s in ss_list], np.int64)
    _check_metrics_domain(int(lengths.max(initial=0)))
    buckets = int(-(-max_range // BUCKET_BLOCK) * BUCKET_BLOCK)
    N = max(int(-(-lengths.max(initial=1) // TILE) * TILE), TILE)
    ssb = np.full((S, N), buckets, np.int32)     # padding id >= buckets
    for s, row in enumerate(ss_list):
        if len(row) and (row.min() < 0 or row.max() >= max_range):
            raise ValueError(
                f"stream {s}: scale stamps must lie in [0, {max_range})")
        ssb[s, :len(row)] = row
    return ssb, buckets, lengths


def stream_metrics(ss: jnp.ndarray,
                   max_range: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused per-second histogram + count moments, one device pass.

    ss: (n,) integer scale stamps in [0, max_range) (any order; sorted input
    is fastest — see the kernel docstring). Returns
    ``(hist int32 (max_range,), moments f32 (2,) = [Σq, Σq²])``.
    """
    hist, mom, _ = stream_metrics_batched([ss], max_range)
    return hist[0], mom[0]


def stream_metrics_batched(ss_seq, max_range: int):
    """Batched fused metrics: S streams' histograms + moments, ONE dispatch.

    ss_seq: sequence of S 1-D integer scale-stamp arrays (ragged lengths
    allowed; empty streams yield all-zero rows). Returns
    ``(hist int32 (S, max_range), moments f32 (S, 2), lengths int64 (S,))``.
    """
    ss_list = [np.asarray(s, np.int32).reshape(-1) for s in ss_seq]
    if not ss_list:
        raise ValueError("need at least one stream")
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    ssb, buckets, lengths = _metrics_padded(ss_list, max_range)
    hist, mom = stream_metrics_pallas(jnp.asarray(ssb), buckets,
                                      interpret=not _on_tpu())
    return hist[:, :max_range], mom, lengths


# --------------------------------------------------------------- histogram
def bucket_hist(ss: jnp.ndarray, max_range: int) -> jnp.ndarray:
    """Per-bucket counts of scale stamps; returns (max_range,) int32.

    Legacy wrapper over the fused metrics engine: counts accumulate in int32
    (bit-exact up to 2**31 per bucket — the seed's f32 one-hot kernel lost
    exactness past 2**24) and :class:`PallasDomainError` is raised beyond
    that domain instead of returning silently wrong counts.
    """
    return stream_metrics(ss, max_range)[0]


# -------------------------------------------------------------- volatility
def volatility_moments(q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (Σq, Σq²) over an arbitrary count series.

    When the series comes from scale stamps, prefer :func:`stream_metrics`,
    which produces the histogram AND its moments in the same record pass;
    this reduction (which subsumed the seed's standalone volatility kernel)
    exists for series that are already materialized.
    """
    out = _volatility_moments_jit(jnp.asarray(q, jnp.float32))
    return out[0], out[1]


_volatility_moments_jit = jax.jit(ref.volatility_ref)


def volatility_stats(q: jnp.ndarray) -> Tuple[float, float, float]:
    """(average, variance, std) — device-fused version of formulas (2)-(4)."""
    n = q.shape[0]
    s, s2 = volatility_moments(q)
    avg = s / n
    var = jnp.maximum(s2 / n - avg * avg, 0.0)
    return avg, var, jnp.sqrt(var)


# ------------------------------------------------------------ flash decode
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lengths: jnp.ndarray, *, block_s: int = 512) -> jnp.ndarray:
    """Blocked online-softmax GQA decode attention (see kernel docstring).

    Pads the cache axis to a block multiple; padded positions are masked by
    ``lengths`` automatically.
    """
    s = k.shape[1]
    pad = (-s) % block_s
    if pad:
        zk = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    return flash_decode_pallas(q, k, v, lengths, block_s=block_s,
                               interpret=not _on_tpu())


__all__ = [
    "KeepRuleOverflow", "PallasDomainError", "bucket_hist", "compact_mask",
    "flash_decode", "on_tpu", "stream_metrics", "stream_metrics_batched",
    "stream_sample", "stream_sample_batched", "stream_sample_ref",
    "volatility_moments", "volatility_stats",
]
